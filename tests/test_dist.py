"""Distribution machinery: divisibility-aware resolution + a real multi-device
lower/compile in a subprocess (so the main test process keeps 1 device)."""

import json
import subprocess
import sys
import textwrap

import pytest
from jax.sharding import PartitionSpec as P



def test_resolve_divisibility(monkeypatch):
    # build a fake mesh-like object without touching devices
    class FakeMesh:
        shape = {"data": 4, "model": 8}

    from repro.dist.sharding import resolve, spec_for
    rules = {"batch": ("data",), "heads": ("model",), "both": ("data", "model")}
    assert resolve(FakeMesh, 16, "batch", rules) == "data"
    assert resolve(FakeMesh, 6, "batch", rules) is None       # 6 % 4 != 0
    assert resolve(FakeMesh, 40, "heads", rules) == "model"
    assert resolve(FakeMesh, 9, "heads", rules) is None
    assert resolve(FakeMesh, 32, "both", rules) == ("data", "model")
    assert resolve(FakeMesh, 4, "both", rules) == "data"      # partial prefix
    s = spec_for(FakeMesh, (16, 9, 40), ("batch", "heads", "heads"), rules)
    assert s == P("data", None, "model")


def test_partial_rule_overrides_merge_onto_defaults():
    """Regression (EXPERIMENTS.md §Perf iter 4): a partial rules dict must
    OVERRIDE defaults, not replace them — treating it as the complete rule
    set silently replicated every param axis the override didn't mention
    (26 GiB of parameter replicas per chip in the qwen3 dry-run)."""
    from repro.dist.sharding import resolve

    class FakeMesh:
        shape = {"data": 4, "model": 8}

    # an act_seq-only override (what shape_rules returns for train/prefill)
    # must leave the default ffn -> model rule intact...
    assert resolve(FakeMesh, 64, "ffn", {"act_seq": ("model",)}) == "model"
    # ...while applying the override itself
    assert resolve(FakeMesh, 64, "act_seq", {"act_seq": ("model",)}) == "model"
    # and explicit overrides of a default still win
    assert resolve(FakeMesh, 64, "ffn", {"ffn": ()}) is None


def test_param_rules_cover_all_families(rng_key):
    """Every leaf of every family resolves without error, and the big matrices
    actually get model-axis sharding."""
    import jax
    from repro.configs import get_config
    from repro.dist.partition import param_specs
    from repro.models import build_model

    class FakeMesh:
        shape = {"data": 2, "model": 2}

    for arch in ["smollm-135m", "deepseek-moe-16b", "falcon-mamba-7b",
                 "recurrentgemma-2b", "whisper-tiny"]:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        sds = jax.eval_shape(
            (lambda k: model.init(k, enc_len=16, dec_len=16))
            if model.is_encdec else model.init,
            jax.ShapeDtypeStruct((2,), jax.numpy.uint32))
        specs = param_specs(FakeMesh, sds)
        flat = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        assert flat, arch
        sharded = [s for s in flat if any(e is not None for e in s)]
        assert sharded, f"{arch}: nothing sharded"


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from repro.configs import get_config
    from repro.dist.partition import batch_specs, param_specs, to_shardings
    from repro.dist.sharding import mesh_context
    from repro.models import build_model

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 3)
    cfg = get_config("{arch}").reduced(vocab_size=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    p_sh = to_shardings(mesh, param_specs(mesh, params))
    batch = {{"tokens": jnp.zeros((8, 32), jnp.int32),
             "labels": jnp.zeros((8, 32), jnp.int32)}}
    b_sh = to_shardings(mesh, batch_specs(mesh, batch))

    def loss_fn(p, b):
        with mesh_context(mesh):
            return model.loss(p, b)[0]

    with mesh:
        fn = jax.jit(jax.grad(loss_fn), in_shardings=(p_sh, b_sh))
        compiled = fn.lower(params, batch).compile()
        cost = compiled.cost_analysis()
        # actually execute on the 8 fake devices
        g = fn(jax.device_put(params, p_sh), jax.device_put(batch, b_sh))
        ok = all(bool(jnp.all(jnp.isfinite(x.astype(jnp.float32))))
                 for x in jax.tree.leaves(g))
    print(json.dumps({{"flops": cost.get("flops", 0), "finite": ok}}))
""")


@pytest.mark.parametrize("arch", ["smollm-135m", "deepseek-moe-16b",
                                  "falcon-mamba-7b"])
def test_multidevice_grad_compiles_and_runs(arch):
    """3-axis (pod, data, model) mesh on 8 host devices: lower, compile, RUN a
    grad step; gradients must be finite. This exercises the same sharding
    rules the 512-chip dry-run uses."""
    proc = subprocess.run(
        [sys.executable, "-c", SUBPROC.format(arch=arch)],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("pathlib").Path(__file__).resolve().parent.parent)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["finite"]
    assert out["flops"] > 0
