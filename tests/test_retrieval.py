"""Vector DB + embedder."""

import numpy as np

from repro.retrieval import HashingEmbedder, VectorDB


def test_embedder_deterministic_and_normalized():
    e = HashingEmbedder()
    toks = np.asarray([4, 8, 15, 16, 23, 42])
    v1 = e.embed_tokens(toks)
    v2 = e.embed_tokens(toks)
    np.testing.assert_array_equal(v1, v2)
    assert abs(np.linalg.norm(v1) - 1.0) < 1e-5


def test_similar_docs_rank_higher():
    e = HashingEmbedder()
    db = VectorDB(e.dim)
    a = np.asarray(list(range(50)))
    b = np.asarray(list(range(1000, 1050)))
    db.add("a", e.embed_tokens(a))
    db.add("b", e.embed_tokens(b))
    # query shares tokens with doc a
    hits = db.search(e.embed_tokens(a[:25]), top_k=2)
    assert hits[0][0] == "a"
    assert hits[0][1] > hits[1][1]


def test_topk_and_delete_with_kv_store(tmp_path):
    from repro.kvstore import FlashKVStore
    e = HashingEmbedder()
    db = VectorDB(e.dim)
    store = FlashKVStore(tmp_path)
    for i in range(10):
        cid = f"c{i}"
        db.add(cid, e.embed_tokens(np.asarray([i, i + 1, i + 2])))
        store.put(cid, b"kv")
    assert len(db.search(e.embed_tokens(np.asarray([3, 4, 5])), top_k=3)) == 3
    assert db.delete("c3", kv_store=store)
    assert not store.exists("c3")          # stale KV removed with embedding
    assert len(db) == 9
    assert all(cid != "c3" for cid, _ in
               db.search(e.embed_tokens(np.asarray([3, 4, 5])), top_k=9))


def test_duplicate_add_ignored():
    e = HashingEmbedder()
    db = VectorDB(e.dim)
    v = e.embed_tokens(np.asarray([1, 2, 3]))
    db.add("x", v)
    db.add("x", v)
    assert len(db) == 1
