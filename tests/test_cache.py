"""Cache semantics: prefill/decode equivalence across families, ring buffers."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.compose import compose_hybrid_cache, compose_ssm_cache
from repro.models import build_model
from repro.models.cache import (AttnCache, init_attn_cache,
                                init_row_attn_cache, insert_cache_row,
                                write_kv)


def _rand_tokens(key, b, s, v):
    return jax.random.randint(key, (b, s), 0, v)


def test_dense_prefill_decode_equivalence(rng_key):
    cfg = get_config("granite-8b").reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = _rand_tokens(rng_key, 2, 12, cfg.vocab_size)
    full, _, _ = model.forward(params, {"tokens": toks})
    _, (k, v) = model.prefill(params, {"tokens": toks[:, :8]})
    cache = model.init_cache(2, 16)
    kb, vb, sp, ln = write_kv(cache.k, cache.v, cache.slot_pos, cache.length,
                              k, v)
    cache = AttnCache(k=kb, v=vb, slot_pos=sp, length=ln)
    for t in range(8, 12):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_subprefill_multi_token_equivalence(rng_key):
    """decode_step with Sq>1 (the MatKV query sub-prefill) == token-by-token."""
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = _rand_tokens(rng_key, 1, 10, cfg.vocab_size)
    _, (k, v) = model.prefill(params, {"tokens": toks[:, :4]})
    def fresh():
        c = model.init_cache(1, 16)
        kb, vb, sp, ln = write_kv(c.k, c.v, c.slot_pos, c.length, k, v)
        return AttnCache(k=kb, v=vb, slot_pos=sp, length=ln)
    lg_bulk, _ = model.decode_step(params, fresh(), toks[:, 4:10])
    cache = fresh()
    for t in range(4, 10):
        lg_one, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg_bulk[:, t - 4], np.float32),
                                   np.asarray(lg_one[:, 0], np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer_decode(rng_key):
    """Windowed arch: ring-buffer decode == full forward with window mask."""
    # float32: the ring buffer permutes slot order, which changes the bf16
    # contraction order and wobbles logits by 1-2 ulp; the *semantic*
    # equivalence we assert here is exact in f32.
    cfg = get_config("smollm-135m").reduced(
        sliding_window=8, param_dtype="float32", activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(rng_key)
    s_total = 20
    toks = _rand_tokens(rng_key, 1, s_total, cfg.vocab_size)
    full, _, _ = model.forward(params, {"tokens": toks})
    cache = model.init_cache(1, 64)   # buffer capped to window=8
    assert cache.buf_size == 8
    errs = []
    for t in range(s_total):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        errs.append(float(jnp.max(jnp.abs(
            lg[:, 0].astype(jnp.float32) - full[:, t].astype(jnp.float32)))))
    assert max(errs) < 2e-3, errs


def test_ssm_state_prefix_reuse(rng_key):
    cfg = get_config("falcon-mamba-7b").reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = _rand_tokens(rng_key, 1, 16, cfg.vocab_size)
    full, _, _ = model.forward(params, {"tokens": toks})
    _, art = model.prefill(params, {"tokens": toks[:, :10]})
    cache = compose_ssm_cache(cfg, art, 10)
    for t in range(10, 16):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_hybrid_prefix_reuse(rng_key):
    cfg = get_config("recurrentgemma-2b").reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = _rand_tokens(rng_key, 1, 16, cfg.vocab_size)
    full, _, _ = model.forward(params, {"tokens": toks})
    _, art = model.prefill(params, {"tokens": toks[:, :10]})
    cache = compose_hybrid_cache(cfg, art, 10, buf_size=64)
    for t in range(10, 16):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(full[:, t], np.float32),
                                   rtol=3e-3, atol=3e-3)


def test_whisper_cross_kv_decode(rng_key):
    cfg = get_config("whisper-tiny").reduced()
    model = build_model(cfg)
    params = model.init(rng_key, enc_len=24, dec_len=32)
    frames = jax.random.normal(rng_key, (1, 24, cfg.d_model))
    toks = _rand_tokens(rng_key, 1, 8, cfg.vocab_size)
    # teacher-forced full decode
    logits_full, _, _ = model.forward(params, {"frontend": frames,
                                               "tokens": toks})
    # materialized cross-KV + incremental decode
    _, (ck, cv) = model.prefill(params, {"frontend": frames})
    cache = model.init_cache(1, 32, enc_len=24)
    cache = dataclasses.replace(cache, cross_k=ck, cross_v=cv)
    for t in range(8):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(lg[:, 0], np.float32),
                                   np.asarray(logits_full[:, t], np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_decode_paths_equivalent(rng_key, monkeypatch):
    """The optimized write-then-attend decode (default) and the
    paper-baseline concat-then-attend lowering (REPRO_DECODE_CONCAT=1) are
    the same math — logits must agree to f32 roundoff, single- and
    multi-token (sub-prefill) alike."""
    cfg = get_config("smollm-135m").reduced(
        param_dtype="float32", activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = _rand_tokens(rng_key, 2, 12, cfg.vocab_size)
    _, (k, v) = model.prefill(params, {"tokens": toks[:, :6]})

    def fresh():
        c = model.init_cache(2, 24)
        kb, vb, sp, ln = write_kv(c.k, c.v, c.slot_pos, c.length, k, v)
        return AttnCache(k=kb, v=vb, slot_pos=sp, length=ln)

    for sq in (1, 4):                       # decode and sub-prefill widths
        step = toks[:, 6:6 + sq]
        monkeypatch.delenv("REPRO_DECODE_CONCAT", raising=False)
        lg_new, c_new = model.decode_step(params, fresh(), step)
        monkeypatch.setenv("REPRO_DECODE_CONCAT", "1")
        lg_old, c_old = model.decode_step(params, fresh(), step)
        np.testing.assert_allclose(np.asarray(lg_new, np.float32),
                                   np.asarray(lg_old, np.float32),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c_new.slot_pos),
                                      np.asarray(c_old.slot_pos))
        assert int(c_new.length) == int(c_old.length)


def test_write_kv_wraps_ring(rng_key):
    cache = init_attn_cache(get_config("smollm-135m").reduced(), 1, 4)
    l, b, _, kvh, hd = cache.k.shape
    k_new = jnp.ones((l, b, 1, kvh, hd))
    base = cache
    k, v, sp, ln = base.k, base.v, base.slot_pos, base.length
    for t in range(6):
        k, v, sp, ln = write_kv(k, v, sp, ln, k_new * (t + 1), k_new, None)
    # after 6 writes into a 4-slot ring, slots hold tokens [4,5,2,3]
    np.testing.assert_array_equal(np.asarray(sp), [4, 5, 2, 3])
    assert int(ln) == 6
    assert float(k[0, 0, 1, 0, 0]) == 6.0  # token 5 written at slot 1


def test_row_cache_staggered_decode_matches_per_row(rng_key):
    """Rows of a RowAttnCache at staggered lengths decode identically to the
    same rows run alone at batch=1 (the per-row write/mask contract)."""
    cfg = get_config("smollm-135m").reduced()
    model = build_model(cfg)
    params = model.init(rng_key)
    toks = _rand_tokens(rng_key, 2, 8, cfg.vocab_size)
    big = init_row_attn_cache(cfg, 2, 12)
    # stagger: row 0 prefills 5 tokens, row 1 prefills 2
    rows = []
    for r, n in enumerate((5, 2)):
        row = init_row_attn_cache(cfg, 1, 12)
        _, row = model.decode_step_rows(params, row, toks[r:r + 1, :n])
        big = insert_cache_row(big, r, row)
        rows.append(row)
    for t in range(3):
        step = jnp.stack([toks[0, 5 + t], toks[1, 2 + t]])[:, None]
        lg, big = model.decode_step_rows(params, big, step)
        for r in range(2):
            lr, rows[r] = model.decode_step_rows(params, rows[r],
                                                 step[r:r + 1])
            np.testing.assert_allclose(np.asarray(lg[r], np.float32),
                                       np.asarray(lr[0], np.float32),
                                       rtol=2e-3, atol=2e-3)
    np.testing.assert_array_equal(np.asarray(big.length), [8, 5])


def test_insert_cache_row_replaces_one_row(rng_key):
    cfg = get_config("smollm-135m").reduced()
    big = init_row_attn_cache(cfg, 2, 4)
    row = init_row_attn_cache(cfg, 1, 4)
    row = dataclasses.replace(
        row, k=row.k + 7.0, slot_pos=row.slot_pos.at[0, :2].set(
            jnp.arange(2, dtype=jnp.int32)),
        length=jnp.asarray([2], jnp.int32))
    out = insert_cache_row(big, 1, row)
    assert float(out.k[0, 0, 0, 0, 0]) == 0.0           # row 0 untouched
    assert float(out.k[0, 1, 0, 0, 0]) == 7.0
    np.testing.assert_array_equal(np.asarray(out.length), [0, 2])
    np.testing.assert_array_equal(np.asarray(out.slot_pos[1, :3]), [0, 1, -1])
    with pytest.raises(ValueError):
        insert_cache_row(big, 0, init_row_attn_cache(cfg, 1, 8))
