"""MatKV core invariants: materialize -> store -> load -> compose -> decode."""

import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import (Materializer, chunk_document, compose_attn_cache,
                        load_artifact)
from repro.core.blend import blend, hkvd_select
from repro.core.chunking import chunk_id_for
from repro.core.quantize import dequantize_kv, quantization_error, quantize_kv
from repro.kvstore import FlashKVStore
from repro.models import build_model


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    return cfg, model, params


def test_chunking_dedupes_and_hashes():
    toks = np.arange(100, dtype=np.int32)
    chunks = chunk_document("d", toks, chunk_tokens=32)
    assert [len(c) for c in chunks] == [32, 32, 32, 4]
    assert chunks[0].chunk_id == chunk_id_for(toks[:32])
    assert chunks[0].chunk_id != chunks[1].chunk_id


def test_materialize_store_load_roundtrip(dense_setup):
    cfg, model, params = dense_setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        mat = Materializer(model, params, store)
        chunk = chunk_document("doc", np.arange(40) % 300, chunk_tokens=64)[0]
        nbytes = mat.ingest(chunk)
        assert store.exists(chunk.chunk_id)
        assert store.size_bytes(chunk.chunk_id) == nbytes
        art, meta = load_artifact(cfg, store.get(chunk.chunk_id))
        k, v = art
        assert k.shape == (cfg.num_layers, 1, 40, cfg.num_kv_heads,
                           cfg.head_dim)
        assert meta["n_tokens"] == 40
        # artifact equals direct prefill output
        _, (k2, v2) = model.prefill(
            params, {"tokens": jnp.asarray(chunk.tokens)[None]})
        np.testing.assert_allclose(np.asarray(k, np.float32),
                                   np.asarray(k2, np.float32),
                                   rtol=1e-2, atol=1e-2)


def test_compose_equals_vanilla_single_doc(dense_setup):
    """THE core invariant: one doc composed from the store == full prefill."""
    cfg, model, params = dense_setup
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 300, 48))[None]
    logits_full, (k, v), = model.prefill(params, {"tokens": toks})
    cache = compose_attn_cache(cfg, [(k, v)], buf_size=64)
    assert int(cache.length) == 48
    # decode the next token both ways
    nxt = jnp.asarray([[5]], jnp.int32)
    lg_m, _ = model.decode_step(params, cache, nxt)
    # vanilla: forward over 49 tokens
    lg_full, _, _ = model.forward(
        params, {"tokens": jnp.concatenate([toks, nxt], axis=1)})
    np.testing.assert_allclose(np.asarray(lg_m[:, 0], np.float32),
                               np.asarray(lg_full[:, -1], np.float32),
                               rtol=1e-3, atol=1e-3)


def test_compose_multi_doc_restart_positions(dense_setup):
    """Paper-faithful mode: doc KVs keep per-chunk positions; slots are global;
    docs must NOT attend to each other (their KVs are frozen)."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(1)
    d1 = jnp.asarray(rng.integers(0, 300, 32))[None]
    d2 = jnp.asarray(rng.integers(0, 300, 32))[None]
    _, a1 = model.prefill(params, {"tokens": d1})
    _, a2 = model.prefill(params, {"tokens": d2})
    cache = compose_attn_cache(cfg, [a1, a2], buf_size=96)
    assert int(cache.length) == 64
    # swapping doc order changes only slot order, not each doc's stored KV
    cache_swapped = compose_attn_cache(cfg, [a2, a1], buf_size=96)
    np.testing.assert_allclose(
        np.asarray(cache.k[:, :, :32], np.float32),
        np.asarray(cache_swapped.k[:, :, 32:64], np.float32), atol=1e-6)


def test_compose_rerotate_matches_global_positions(dense_setup):
    """Re-rotated compose == KVs as if the chunk had been prefilled at its
    global offset (RoPE rotation composition)."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, 300, 32))[None]
    _, art = model.prefill(params, {"tokens": toks})
    cache = compose_attn_cache(cfg, [art, art], buf_size=64, rerotate=True)
    # chunk 2's keys should equal prefill at positions 32..63
    _, art_off = model.prefill(params, {"tokens": toks},
                               positions=jnp.arange(32, 64))
    np.testing.assert_allclose(np.asarray(cache.k[:, :, 32:64], np.float32),
                               np.asarray(art_off[0], np.float32),
                               rtol=3e-2, atol=3e-2)


def test_quantize_roundtrip_error_small(rng_key):
    x = jax.random.normal(rng_key, (4, 64, 2, 32))
    assert quantization_error(x) < 0.01
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float16
    back = dequantize_kv(q, s, jnp.float32)
    assert float(jnp.max(jnp.abs(back - x))) < 0.05


def test_quantized_artifact_roundtrip(dense_setup):
    cfg, model, params = dense_setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        mat_q = Materializer(model, params, store, codec="int8")
        chunk = chunk_document("doc", np.arange(32) % 300, chunk_tokens=32)[0]
        n_q = mat_q.ingest(chunk)
        art_q, meta = load_artifact(cfg, store.get(chunk.chunk_id))
        assert meta["codec"] == "int8"
        _, (k_true, _) = model.prefill(
            params, {"tokens": jnp.asarray(chunk.tokens)[None]})
        rel = (jnp.linalg.norm(art_q[0].astype(jnp.float32)
                               - k_true.astype(jnp.float32))
               / jnp.linalg.norm(k_true.astype(jnp.float32)))
        assert float(rel) < 0.05
        # storage saving vs bf16
        mat_f = Materializer(model, params, store, codec="bf16")
        chunk2 = dataclasses.replace(chunk, chunk_id="other")
        n_f = mat_f.ingest(chunk2)
        assert n_q < 0.65 * n_f


def test_cacheblend_blends_toward_vanilla(dense_setup):
    """Blending with ratio=1.0 must exactly reproduce vanilla full-attention KV."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(3)
    d1 = jnp.asarray(rng.integers(0, 300, 24))[None]
    d2 = jnp.asarray(rng.integers(0, 300, 24))[None]
    _, a1 = model.prefill(params, {"tokens": d1})
    _, a2 = model.prefill(params, {"tokens": d2})
    cache = compose_attn_cache(cfg, [a1, a2], buf_size=48)
    full = jnp.concatenate([d1, d2], axis=1)
    blended, sel = blend(cfg, params, full, cache, ratio=1.0)
    assert sel.shape == (48,)
    _, (k_true, v_true) = model.prefill(params, {"tokens": full})
    np.testing.assert_allclose(np.asarray(blended.k[:, :, :48], np.float32),
                               np.asarray(k_true, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_hkvd_selects_cross_chunk_tokens(dense_setup):
    """Tokens in chunk 2 (whose cached KV lacks cross-chunk context) should
    dominate the HKVD selection over chunk-1 tokens (which are exact)."""
    cfg, model, params = dense_setup
    rng = np.random.default_rng(4)
    d1 = jnp.asarray(rng.integers(0, 300, 24))[None]
    d2 = jnp.asarray(rng.integers(0, 300, 24))[None]
    _, a1 = model.prefill(params, {"tokens": d1})
    _, a2 = model.prefill(params, {"tokens": d2})
    cache = compose_attn_cache(cfg, [a1, a2], buf_size=48)
    sel = hkvd_select(cfg, params, jnp.concatenate([d1, d2], axis=1), cache,
                      ratio=0.25)
    frac_chunk2 = float(np.mean(np.asarray(sel) >= 24))
    assert frac_chunk2 >= 0.5
