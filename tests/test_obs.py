"""Observability plane (DESIGN.md §15): tracer invariants, registry
semantics, Chrome export validity, metrics round-trip, and the instrumented
scheduler's per-request phase accounting."""

import json
import tempfile
import threading

import jax
import pytest

from repro.configs import get_config
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.obs import (Counter, MetricsRegistry, NULL_TRACER, Tracer,
                       arg_values, load_chrome, merge_chrome, validate_chrome)
from repro.obs.trace import _NULL_SPAN
from repro.serving import ContinuousScheduler, RagEngine
from repro.serving.metrics import METRICS_SCHEMA, ServeMetrics

# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------


def test_spans_nest_and_time_with_injectable_clock():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = Tracer(role="test", clock=clock)
    with tr.span("outer", req=1):
        with tr.span("inner", chunk="c0"):
            tr.instant("tick")
    spans = list(tr.spans())
    # inner closes first (stack replay yields in close order)
    assert [s[0] for s in spans] == ["inner", "outer"]
    by_name = {s[0]: s for s in spans}
    # deterministic clock: outer B=1, inner B=2, tick=3, inner E=4, outer E=5
    assert by_name["inner"][2] == 2.0 and by_name["outer"][2] == 4.0
    assert by_name["inner"][4] == {"chunk": "c0"}
    assert tr.totals()["outer"] == (1, 4.0)


def test_unbalanced_spans_raise():
    tr = Tracer()
    tr._record("B", "a", None)
    tr._record("E", "b", None)
    with pytest.raises(ValueError, match="unbalanced"):
        list(tr.spans())
    tr.clear()
    tr._record("B", "a", None)
    with pytest.raises(ValueError, match="unclosed"):
        list(tr.spans())


def test_threads_get_independent_span_stacks():
    tr = Tracer()
    barrier = threading.Barrier(8)     # all threads alive at once, so their
                                       # idents are distinct and interleave

    def worker(i):
        with tr.span("outer", req=i):
            barrier.wait()
            with tr.span("inner", req=i):
                pass

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    spans = list(tr.spans())           # must not raise despite interleaving
    assert len(spans) == 16
    assert len({s[3] for s in spans}) == 8   # eight distinct thread lanes


def test_disabled_tracer_allocates_nothing():
    tr = Tracer(enabled=False)
    s1 = tr.span("a", req=1)
    s2 = tr.span("b")
    # the disabled fast path returns one shared module-level singleton
    assert s1 is s2 is _NULL_SPAN
    with s1:
        tr.instant("x")
    assert tr.events == []
    assert NULL_TRACER.events == [] and not NULL_TRACER.enabled


# ---------------------------------------------------------------------------
# chrome export + merge
# ---------------------------------------------------------------------------


def test_chrome_export_is_valid_and_round_trips(tmp_path):
    tr = Tracer(role="decode")
    with tr.span("flash_read", chunk="c1"):
        tr.instant("arrive", req=0)
    path = tmp_path / "t.trace.json"
    doc = tr.to_chrome(path)
    stats = validate_chrome(doc)
    assert stats["spans"] == 1 and stats["events"] == 4  # M + B + i + E
    loaded = load_chrome(path)
    assert validate_chrome(loaded) == stats
    json.dumps(loaded)                  # plain-JSON serializable
    assert arg_values(loaded, "chunk") == {"c1"}
    assert arg_values(loaded, "req") == {0}


def test_validate_chrome_rejects_malformed():
    ok = {"name": "s", "ph": "B", "pid": 1, "tid": 1, "ts": 0.0}
    with pytest.raises(ValueError, match="not a list"):
        validate_chrome({})
    with pytest.raises(ValueError, match="without matching B"):
        validate_chrome({"traceEvents": [dict(ok, ph="E")]})
    with pytest.raises(ValueError, match="unclosed"):
        validate_chrome({"traceEvents": [ok]})
    with pytest.raises(ValueError, match="must nest"):
        validate_chrome({"traceEvents": [
            ok, dict(ok, name="other", ts=1.0),
            dict(ok, ph="E", ts=2.0),
            dict(ok, name="other", ph="E", ts=3.0)]})
    with pytest.raises(ValueError, match="unknown phase"):
        validate_chrome({"traceEvents": [dict(ok, ph="Z")]})


def test_merge_chrome_gives_each_role_a_pid_lane():
    a, b = Tracer(role="materialize"), Tracer(role="decode")
    with a.span("materialize", chunk="c9"):
        pass
    with b.span("flash_read", chunk="c9"):
        pass
    merged = merge_chrome(a.to_chrome_dict(), b.to_chrome_dict())
    validate_chrome(merged)
    assert merged["otherData"]["roles"] == ["materialize", "decode"]
    assert {ev["pid"] for ev in merged["traceEvents"]} == {1, 2}
    assert arg_values(merged, "chunk") == {"c9"}  # the cross-role join key


def test_jsonl_export(tmp_path):
    tr = Tracer(role="serve")
    with tr.span("s"):
        pass
    p = tmp_path / "t.jsonl"
    tr.to_jsonl(p)
    lines = [json.loads(l) for l in p.read_text().splitlines()]
    assert lines[0] == {"schema": 1, "role": "serve"}
    assert [l["ph"] for l in lines[1:]] == ["B", "E"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_counters_are_monotone():
    reg = MetricsRegistry()
    c = reg.counter("serve.requests")
    c.inc(3)
    c.inc(0)
    with pytest.raises(ValueError):
        c.inc(-1)
    assert reg.value("serve.requests") == 3
    assert isinstance(c, Counter)


def test_gauge_tracks_peak_and_hist_quantiles():
    reg = MetricsRegistry()
    g = reg.gauge("pool.hbm_kv_bytes_resident")
    g.set(10)
    g.set(4)
    assert reg.value("pool.hbm_kv_bytes_resident") == 4
    assert reg.peak("pool.hbm_kv_bytes_resident") == 10
    h = reg.hist("request.latency_s")
    for v in [5.0, 1.0, 3.0, 2.0, 4.0]:
        h.observe(v)
    assert h.quantile(0.5) == 3.0 and h.quantile(0.95) == 5.0
    assert reg.hist_values("request.latency_s") == [5.0, 1.0, 3.0, 2.0, 4.0]


def test_registry_rejects_kind_collisions_and_strips_prefixes():
    reg = MetricsRegistry()
    reg.counter("phase.compose_s").inc(2.5)
    reg.counter("phase.prefill_s").inc(1.5)
    with pytest.raises(TypeError):
        reg.gauge("phase.compose_s")
    assert reg.counters_under("phase.") == {"compose_s": 2.5,
                                            "prefill_s": 1.5}
    assert reg.value("never.written") == 0


# ---------------------------------------------------------------------------
# metrics view + round-trip
# ---------------------------------------------------------------------------


def test_servemetrics_dict_round_trip_and_schema_gate():
    m = ServeMetrics(role="decode", wall_s=2.0, n_new_tokens=10,
                     latencies_s=[0.5, 1.0], ttft_s=[0.1, 0.2],
                     phase_s={"compose": 0.3, "prefill": 0.2})
    d = m.as_dict()
    assert d["schema"] == METRICS_SCHEMA
    assert d["derived"]["tokens_per_s"] == pytest.approx(5.0)
    assert d["derived"]["p95_ttft_s"] == pytest.approx(
        m.p95_ttft_s)
    json.dumps(d)                        # results.jsonl-serializable
    back = ServeMetrics.from_dict(json.loads(json.dumps(d)))
    assert back == m
    with pytest.raises(ValueError, match="schema"):
        ServeMetrics.from_dict(dict(d, schema=99))


def test_servemetrics_from_registry_prefill_split():
    """The satellite fix: ``prefill_s`` is compose + prefill COMPUTE only;
    admission bookkeeping and flash-read wait live in ``phase_s``."""
    reg = MetricsRegistry()
    reg.counter("phase.compose_s").inc(0.3)
    reg.counter("phase.prefill_s").inc(0.2)
    reg.counter("phase.load_stall_s").inc(0.4)
    reg.counter("phase.admission_s").inc(0.1)
    reg.counter("serve.requests").inc(2)
    reg.gauge("serve.wall_s").set(1.5)
    m = ServeMetrics.from_registry(reg, role="both")
    assert m.prefill_s == pytest.approx(0.5)
    assert m.phase_s["load_stall"] == pytest.approx(0.4)
    assert m.phase_s["admission"] == pytest.approx(0.1)
    assert m.n_requests == 2 and m.wall_s == 1.5


# ---------------------------------------------------------------------------
# instrumented scheduler end to end
# ---------------------------------------------------------------------------

CORPUS = {
    "d1": "the amber gate stands in hall nine beyond the long stair. " * 4,
    "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
}
QUESTIONS = ["where is the amber gate?", "where is the cedar door?"]


@pytest.fixture(scope="module")
def served():
    """One traced paged run; every invariant test reads off it."""
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    with tempfile.TemporaryDirectory() as d:
        eng = RagEngine(model, params, FlashKVStore(d), chunk_tokens=48,
                        top_k=2)
        for doc, text in CORPUS.items():
            eng.ingest(doc, text)
        qs = [QUESTIONS[i % 2] for i in range(4)]
        tracer = Tracer(role="serve")
        sched = ContinuousScheduler(eng, max_slots=2, paged=True,
                                    tracer=tracer)
        sched.run(qs, max_new_tokens=4)              # warm jit
        tracer.clear()
        ans, m = sched.run(qs, max_new_tokens=4)
        sched.shutdown()
        yield ans, m, sched, tracer


def test_run_metrics_have_ttft_and_phases(served):
    ans, m, sched, _ = served
    assert m.n_requests == 4 and len(m.ttft_s) == 4
    for ttft, lat in zip(sorted(m.ttft_s), sorted(m.latencies_s)):
        assert 0 < ttft <= lat + 1e-6
    assert m.p95_ttft_s >= m.p50_ttft_s > 0
    # the split phases exist and prefill_s means compute only
    for phase in ("admission", "compose", "prefill", "decode_step"):
        assert phase in m.phase_s, sorted(m.phase_s)
    assert m.prefill_s == pytest.approx(
        m.phase_s["compose"] + m.phase_s["prefill"])
    assert m.n_decode_steps > 0 and m.decode_kv_bytes_measured > 0


def test_per_request_phase_sum_approximates_latency(served):
    """Per request, queue wait + load stall + compose + prefill + decode
    share must sum to ≈ the request's latency: nothing a request lived
    through escapes phase attribution (loop bookkeeping between decode
    steps is the only un-attributed slack)."""
    _, m, sched, _ = served
    for r, lat in zip(sched.last_records, m.latencies_s):
        assert r.finish_s is not None
        assert r.phase_sum_s <= lat * 1.05 + 0.02, (
            f"phases over-count: {r.phase_sum_s:.4f}s vs latency {lat:.4f}s")
        assert r.phase_sum_s >= lat * 0.5, (
            f"phases under-count: {r.phase_sum_s:.4f}s vs latency {lat:.4f}s")


def test_trace_covers_lifecycle_and_exports_valid(served, tmp_path):
    _, m, _, tracer = served
    totals = tracer.totals()             # also asserts strict nesting
    for name in ("admit", "compose", "prefill", "decode_step", "flash_read",
                 "pool_insert"):
        assert name in totals, (name, sorted(totals))
    doc = tracer.to_chrome(tmp_path / "serve.trace.json")
    validate_chrome(doc)
    assert arg_values(doc, "req") == {0, 1, 2, 3}
    names = {ev["name"] for ev in doc["traceEvents"]}
    assert {"arrive", "first_token", "finish"} <= names


def test_tracing_does_not_change_answers(served):
    """Spans are pure observers: a traced run's answers match an untraced
    scheduler over the same engine state (fixture ran traced; compare
    against a fresh untraced run)."""
    ans, _, sched, _ = served
    qs = [QUESTIONS[i % 2] for i in range(4)]
    untraced = ContinuousScheduler(sched.engine, max_slots=2, paged=True)
    ans2, _ = untraced.run(qs, max_new_tokens=4)
    untraced.shutdown()
    assert ans == ans2
