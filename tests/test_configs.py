"""Config registry + analytical model accounting."""

import pytest

from repro.configs import (ASSIGNED, REGISTRY, SHAPES, config_for_shape,
                           get_config, get_shape)


def test_all_assigned_present():
    expected = {
        "whisper-tiny", "deepseek-moe-16b", "qwen3-14b", "phi4-mini-3.8b",
        "recurrentgemma-2b", "falcon-mamba-7b", "qwen3-moe-30b-a3b",
        "llava-next-mistral-7b", "smollm-135m", "granite-8b",
    }
    assert expected == set(ASSIGNED)


def test_every_config_cites_source():
    for cfg in REGISTRY.values():
        assert cfg.source, cfg.name


@pytest.mark.parametrize("name,lo,hi", [
    ("deepseek-moe-16b", 15e9, 17.5e9),
    ("qwen3-14b", 13.5e9, 15.5e9),
    ("phi4-mini-3.8b", 3.5e9, 4.2e9),
    ("recurrentgemma-2b", 2.0e9, 2.8e9),
    ("falcon-mamba-7b", 6.8e9, 7.8e9),
    ("qwen3-moe-30b-a3b", 29e9, 32e9),
    ("llava-next-mistral-7b", 7.0e9, 7.6e9),
    ("smollm-135m", 0.12e9, 0.15e9),
    ("granite-8b", 7.6e9, 8.5e9),
    ("whisper-tiny", 0.03e9, 0.08e9),
    ("llama-3.1-70b", 68e9, 72e9),
])
def test_param_counts_match_public_numbers(name, lo, hi):
    n = get_config(name).param_count()
    assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params():
    cfg = get_config("qwen3-moe-30b-a3b")
    active = cfg.active_param_count()
    assert 2.5e9 <= active <= 4e9  # the "A3B" in the name


def test_kv_bytes_per_token_paper_scale():
    # paper: LLaMA-70B, 1024-token chunk -> ~250MB materialized KV (fp16)
    cfg = get_config("llama-3.1-70b")
    mb = cfg.kv_bytes_per_token(2) * 1024 / 1e6
    assert 250 <= mb <= 400  # 8 kv heads x 128 x 2 x 80L x 2B = 335MB

    assert get_config("falcon-mamba-7b").kv_bytes_per_token() == 0


def test_shape_policy():
    # whisper skips long_500k; everyone else runs it (window variant for dense)
    _, ok, reason = config_for_shape("whisper-tiny", "long_500k")
    assert not ok and "448" in reason
    for arch in ASSIGNED:
        if arch == "whisper-tiny":
            continue
        cfg, ok, _ = config_for_shape(arch, "long_500k")
        assert ok, arch
        if cfg.family in ("dense", "moe", "vlm"):
            assert cfg.sliding_window is not None
    # base configs unmodified for other shapes
    cfg, ok, _ = config_for_shape("granite-8b", "decode_32k")
    assert ok and cfg.sliding_window is None


def test_reduced_configs_valid():
    for name in ASSIGNED:
        small = get_config(name).reduced()
        assert small.num_layers <= 3
        assert small.d_model <= 512
        if small.family == "moe":
            assert small.num_experts <= 4
        small.validate()


def test_shapes_registry():
    assert get_shape("train_4k").global_batch == 256
    assert get_shape("long_500k").seq_len == 524_288
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    with pytest.raises(KeyError):
        get_shape("nope")
