"""Blockwise flash attention (jnp) vs naive reference: values + custom VJP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import flash_attention, position_mask

def naive_attention(q, k, v, q_pos, k_pos, window, causal):
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qr = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqcgd,bscd->bcgqs", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    m = position_mask(q_pos, k_pos, window, causal)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bcgqs,bscd->bqcgd", p, v.astype(jnp.float32))
    return o.reshape(b, sq, h, hd).astype(q.dtype)


@pytest.mark.parametrize("sq,sk,h,kv,win", [
    (128, 128, 4, 2, None),
    (64, 64, 8, 1, 16),
    (1, 96, 4, 4, None),     # decode shape
    (24, 152, 6, 2, None),   # subprefill: query over prefix (ragged sizes)
])
def test_matches_naive(rng_key, sq, sk, h, kv, win):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, sq, h, 32))
    k = jax.random.normal(ks[1], (2, sk, kv, 32))
    v = jax.random.normal(ks[2], (2, sk, kv, 32))
    q_pos = jnp.arange(sk - sq, sk, dtype=jnp.int32)
    k_pos = jnp.arange(sk, dtype=jnp.int32)
    out = flash_attention(q, k, v, q_pos, k_pos, win, True)
    ref = naive_attention(q, k, v, q_pos, k_pos, win, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_invalid_slots_masked(rng_key):
    """Slots with pos=-1 (ring-buffer holes / padding) contribute nothing."""
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 4, 2, 16))
    k = jax.random.normal(ks[1], (1, 32, 2, 16))
    v = jax.random.normal(ks[2], (1, 32, 2, 16))
    k_pos = jnp.where(jnp.arange(32) < 16, jnp.arange(32), -1)
    q_pos = jnp.arange(16, 20, dtype=jnp.int32)
    out = flash_attention(q, k, v, q_pos, k_pos, None, True)
    # zeroing the masked-out K/V must not change the result
    k2 = k.at[:, 16:].set(1e3)
    v2 = v.at[:, 16:].set(-1e3)
    out2 = flash_attention(q, k2, v2, q_pos, k_pos, None, True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_custom_vjp_matches_naive_grads(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    pos = jnp.arange(64, dtype=jnp.int32)

    def f_flash(q, k, v):
        return jnp.sum(jnp.sin(flash_attention(q, k, v, pos, pos, None, True)))

    def f_naive(q, k, v):
        return jnp.sum(jnp.sin(naive_attention(q, k, v, pos, pos, None, True)))

    g1 = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_sliding_window_limits_attention(rng_key):
    """With window W, perturbing keys older than W leaves outputs unchanged."""
    ks = jax.random.split(rng_key, 3)
    s, w = 128, 32
    q = jax.random.normal(ks[0], (1, s, 2, 16))
    k = jax.random.normal(ks[1], (1, s, 2, 16))
    v = jax.random.normal(ks[2], (1, s, 2, 16))
    pos = jnp.arange(s, dtype=jnp.int32)
    out = flash_attention(q, k, v, pos, pos, w, True)
    k2 = k.at[:, :s - w].add(100.0)  # only affects queries within w of them
    out2 = flash_attention(q, k2, v, pos, pos, w, True)
    # last query position attends only to (s-w, s] -> unchanged
    np.testing.assert_allclose(np.asarray(out[:, -1]), np.asarray(out2[:, -1]),
                               atol=1e-5)
