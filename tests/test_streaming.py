"""Streaming admission regressions (DESIGN.md §16): block-granular artifact
reads, the pool's stream lifecycle + resident frontier, the host-DRAM
demotion tier, the online-softmax carry's answer parity, and the admit-time
reclaim re-park race in the continuous scheduler.
"""

import tempfile
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.economics import SsdSpec
from repro.core.materialize import load_artifact_encoded
from repro.kvstore import (ArtifactIndex, AsyncKvLoader, FlashKVStore,
                           SimulatedReader, block_payload_bytes,
                           read_block_encoded)
from repro.models import build_model
from repro.obs import Tracer, span_overlap_frac
from repro.paged import PagedKvPool
from repro.serving import ContinuousScheduler, RagEngine
from repro.serving.metrics import ServeMetrics

CORPUS = {
    "d1": "the amber gate stands in hall nine beyond the long stair. " * 4,
    "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
    "d3": "the brass lamp hums beside the tall window all night. " * 4,
}
QUESTIONS = ["where is the amber gate?", "where is the cedar door?",
             "where is the brass lamp?"]


@pytest.fixture(autouse=True)
def _lockdep(lock_order):
    """Run under the lock-order detector (conftest ``lock_order``): any
    acquisition-order cycle observed during the test fails it."""
    yield


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _engine(model, params, store, **kw):
    kw.setdefault("top_k", 2)
    eng = RagEngine(model, params, store, chunk_tokens=48, **kw)
    for d, text in CORPUS.items():
        eng.ingest(d, text)
    return eng


def _np(x):
    return np.asarray(jax.device_get(x))


# ---------------------------------------------------------------------------
# block-granular artifact reads (kvstore/streaming.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_block_reads_match_whole_payload(setup, codec):
    """Every token block read via byte ranges (including the coalesced
    full-axis fast path) must reassemble bit-exactly into the whole-payload
    decode, for both codecs and for ragged final blocks."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv", codec=codec)
        cid = next(iter(eng._chunks))
        whole, _ = load_artifact_encoded(cfg, store.get(cid))
        idx = ArtifactIndex.open(store, cid)
        assert idx.n_tokens == whole.n_tokens
        for block in (16, 48):      # 48 == whole axis: L segments coalesce
            parts = [read_block_encoded(store, idx, t0,
                                        min(t0 + block, idx.n_tokens))
                     for t0 in range(0, idx.n_tokens, block)]
            for name in ("k", "v", "k_scale", "v_scale"):
                ref = getattr(whole, name)
                if ref is None:
                    assert all(getattr(p, name) is None for p in parts)
                    continue
                got = np.concatenate([_np(getattr(p, name))
                                      for p in parts], axis=1)
                assert np.array_equal(got, _np(ref)), (codec, name, block)
        # the degraded path (reader without get_range) must agree too
        class _WholeOnly:
            def get(self, c):
                return store.get(c)
        idx2 = ArtifactIndex.open(_WholeOnly(), cid)
        a = read_block_encoded(_WholeOnly(), idx2, 0, 16)
        b = read_block_encoded(store, idx, 0, 16)
        assert np.array_equal(_np(a.k), _np(b.k))


def test_block_payload_bytes_cover_the_kv_payload(setup):
    """Per-block flash accounting sums to the artifact's full KV payload —
    no byte is double-counted or dropped by the block split."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        cid = next(iter(eng._chunks))
        idx = ArtifactIndex.open(store, cid)
        kn, vn = idx.kv_names()
        kv_total = sum(e.nbytes for n, e in idx.tensors.items()
                       if n.split(".")[0] in (kn, vn))
        for block in (16, 17, 48):
            got = sum(block_payload_bytes(idx, t0,
                                          min(t0 + block, idx.n_tokens))
                      for t0 in range(0, idx.n_tokens, block))
            assert got == kv_total, block


def test_chunk_stream_delivers_ordered_blocks(setup):
    """``load_stream`` pushes every token block in file order and the
    drained blocks reassemble into the whole payload."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        cid = next(iter(eng._chunks))
        whole, _ = load_artifact_encoded(cfg, store.get(cid))
        loader = AsyncKvLoader(store, n_workers=2)
        try:
            stream = loader.load_stream(cid, block_tokens=16)
            deadline = time.time() + 30
            while not stream.done and time.time() < deadline:
                time.sleep(0.005)
            assert stream.done and stream.error is None
            blocks, _ = stream.drain_from(0)
        finally:
            loader.shutdown()
        assert stream.n_tokens == whole.n_tokens
        assert [b[0] for b in blocks] == list(range(0, whole.n_tokens, 16))
        got = np.concatenate([_np(b[2].k) for b in blocks], axis=1)
        assert np.array_equal(got, _np(whole.k))
        assert stream.total_bytes == sum(b[3] for b in blocks) > 0


# ---------------------------------------------------------------------------
# pool stream lifecycle + resident frontier (paged/pool.py)
# ---------------------------------------------------------------------------

def _encoded_chunk(setup, store_dir):
    cfg, model, params = setup
    store = FlashKVStore(store_dir)
    eng = _engine(model, params, store, mode="matkv")
    cid = next(iter(eng._chunks))
    enc, _ = load_artifact_encoded(cfg, store.get(cid))
    return cfg, cid, enc


def _slice_enc(enc, t0, t1):
    codec = enc.codec

    def cut(x):
        return None if x is None else x[:, t0:t1]
    from repro.core.quantize import EncodedKV
    return EncodedKV(codec, cut(enc.k), cut(enc.v), cut(enc.k_scale),
                     cut(enc.v_scale), t1 - t0)


def test_pool_stream_lifecycle_and_frontier(setup):
    """begin → extend (strictly in order) → commit: the entry is invisible
    until commit, the frontier tracks arrivals, out-of-order blocks are
    rejected, and the committed pages equal an all-at-once insert."""
    with tempfile.TemporaryDirectory() as d:
        cfg, cid, enc = _encoded_chunk(setup, d)
        n = enc.n_tokens
        pool = PagedKvPool(cfg, n_blocks=8, block_size=16)
        ref = PagedKvPool(cfg, n_blocks=8, block_size=16)
        ref.insert(cid, encoded=enc)
        pool.begin_stream(cid, n)
        assert not pool.has(cid)
        assert pool.stream_frontier(cid) == 0
        assert pool.chunk_tokens(cid) == n
        with pytest.raises(ValueError):
            pool.extend_stream(cid, _slice_enc(enc, 16, 32), 16, 32)
        for t0 in range(0, n, 16):
            t1 = min(t0 + 16, n)
            front = pool.extend_stream(cid, _slice_enc(enc, t0, t1), t0, t1)
            assert front == t1 == pool.stream_frontier(cid)
            assert not pool.has(cid)
        assert pool.commit_stream(cid) == n
        assert pool.has(cid) and pool.stream_frontier(cid) is None
        ids = pool.token_slot_ids(pool._entries[cid].block_ids, n)
        ref_ids = ref.token_slot_ids(ref._entries[cid].block_ids, n)
        assert np.array_equal(_np(pool.k[:, ids]), _np(ref.k[:, ref_ids]))
        assert np.array_equal(_np(pool.v[:, ids]), _np(ref.v[:, ref_ids]))


def test_stream_reservation_is_not_reclaimable(setup):
    """An in-flight stream's pages can never be recycled by a racing
    allocation: the pool exhausts instead, and abort frees them."""
    with tempfile.TemporaryDirectory() as d:
        cfg, cid, enc = _encoded_chunk(setup, d)
        blocks = -(-enc.n_tokens // 16)
        pool = PagedKvPool(cfg, n_blocks=blocks + 1, block_size=16)
        pool.begin_stream(cid, enc.n_tokens)
        with pytest.raises(RuntimeError, match="exhausted"):
            pool.insert("other", encoded=enc)
        pool.abort_stream(cid)
        assert pool.stream_frontier(cid) is None
        pool.insert("other", encoded=enc)      # pages are free again
        assert pool.has("other")


def test_host_tier_demote_promote_roundtrip(setup):
    """Reclaimed refs-0 pages demote into host bytes; promotion rehydrates
    the identical KV with zero flash involvement."""
    with tempfile.TemporaryDirectory() as d:
        cfg, cid, enc = _encoded_chunk(setup, d)
        blocks = -(-enc.n_tokens // 16)
        pool = PagedKvPool(cfg, n_blocks=blocks + 1, block_size=16,
                           host_tier=32 * 2**20)
        ref = PagedKvPool(cfg, n_blocks=2 * blocks, block_size=16)
        ref.insert(cid, encoded=enc)
        pool.insert(cid, encoded=enc)
        pool.release(cid)                       # refs-0, reclaimable
        pool.insert("other", encoded=enc)       # forces the reclaim
        assert not pool.has(cid)
        assert pool.stats.demotions == 1 and pool.host_has(cid)
        pool.release("other")                   # refs-0 so the eager drop
        assert pool.drop_if_unreferenced("other")   # frees without demoting
        assert pool.promote(cid) == enc.n_tokens
        assert pool.stats.promotions == 1 and pool.has(cid)
        ids = pool.token_slot_ids(pool._entries[cid].block_ids, enc.n_tokens)
        ref_ids = ref.token_slot_ids(ref._entries[cid].block_ids,
                                     enc.n_tokens)
        assert np.array_equal(_np(pool.k[:, ids]), _np(ref.k[:, ref_ids]))
        assert np.array_equal(_np(pool.v[:, ids]), _np(ref.v[:, ref_ids]))
        assert pool.promote("never-seen") is None


# ---------------------------------------------------------------------------
# scheduler: streamed answers, re-park race, metadata fallback
# ---------------------------------------------------------------------------

def test_streamed_answers_match_all_at_once(setup):
    """The online-softmax carry fold admits incrementally but the first
    token (and everything after) is identical to all-at-once admission."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        base = ContinuousScheduler(eng, max_slots=2, paged=True,
                                   block_size=16)
        a0, _ = base.run(QUESTIONS, max_new_tokens=5)
        base.shutdown()
        sched = ContinuousScheduler(eng, max_slots=2, paged=True,
                                    block_size=16, streaming=True)
        a1, _ = sched.run(QUESTIONS, max_new_tokens=5)
        n_streamed = int(sched.last_registry.value("serve.streamed_admits"))
        sched.shutdown()
        assert a1 == a0
        assert n_streamed >= 1


def test_admit_time_reclaim_reparks_instead_of_composing(setup):
    """Regression for the ready()/admit race: pages reclaimed after the
    readiness check re-issue their loads and the request re-parks — it must
    never compose over freed blocks."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        ref = eng.answer(QUESTIONS[0], max_new_tokens=5)[0]
        dropped = []

        def drop_once(r):
            # between ready() and admit: evict the request's refs-0 pages,
            # exactly what a racing allocation's reclaim does. Request 1
            # loads cold (expected empty — nothing to drop); request 2
            # expects request 1's now refs-0 resident pages.
            if dropped:
                return
            pool = sched.last_pool
            for c in list(r.expected):
                if pool.drop_if_unreferenced(eng.page_key(c)):
                    dropped.append(c)

        sched = ContinuousScheduler(eng, max_slots=1, paged=True,
                                    block_size=16,
                                    pre_admit_hook=drop_once)
        ans, _ = sched.run([QUESTIONS[0], QUESTIONS[0]], max_new_tokens=5)
        reparks = int(sched.last_registry.value("serve.reparks"))
        sched.shutdown()
        assert dropped, "hook never found a reclaimable page: test is inert"
        assert reparks >= 1
        assert ans == [ref, ref]


def test_engine_chunk_n_tokens_metadata(setup):
    """The retrieval-index token counts let the streaming scheduler seed a
    request's carry before any artifact header arrives."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        cid = next(iter(eng._chunks))
        idx = ArtifactIndex.open(store, cid)
        assert eng.chunk_n_tokens(cid) == idx.n_tokens
        assert eng.chunk_n_tokens("no-such-chunk") is None


# ---------------------------------------------------------------------------
# link simulator + overlap metric plumbing
# ---------------------------------------------------------------------------

def test_shared_link_reservation_backdates_to_call_entry():
    """The shared link pipelines the backing-store read into the byte-time
    reservation: a slow backing read costs max(read, link), not their sum —
    otherwise block-granular readers pay a per-call tax."""
    class _SlowStore:
        def get_range(self, cid, off, length):
            time.sleep(0.05)
            return b"\0" * length
        def get(self, cid):
            return self.get_range(cid, 0, 1000)
    nbytes, target = 1000, 0.1
    spec = SsdSpec("test", 0.1, nbytes / target / 1e9, 1.0)
    r = SimulatedReader(_SlowStore(), spec, shared_link=True)
    t0 = time.perf_counter()
    r.get_range("c", 0, nbytes)
    elapsed = time.perf_counter() - t0
    assert elapsed < 0.135, (
        f"one read took {elapsed:.3f}s: the 0.05s backing read was charged "
        f"on top of the 0.1s link reservation instead of pipelined into it")
    assert r.records[-1].simulated_s == pytest.approx(target, rel=0.5)


def test_span_overlap_frac_deterministic():
    """Unit check of the load-hidden-behind-decode join on a synthetic
    timeline (injectable tracer clock)."""
    ticks = iter([0.0, 4.0,            # flash_read: [0, 4)
                  1.0, 2.0,            # decode_step: [1, 2)
                  2.5, 3.5])           # decode_step: [2.5, 3.5)
    tr = Tracer(clock=lambda: next(ticks))
    with tr.span("flash_read"):
        pass
    with tr.span("decode_step"):
        pass
    with tr.span("decode_step"):
        pass
    assert span_overlap_frac(tr, "flash_read", "decode_step") == \
        pytest.approx(0.5)
    assert span_overlap_frac(tr, "flash_read", "missing") == 0.0


def test_serve_metrics_roundtrip_carries_streaming_fields():
    """as_dict/from_dict round-trips the streaming-era fields the serving
    benches emit into results.jsonl."""
    m = ServeMetrics(n_requests=2, flash_read_s=[0.01, 0.02],
                     load_overlap_frac=0.25)
    d = m.as_dict()
    back = ServeMetrics.from_dict(d)
    assert back.flash_read_s == [0.01, 0.02]
    assert back.load_overlap_frac == 0.25
    assert back.n_requests == 2
