"""Mesh-sharded paged serving (DESIGN.md §12): tensor-parallel decode over
the block pool, validated on a forced-8-host-device CPU platform.

The multi-device half runs in a subprocess (test_dist.py pattern) so the
main test process keeps its single real device. Three acceptance bars:

* a 1-device mesh must be BIT-IDENTICAL to the plain single-device paged
  path — the dist threading adds sharding constraints, never math;
* an 8-device mesh must pass the shared teacher-forced logits bound of
  ``serving/parity.py`` against the single-device dense path;
* the pool's per-shard accounting must sum to the single-device totals
  (ground truth read off the device buffers).
"""

import json
import subprocess
import sys
import textwrap

from jax.sharding import PartitionSpec as P

from repro.dist.sharding import SERVING_RULES, spec_for


class FakeMesh:
    shape = {"model": 8}


def test_serving_rules_shard_kv_heads_not_sequence():
    """Serving rules: the KV-head axis of a (L, S_buf, KV, hd) pool block
    tensor lands on the model axis; the cache sequence axis — sequence-
    sharded under the default train/prefill rules — stays whole."""
    names = (None, None, "kv_heads", None)
    assert spec_for(FakeMesh, (2, 1024, 8, 16), names,
                    SERVING_RULES) == P(None, None, "model", None)
    # default rules would have sharded cache_seq; serving turns it off
    assert spec_for(FakeMesh, (2, 1024, 8, 16),
                    (None, "cache_seq", "kv_heads", None),
                    SERVING_RULES) == P(None, None, "model", None)
    # indivisible head counts degrade to replication, never an error
    assert spec_for(FakeMesh, (2, 1024, 3, 16), names,
                    SERVING_RULES) == P(None, None, None, None)


def test_row_cache_specs_cover_row_slotted_fields():
    """cache_specs resolves RowAttnCache's rank-2 slot_pos / rank-1 length
    (the row-slotted variants) without error, KV-head-sharded k/v."""
    import jax
    from repro.configs import get_config
    from repro.dist.partition import cache_specs
    from repro.models import build_model

    cfg = get_config("smollm-135m").reduced(
        vocab_size=320, num_heads=8, num_kv_heads=8, head_dim=16, d_model=128)
    cache = jax.eval_shape(
        lambda: build_model(cfg).init_row_cache(2, 64))
    specs = cache_specs(FakeMesh, cache, SERVING_RULES)
    assert specs.k == P(None, None, None, "model", None)
    assert specs.slot_pos == P(None, None)
    assert specs.length == P(None)


def test_engine_without_mesh_is_untouched(tmp_path):
    """mesh=None must leave the engine exactly on the single-device path:
    no rules, no param movement (the object identity is preserved)."""
    import jax
    from repro.configs import get_config
    from repro.kvstore import FlashKVStore
    from repro.models import build_model
    from repro.serving import RagEngine

    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = RagEngine(model, params, FlashKVStore(tmp_path), mode="matkv")
    assert eng.mesh is None and eng.rules is None
    assert eng.params is params


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")
    import json
    import tempfile

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.kernels.paged_decode import tp_parity_probe
    from repro.kernels.paged_decode_fused import fused_tp_parity_probe
    from repro.kvstore import FlashKVStore
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model
    from repro.serving import (ContinuousScheduler, RagEngine,
                               dense_row_path, paged_row_path,
                               teacher_forced_rel)

    assert len(jax.devices()) == 8
    cfg = get_config("smollm-135m").reduced(
        vocab_size=320, num_heads=8, num_kv_heads=8, head_dim=16,
        d_model=128, d_ff=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    CORPUS = {
        "d1": "the amber gate stands in hall nine beyond the stair. " * 4,
        "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
        "d3": "the brass lamp hums beside the tall window all night. " * 4,
    }
    QS = ["where is the amber gate?", "where is the cedar door?",
          "where is the brass lamp?", "where is the amber gate?"]
    out = {}

    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng0 = RagEngine(model, params, store, mode="matkv",
                         chunk_tokens=48, top_k=2)
        for doc, text in CORPUS.items():
            eng0.ingest(doc, text)
        refs = [eng0.answer(q, max_new_tokens=5)[0] for q in QS]

        def mesh_engine(n):
            eng = RagEngine(model, params, store, mode="matkv",
                            chunk_tokens=48, top_k=2,
                            mesh=make_serving_mesh(n))
            eng._chunks, eng.vdb = eng0._chunks, eng0.vdb
            return eng

        def serve(eng):
            sched = ContinuousScheduler(eng, max_slots=2, paged=True,
                                        block_size=32)
            answers, m = sched.run(QS, max_new_tokens=5)
            sched.shutdown()
            return answers, m

        # single-device paged reference (also the shard-sum baseline)
        ans0, m0 = serve(eng0)
        out["paged_single_matches_answer"] = ans0 == refs

        # 1-device mesh: bit parity with the single-device path
        ans1, m1 = serve(mesh_engine(1))
        out["mesh1_bit_parity"] = ans1 == refs

        # 8-device mesh: serves, and per-shard pool bytes sum to the
        # single-device footprint
        eng8 = mesh_engine(8)
        ans8, m8 = serve(eng8)
        out["mesh8_serves_all"] = (len(ans8) == len(QS)
                                   and all(isinstance(a, str) for a in ans8))
        out["mesh8_n_shards"] = len(m8.pool_shard_bytes)
        out["mesh8_shard_sum_matches"] = (
            sum(m8.pool_shard_bytes) == sum(m0.pool_shard_bytes))
        pc8 = eng8.init_paged_cache(2, 192, block_size=32)
        pool = pc8.pool
        out["pool_n_kv_shards"] = pool.n_kv_shards
        out["pool_pinned_shards_sum"] = (
            pool.pinned_bytes_per_shard * pool.n_kv_shards
            == pool.pinned_bytes)

        # 8-device teacher-forced logits parity vs single-device dense
        rel = teacher_forced_rel(eng0, dense_row_path(eng0, 192),
                                 eng8, paged_row_path(eng8, 192),
                                 QS[0], steps=4)
        out["teacher_forced_rel"] = rel

        # shard_map kernel bit parity (one probe shared with the benchmark)
        out["kernel_bit_parity"] = tp_parity_probe(make_serving_mesh(8))

        # fused single-launch decode under the 8-way mesh: the serves above
        # ran it (scheduler default) — pin three-phase on the same engine
        # and require identical answers, plus the fused shard_map twin's
        # bit-parity probe
        sched3p = ContinuousScheduler(eng8, max_slots=2, paged=True,
                                      block_size=32, fused=False)
        ans8_3p, _ = sched3p.run(QS, max_new_tokens=5)
        sched3p.shutdown()
        out["mesh8_fused_matches_three_phase"] = ans8_3p == ans8
        out["fused_kernel_bit_parity"] = fused_tp_parity_probe(
            make_serving_mesh(8))

    print(json.dumps(out))
""")


def test_mesh_sharded_paged_serving_8_host_devices():
    proc = subprocess.run(
        [sys.executable, "-c", SUBPROC],
        capture_output=True, text=True, timeout=900,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=__import__("pathlib").Path(__file__).resolve().parent.parent)
    assert proc.returncode == 0, proc.stderr[-4000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["paged_single_matches_answer"]
    assert out["mesh1_bit_parity"], (
        "1-device-mesh paged answers must be bit-identical to the plain "
        "single-device path")
    assert out["mesh8_serves_all"]
    assert out["mesh8_n_shards"] == 8
    assert out["mesh8_shard_sum_matches"], (
        "per-shard pool bytes must sum to the single-device footprint")
    assert out["pool_n_kv_shards"] == 8
    assert out["pool_pinned_shards_sum"]
    assert out["teacher_forced_rel"] < 0.05
    assert out["kernel_bit_parity"]
    assert out["mesh8_fused_matches_three_phase"], (
        "8-device fused paged decode diverged from the three-phase oracle")
    assert out["fused_kernel_bit_parity"], (
        "paged_decode_fused_tp diverged from the single-device fused kernel")
