"""MoE routing / dispatch invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.moe import _capacity, init_moe, moe_ffn


@pytest.fixture(scope="module")
def moe_cfg():
    return get_config("deepseek-moe-16b").reduced(num_experts=4, moe_top_k=2)


def test_moe_output_shape_and_finite(moe_cfg, rng_key):
    p = init_moe(moe_cfg, rng_key)
    x = jax.random.normal(rng_key, (2, 16, moe_cfg.d_model),
                          jnp.dtype(moe_cfg.activation_dtype))
    out, aux = moe_ffn(moe_cfg, p, x)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
    assert float(aux) >= 0.0


def test_moe_grad_flows_to_router_and_experts(moe_cfg, rng_key):
    p = init_moe(moe_cfg, rng_key)
    x = jax.random.normal(rng_key, (2, 8, moe_cfg.d_model))

    def loss(p):
        out, aux = moe_ffn(moe_cfg, p, x)
        return jnp.sum(out.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.max(jnp.abs(g["router"]))) > 0
    assert float(jnp.max(jnp.abs(g["w_gate"].astype(jnp.float32)))) > 0


def test_capacity_no_drop_when_uniform(moe_cfg, rng_key):
    """With capacity_factor >> 1 nothing drops: each token's output is a convex
    combination of expert outputs; with identical experts the result must equal
    running any single expert."""
    cfg = dataclasses.replace(moe_cfg, capacity_factor=8.0,
                              num_shared_experts=0)
    p = init_moe(cfg, rng_key)
    # make all experts identical
    for n in ("w_gate", "w_up", "w_down"):
        p[n] = jnp.broadcast_to(p[n][:1], p[n].shape)
    x = jax.random.normal(rng_key, (1, 16, cfg.d_model))
    out, _ = moe_ffn(cfg, p, x)
    act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
    single = (act(x @ p["w_gate"][0]) * (x @ p["w_up"][0])) @ p["w_down"][0]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(single, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_capacity_drops_overflow(moe_cfg, rng_key):
    """With capacity 0 < c << needed, overflow tokens produce zero output."""
    cfg = dataclasses.replace(moe_cfg, capacity_factor=1e-6,
                              num_shared_experts=0)
    p = init_moe(cfg, rng_key)
    x = jax.random.normal(rng_key, (1, 64, cfg.d_model))
    out, _ = moe_ffn(cfg, p, x)
    norms = jnp.linalg.norm(out.astype(jnp.float32), axis=-1)[0]
    assert float(jnp.sum(norms == 0.0)) > 0  # some tokens dropped


def test_capacity_rounding():
    cfg = get_config("deepseek-moe-16b")
    c = _capacity(1_000_000, cfg)
    assert c % 2048 == 0
    assert c >= 1_000_000 * cfg.moe_top_k / cfg.num_experts


def test_shared_experts_always_active(moe_cfg, rng_key):
    """Zeroing all routed experts leaves exactly the shared-expert output."""
    p = init_moe(moe_cfg, rng_key)
    p0 = dict(p)
    p0["w_down"] = jnp.zeros_like(p["w_down"])
    x = jax.random.normal(rng_key, (1, 8, moe_cfg.d_model))
    out, _ = moe_ffn(moe_cfg, p0, x)
    from repro.models.mlp import mlp
    expect = mlp(moe_cfg, p["shared"], x)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-2, atol=2e-2)
