"""RP106 fixtures (good): the injected clock is used everywhere; the
wall-clock *reference* in a default is fine (it is not a read)."""

import time


class Meter:
    def __init__(self, now_fn=time.perf_counter):
        self._now_fn = now_fn

    def stamp(self):
        return self._now_fn()
