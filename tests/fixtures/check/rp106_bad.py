"""RP106 fixture (bad): a module declaring an injectable clock reads the
wall clock directly — the Tracer shape, bypassing its own ``now_fn``."""

import time


class Meter:
    def __init__(self, now_fn=time.perf_counter):
        self._now_fn = now_fn

    def stamp(self):
        return time.perf_counter()  # bypasses the injected clock
