"""RP102 fixtures (good): donation followed by rebind is the contract."""

import jax


def _scatter_impl(k, upd):
    return k


scatter = jax.jit(_scatter_impl, donate_argnums=(0,))


def rebind_in_same_statement(pool, upd):
    pool.k = scatter(pool.k, upd)
    return pool.k.sum()


def rebind_before_read(pool, upd):
    out = scatter(pool.k, upd)
    pool.k = out
    return pool.k.sum()


def prefix_rebind_revives(pool, make_pool, upd):
    scatter(pool.k, upd)
    pool = make_pool()
    return pool.k.sum()
