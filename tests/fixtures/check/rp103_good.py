"""RP103 fixtures (good): every guard idiom the rule must accept."""

import concurrent.futures as cf


def _outcome(f):
    if f.cancelled():
        return None
    return f.exception()


def submit_cancelled_probe(executor, task, tracker):
    fut = executor.submit(task)

    def _done(f):
        if f.cancelled():
            tracker.note(None)
            return
        tracker.note(f.exception())

    fut.add_done_callback(_done)
    return fut


def submit_outcome_helper(executor, task, tracker):
    fut = executor.submit(task)

    def _done(f):
        err = _outcome(f)
        if err is None:
            tracker.note(f.result())

    fut.add_done_callback(_done)
    return fut


def submit_try_caught(executor, task, tracker):
    fut = executor.submit(task)

    def _done(f):
        try:
            tracker.note(f.result())
        except cf.CancelledError:
            pass

    fut.add_done_callback(_done)
    return fut


def plain_call_site_out_of_scope(fut):
    # exception() outside a done callback is synchronous caller code —
    # CancelledError propagates normally there, so RP103 must skip it
    return fut.exception()
