"""RP104 fixture (bad): lock-guarded queue state mutated lock-free.

Minimized from the WorkQueue/AsyncKvLoader shape: state the class itself
treats as lock-guarded (accessed under ``with self._lock`` elsewhere)
mutated on a path that skips the lock.
"""

import threading


class WorkTracker:
    def __init__(self):
        self._lock = threading.Lock()
        self._pending = []
        self._done = {}

    def put(self, item):
        with self._lock:
            self._pending.append(item)
            self._done.pop(item, None)

    def finish(self, key, value):
        self._done[key] = value  # item-assign outside the lock

    def drop_all(self):
        self._pending.clear()  # mutator call outside the lock

    def submit(self, executor, task):
        fut = executor.submit(task)

        def _done_cb(f):
            # nested closure runs on the executor thread — exactly the
            # unguarded-mutation shape RP104 exists for
            self._pending.pop()

        fut.add_done_callback(_done_cb)
        return fut
