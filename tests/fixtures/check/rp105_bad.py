"""RP105 fixture (bad): host access + f64 inside a Pallas kernel body."""

import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl

_trace_log = []


def _bad_kernel(x_ref, o_ref):
    host = np.zeros((8,))  # host numpy inside the kernel
    print("step")  # side-effecting builtin
    _trace_log.append(1)  # closure mutation: runs at trace time only
    o_ref[...] = x_ref[...].astype(jnp.float64) + host.sum()  # f64 on TPU


def launch(x):
    return pl.pallas_call(_bad_kernel, out_shape=x)(x)
