"""RP105 fixtures (good): pure kernel body; host code outside is fine."""

import functools

import numpy as np
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _good_kernel(scale, x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(jnp.float32) * scale


def launch(x, scale):
    kernel = functools.partial(_good_kernel, scale)
    return pl.pallas_call(kernel, out_shape=x)(x)


def host_helper():
    # not a kernel body: host numpy and print are fine here
    print("host side")
    return np.zeros((8,))
