"""RP102 fixture (bad): the PR 3 donated-scatter reuse bug, minimized."""

import jax


def _scatter_impl(k, upd):
    return k


scatter = jax.jit(_scatter_impl, donate_argnums=(0,))


def decode_step(pool, upd):
    new_k = scatter(pool.k, upd)
    norm = pool.k.sum()  # read of the donated (now invalid) buffer
    return new_k, norm
