"""RP103 fixture (bad): the PR 7 hang, minimized.

``Future.exception()`` on a cancelled future raises CancelledError — a
BaseException — straight out of ``Future._invoke_callbacks``, silently
aborting every later callback on the same future.
"""


def submit_unguarded(executor, task, tracker):
    fut = executor.submit(task)

    def _done(f):
        err = f.exception()
        tracker.note(err)

    fut.add_done_callback(_done)
    return fut


def submit_lambda_unguarded(executor, task, sink):
    fut = executor.submit(task)
    fut.add_done_callback(lambda f: sink.append(f.result()))
    return fut
