"""RP101 fixtures (bad): the PR 3/5/9 leak shapes.

Never imported — parsed by tests/test_check.py via repro.check.
"""


def compose_row_leaks_on_error(pool, key):
    # the PR 5 double-free's dual: a ref taken with no release anywhere
    pages = pool.acquire(key)
    if pages is None:
        raise KeyError(key)
    return pages


def stream_commit_skipped(pool, key, n_tokens):
    # the PR 9 shape: an early return jumps over the commit, leaking the
    # stream reservation
    pool.begin_stream(key, n_tokens)
    if n_tokens == 0:
        return None
    pool.commit_stream(key)


def private_tail_conditional_free(pool, n):
    # release nested deeper than its acquire: some paths skip it
    blocks = pool.alloc_private(n)
    if n > 1:
        pool.free_private(blocks)
    return blocks
