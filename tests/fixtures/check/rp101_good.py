"""RP101 fixtures (good): paired lifecycles the rule must accept."""


def compose_row_paired(pool, key, transform):
    pages = pool.acquire(key)
    try:
        return transform(pages)
    finally:
        pool.release(key)


def stream_single_exit(pool, key, n_tokens):
    pool.begin_stream(key, n_tokens)
    pool.commit_stream(key)


def stream_abort_in_finally(pool, key, n_tokens, feed):
    pool.begin_stream(key, n_tokens)
    committed = False
    try:
        for blk in feed:
            pool.extend_stream(key, blk)
        pool.commit_stream(key)
        committed = True
    finally:
        if not committed:
            pool.abort_stream(key)


def lock_acquire_is_out_of_scope(lock):
    # threading.Lock().acquire() is not a pool ref — RP101 must skip it
    lock.acquire()
    lock.release()


def ownership_transfer_suppressed(pool, key, registry):
    registry[key] = pool.acquire(key)  # repro: noqa[RP101] released by owner
