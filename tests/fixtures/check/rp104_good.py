"""RP104 fixtures (good): lock discipline the rule must accept."""

import threading


class WorkTracker:
    def __init__(self):
        self._lock = threading.Lock()
        self._pending = []  # construction is unshared: no lock needed

    def put(self, item):
        with self._lock:
            self._pending.append(item)

    def drain(self):
        with self._lock:
            out, self._pending = self._pending, []
        return out

    def approx_len(self):
        # an unlocked *read* is a documented racy-snapshot idiom here;
        # RP104 only flags mutations
        return len(self._pending)


class NoLockByDesign:
    """Single-writer class (the PagedKvPool contract): no lock declared,
    so RP104 has nothing to enforce."""

    def __init__(self):
        self._rows = []

    def push(self, row):
        self._rows.append(row)
