"""Fused single-launch paged decode vs the three-phase pipeline.

Boundary-case parity (bit-exact at the logits level): rows stepping across a
block boundary into a freshly-allocated tail block, empty-retrieval rows,
and stale released slots riding along as masked single-token rows. Plus the
shared-page mutation guard (an append past the private tail must raise
before touching the pool, never corrupt co-resident rows) and end-to-end
answer parity under both codecs. The kernel-vs-oracle layer is covered
separately by tests/test_kernel_fuzz.py.
"""

import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.serving import ContinuousScheduler, RagEngine
from repro.serving.sampling import greedy

CORPUS = {
    "d1": "the amber gate stands in hall nine beyond the long stair. " * 4,
    "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
    "d3": "the brass lamp hums beside the tall window all night. " * 4,
}
QUESTIONS = ["where is the amber gate?", "where is the cedar door?",
             "where is the brass lamp?"]
BUF, BLOCK = 192, 32


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _engine(model, params, store, **kw):
    kw.setdefault("top_k", 2)
    eng = RagEngine(model, params, store, chunk_tokens=48, **kw)
    for d, text in CORPUS.items():
        eng.ingest(d, text)
    return eng


def _twin_pcaches(eng, qs, max_new):
    """Two identically-composed paged caches — one will step fused, the
    other three-phase — plus the first sampled token per row."""
    pcs = [eng.init_paged_cache(len(qs), BUF, block_size=BLOCK)
           for _ in range(2)]
    toks = np.zeros((len(qs),), np.int32)
    for slot, q in enumerate(qs):
        firsts = []
        for pc in pcs:
            req = eng.prepare_request(q, max_new)
            eng.compose_row_paged(req, pc, slot)
            firsts.append(eng.prefill_row_paged(pc, slot, req.prompt))
        np.testing.assert_array_equal(np.asarray(firsts[0]),
                                      np.asarray(firsts[1]))
        toks[slot] = int(firsts[0][0])
    return pcs[0], pcs[1], toks


def _parity_steps(eng, pc_fused, pc_3p, toks, n_steps, rows=None):
    """Step both pipelines in lockstep, asserting bit-identical logits each
    step (over ``rows`` when given — stale slots' discarded outputs may
    legitimately differ)."""
    for _ in range(n_steps):
        t = jnp.asarray(toks)[:, None]
        lf = eng.step_rows_paged(pc_fused, t, fused=True)
        l3 = eng.step_rows_paged(pc_3p, t, fused=False)
        a, b = np.asarray(lf), np.asarray(l3)
        if rows is not None:
            a, b = a[rows], b[rows]
        np.testing.assert_array_equal(a, b)
        toks = np.asarray(greedy(lf[:, -1]))
    return toks


def test_fused_logits_bit_identical_across_block_boundary(setup):
    """Decode from mid-block through a 32-token block boundary: the step
    landing exactly at ``length % block == 0`` appends into a
    freshly-allocated (never-written) tail block mid-decode, and the next
    step reads it back. Every step must match three-phase bit-for-bit."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        pc_f, pc_3, toks = _twin_pcaches(eng, QUESTIONS[:2], max_new=40)
        n_steps = max(BLOCK - int(pc_f.host_lengths[s]) % BLOCK + 2
                      for s in range(2))                    # cross for both
        assert n_steps <= 38
        _parity_steps(eng, pc_f, pc_3, toks, n_steps)
        # both rows actually crossed into a fresh block during the loop
        assert all(int(pc_f.host_lengths[s]) // BLOCK
                   > (int(pc_f.host_lengths[s]) - n_steps) // BLOCK
                   for s in range(2))


def test_fused_empty_retrieval_and_released_rows(setup):
    """An empty-retrieval row (no doc pages, prompt-only tail) and — after a
    mid-run release — a stale slot stepping on scratch pages. Live rows stay
    bit-identical throughout; the released slot's discarded column must not
    perturb them."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        eng.retrieve = lambda q: []          # every row: prompt-only
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            pc_f, pc_3, toks = _twin_pcaches(
                eng, ["where is nothing at all?", QUESTIONS[0]], max_new=12)
        toks = _parity_steps(eng, pc_f, pc_3, toks, 3)
        eng.release_row_paged(pc_f, 0)
        eng.release_row_paged(pc_3, 0)
        _parity_steps(eng, pc_f, pc_3, toks, 3, rows=[1])


def test_fused_append_past_tail_raises_not_corrupts(setup):
    """The shared-page mutation guard: stepping a row past its admitted
    decode budget must raise (the append would land in ref-counted shared
    pages) and must raise BEFORE mutating anything — pool pages and position
    state stay exactly as the last good step left them."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        pcache = eng.init_paged_cache(1, BUF, block_size=BLOCK)
        req = eng.prepare_request(QUESTIONS[0], 2)   # 2-token decode budget
        eng.compose_row_paged(req, pcache, 0)
        first = eng.prefill_row_paged(pcache, 0, req.prompt)
        tok = jnp.asarray([[int(first[0])]], jnp.int32)
        cap = pcache.rows[0].n_doc + len(pcache.rows[0].tail_slots)
        budget = cap - int(pcache.host_lengths[0])
        for _ in range(budget):                      # in-budget steps are fine
            logits = eng.step_rows_paged(pcache, tok, fused=True)
            tok = jnp.asarray(greedy(logits[:, -1]))[:, None]
        k_before = np.asarray(pcache.pool.k)
        lengths_before = pcache.host_lengths.copy()
        with pytest.raises(ValueError, match="shared pages"):
            eng.step_rows_paged(pcache, tok, fused=True)
        np.testing.assert_array_equal(np.asarray(pcache.pool.k), k_before)
        np.testing.assert_array_equal(pcache.host_lengths, lengths_before)


@pytest.mark.parametrize("codec", ["bf16", "int8"])
def test_fused_end_to_end_answers_match_three_phase(setup, codec):
    """Full ContinuousScheduler runs — fused default vs pinned three-phase —
    must produce identical answers under both KV codecs (bf16 logits parity
    is bit-exact; int8 rows share the same stored quantized pages, so greedy
    decode agrees there too)."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv",
                      codec=codec)
        qs = QUESTIONS + [QUESTIONS[0]]              # one shared-chunk pair
        answers = {}
        for fused in (False, True):
            sched = ContinuousScheduler(eng, max_slots=2, paged=True,
                                        block_size=BLOCK, fused=fused)
            answers[fused], m = sched.run(qs, max_new_tokens=5)
            sched.shutdown()
            assert m.n_new_tokens > 0
        assert answers[True] == answers[False], (
            f"fused paged decode diverged from the three-phase parity "
            f"oracle under codec={codec}")
