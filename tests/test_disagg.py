"""Disaggregated materializer/decode roles (DESIGN.md §14).

The flash artifact plane + the ``WorkQueue`` are the roles' SOLE interface;
the contract tested here: any artifact a ``MaterializerWorker`` writes —
either codec, mesh or no mesh — must land in a ``DecodeWorker``'s paged
pool byte-for-byte, refreshed artifacts must never alias stale resident
pages (generation-tagged page keys), and the composed ``--role both``
engine must stay bit-identical to the standalone decode role.
"""

import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.materialize import load_artifact_encoded
from repro.kvstore import FlashKVStore
from repro.launch.mesh import make_serving_mesh
from repro.models import build_model
from repro.serving import (ContinuousScheduler, DecodeWorker, HandoffRecord,
                           MaterializeJob, MaterializerWorker, RagEngine,
                           WorkQueue)

CORPUS = {
    "d1": "the amber gate stands in hall nine beyond the long stair. " * 4,
    "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
    "d3": "the brass lamp hums beside the tall window all night. " * 4,
}
QUESTIONS = ["where is the amber gate?", "where is the cedar door?",
             "where is the brass lamp?"]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _engine(model, params, store, **kw):
    kw.setdefault("top_k", 2)
    eng = RagEngine(model, params, store, mode="matkv", chunk_tokens=48, **kw)
    for d, text in CORPUS.items():
        eng.ingest(d, text)
    return eng


# ---------------------------------------------------------------------------
# cross-role artifact contract (the satellite-6 sweep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codec", ["bf16", "int8"])
@pytest.mark.parametrize("with_mesh", [False, True],
                         ids=["no_mesh", "mesh1"])
def test_decode_pool_ingests_any_materializer_artifact(setup, codec,
                                                       with_mesh, tmp_path):
    """Golden round-trip: a materializer-role artifact (either codec), read
    back through a decode-role pool (mesh or not), must be byte-for-byte
    the flash artifact's encoded tensors — no widening, no transcode."""
    cfg, model, params = setup
    store = FlashKVStore(tmp_path)
    queue = WorkQueue()
    mat = MaterializerWorker(model, params, store, codec=codec,
                             chunk_tokens=48, queue=queue)
    cids = mat.ingest_document("d1", CORPUS["d1"])
    assert all(queue.generation(c) == 0 for c in cids)
    assert all(store.get_meta(c)["generation"] == 0 for c in cids)

    mesh = make_serving_mesh(1) if with_mesh else None
    worker = DecodeWorker(model, params, store, codec=codec, chunk_tokens=48,
                          top_k=len(cids), queue=queue, mesh=mesh)
    req = worker.prepare_request("where is the amber gate?", 4,
                                 chunk_ids=cids)
    pcache = worker.init_paged_cache(1, 384, block_size=16)
    pool = pcache.pool
    worker.compose_row_paged(req, pcache, 0)
    for cid in cids:
        key = worker.page_key(cid)
        assert key == f"{cid}@g0"          # generation-tagged pool entries
        slots = pool.chunk_slot_ids(key)
        enc, _ = load_artifact_encoded(cfg, store.get(cid))
        ek, ev = np.asarray(enc.k), np.asarray(enc.v)
        pk, pv = np.asarray(pool.k[:, slots]), np.asarray(pool.v[:, slots])
        assert pk.dtype == ek.dtype and pv.dtype == ev.dtype
        np.testing.assert_array_equal(pk, ek)
        np.testing.assert_array_equal(pv, ev)
        if codec == "int8":
            np.testing.assert_array_equal(
                np.asarray(pool.k_scale[:, slots]),
                np.asarray(enc.k_scale)[..., 0].astype(
                    pool.k_scale.dtype))
            np.testing.assert_array_equal(
                np.asarray(pool.v_scale[:, slots]),
                np.asarray(enc.v_scale)[..., 0].astype(
                    pool.v_scale.dtype))
    worker.shutdown()


# ---------------------------------------------------------------------------
# decode role == composed engine, bit for bit
# ---------------------------------------------------------------------------

def test_decode_worker_answers_match_composed_engine(setup):
    """A standalone DecodeWorker fed HandoffRecords must answer bit-identically
    to RagEngine.answer — the role split moves code, never math."""
    cfg, model, params = setup
    qs = [QUESTIONS[i % 3] for i in range(4)]      # a duplicate question too
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store)
        refs = [eng.answer(q, max_new_tokens=5)[0] for q in qs]

        queue = WorkQueue()
        worker = DecodeWorker(model, params, store, chunk_tokens=48, top_k=2,
                              queue=queue)
        for q in qs:
            queue.submit_handoff(HandoffRecord(q, eng.retrieve(q), 5))
        sched = ContinuousScheduler(worker, max_slots=2, paged=True,
                                    block_size=32)
        answers, m = sched.run(qs, max_new_tokens=5)
        sched.shutdown()
        worker.shutdown()
        assert answers == refs
        assert queue.n_handoffs == 0               # all records consumed
        # per-role metrics: decode work only, ever
        assert m.role == "decode"
        assert m.n_new_tokens > 0 and m.decode_tokens_per_s > 0
        assert m.materialize_s == 0 and m.n_materialized_tokens == 0


def test_decode_worker_without_handoff_is_an_error(setup):
    """No retrieval on the decode role: a request with no HandoffRecord and
    no explicit chunk_ids is a deployment error, not a silent query-only."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        worker = DecodeWorker(model, params, store, queue=WorkQueue())
        with pytest.raises(LookupError, match="no HandoffRecord"):
            worker.prepare_request("who goes there?", 4)
        # ...and with no queue at all, a miss cannot even be requested
        bare = DecodeWorker(model, params, store)
        with pytest.raises(LookupError, match="no work queue"):
            bare.request_materialize("deadbeef")
        worker.shutdown()
        bare.shutdown()


# ---------------------------------------------------------------------------
# artifact generations: refresh never mixes with stale resident pages
# ---------------------------------------------------------------------------

def test_generation_refresh_is_a_pool_miss_by_construction(setup, tmp_path):
    """Re-materializing the SAME chunk id (new params — a finetune push)
    bumps the generation: the decode worker's page key changes, so the
    fresh artifact can never be served from the stale resident entry, and
    the superseded refcount-0 entry is dropped eagerly at next compose."""
    cfg, model, params = setup
    store = FlashKVStore(tmp_path)
    queue = WorkQueue()
    mat = MaterializerWorker(model, params, store, chunk_tokens=48,
                             queue=queue)
    cids = mat.ingest_document("d1", CORPUS["d1"])
    cid = cids[0]

    worker = DecodeWorker(model, params, store, chunk_tokens=48,
                          top_k=len(cids), queue=queue)
    pcache = worker.init_paged_cache(2, 384, block_size=16)
    pool = pcache.pool
    req = worker.prepare_request("where is the amber gate?", 4,
                                 chunk_ids=cids)
    worker.compose_row_paged(req, pcache, 0)
    key0 = worker.page_key(cid)
    assert key0 == f"{cid}@g0" and pool.has(key0)
    old_k = np.asarray(pool.k[:, pool.chunk_slot_ids(key0)])

    # refresh with DIFFERENT params: same chunk id, new artifact bytes
    params2 = model.init(jax.random.PRNGKey(7))
    mat2 = MaterializerWorker(model, params2, store, chunk_tokens=48,
                              queue=queue)
    for c in cids:
        mat2.register_chunk(mat.chunk(c))
    assert mat2.refresh(cid) == 1
    assert queue.generation(cid) == 1
    key1 = worker.page_key(cid)
    assert key1 == f"{cid}@g1"
    assert pool.has(key0) and not pool.has(key1)   # stale copy still resident

    # release the old row, compose a fresh one: the new generation is a pool
    # miss (fresh flash read), and the superseded entry is dropped eagerly
    worker.release_row_paged(pcache, 0)
    req2 = worker.prepare_request("where is the amber gate?", 4,
                                  chunk_ids=cids)
    _, nbytes, _, hits, misses = worker.compose_row_paged(req2, pcache, 1)
    assert misses >= 1 and nbytes > 0              # g1 came from flash
    assert pool.has(key1) and not pool.has(key0)   # stale entry evicted
    new_k = np.asarray(pool.k[:, pool.chunk_slot_ids(key1)])
    enc, _ = load_artifact_encoded(cfg, store.get(cid))
    np.testing.assert_array_equal(new_k, np.asarray(enc.k))
    assert not np.array_equal(new_k, old_k)        # genuinely new bytes
    assert store.get_meta(cid)["generation"] == 1
    worker.shutdown()


# ---------------------------------------------------------------------------
# materialize-on-miss through the scheduler
# ---------------------------------------------------------------------------

def test_scheduler_materializes_cold_chunk_instead_of_stalling(setup):
    """Admission finding a chunk with no flash artifact parks THAT request
    behind a queue job (decode keeps stepping everything else); a
    materializer draining the queue un-parks it, and answers stay exact."""
    cfg, model, params = setup
    qs = list(QUESTIONS)
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store)
        refs = [eng.answer(q, max_new_tokens=5)[0] for q in qs]

        queue = WorkQueue()
        mat = MaterializerWorker(model, params, store, chunk_tokens=48,
                                 queue=queue)
        for c in eng._chunks.values():
            mat.register_chunk(c)
        worker = DecodeWorker(model, params, store, chunk_tokens=48, top_k=2,
                              queue=queue)
        for q in qs:
            queue.submit_handoff(HandoffRecord(q, eng.retrieve(q), 5))
        victim = eng.retrieve(qs[0])[0]
        assert store.delete(victim)

        stop = threading.Event()

        def pump():
            while not stop.is_set():
                mat.process_jobs()
                time.sleep(0.002)

        t = threading.Thread(target=pump, daemon=True)
        t.start()
        try:
            sched = ContinuousScheduler(worker, max_slots=2, paged=True,
                                        block_size=32)
            answers, m = sched.run(qs, max_new_tokens=5)
            sched.shutdown()
        finally:
            stop.set()
            t.join()
        worker.shutdown()
        assert answers == refs                     # same params -> same bytes
        assert mat.metrics.n_materialize_jobs >= 1
        assert store.exists(victim)
        assert mat.metrics.flash_bytes_written > 0


def test_process_jobs_rejects_unregistered_chunk():
    """A miss job for a chunk the materializer never ingested is a
    deployment error — the decode role cannot supply token content."""
    queue = WorkQueue()
    cfg = get_config("smollm-135m").reduced(vocab_size=300, num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with tempfile.TemporaryDirectory() as d:
        mat = MaterializerWorker(model, params, FlashKVStore(d), queue=queue)
        queue.submit_job(MaterializeJob("not-a-chunk", reason="miss"))
        with pytest.raises(KeyError, match="no registered chunk"):
            mat.process_jobs()


# ---------------------------------------------------------------------------
# WorkQueue units
# ---------------------------------------------------------------------------

def test_work_queue_job_dedup_and_fifo():
    q = WorkQueue()
    assert q.submit_job(MaterializeJob("a"))
    assert not q.submit_job(MaterializeJob("a", reason="miss"))  # dedup
    assert q.submit_job(MaterializeJob("b"))
    assert q.n_jobs == 2
    assert q.next_job().chunk_id == "a"
    assert q.submit_job(MaterializeJob("a"))       # reopens after drain
    assert [q.next_job().chunk_id for _ in range(2)] == ["b", "a"]
    assert q.next_job() is None


def test_work_queue_generations_monotonic():
    q = WorkQueue()
    assert q.generation("c") is None
    assert q.next_generation("c") == 0
    q.publish("c", 0)
    assert q.generation("c") == 0
    assert q.next_generation("c") == 1
    q.publish("c", 1)
    q.publish("c", 0)                              # stale publish: no-op
    assert q.generation("c") == 1
    assert q.generations_snapshot(["c", "missing"]) == {"c": 1}


def test_work_queue_handoffs_fifo_per_question():
    q = WorkQueue()
    q.submit_handoff(HandoffRecord("q1", ["a"], 3))
    q.submit_handoff(HandoffRecord("q2", ["b"], 4))
    q.submit_handoff(HandoffRecord("q1", ["c"], 5))
    assert q.take_handoff("q1").chunk_ids == ["a"]  # oldest q1 first
    assert q.take_handoff().question == "q2"        # plain FIFO
    assert q.take_handoff("q2") is None
    assert q.take_handoff("q1").chunk_ids == ["c"]
    assert q.n_handoffs == 0


def test_work_queue_manifest_roundtrip(tmp_path):
    q = WorkQueue()
    q.publish("c1", 2)
    q.publish("c2", 0)
    q.submit_job(MaterializeJob("c3", reason="miss", doc_id="d9"))
    q.submit_handoff(HandoffRecord("q?", ["c1", "c2"], 7,
                                   generations={"c1": 2}))
    path = tmp_path / "queue.json"
    q.save(path)
    q2 = WorkQueue.load(path)
    assert q2.generation("c1") == 2 and q2.generation("c2") == 0
    job = q2.next_job()
    assert (job.chunk_id, job.reason, job.doc_id) == ("c3", "miss", "d9")
    rec = q2.take_handoff("q?")
    assert rec.chunk_ids == ["c1", "c2"] and rec.max_new_tokens == 7
    assert rec.generations == {"c1": 2}
    # round-trip is lossless both ways
    assert WorkQueue.from_manifest(q.to_manifest()).to_manifest() \
        == q.to_manifest()
