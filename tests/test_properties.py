"""Property-based tests (hypothesis) over the system's invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed; property tests skipped")
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.chunking import chunk_document
from repro.core.economics import (GpuSpec, SsdSpec, break_even_interval_s)
from repro.core.quantize import dequantize_kv, quantize_kv
from repro.kvstore import LruBytesCache, deserialize, serialize
from repro.models.attention import position_mask

_DTYPES = [np.float32, np.float16, np.int8, np.int32]


@settings(max_examples=30, deadline=None)
@given(
    shapes=st.lists(
        st.tuples(st.integers(1, 4), st.integers(1, 8), st.integers(1, 8)),
        min_size=1, max_size=4),
    dt_idx=st.integers(0, len(_DTYPES) - 1),
    seed=st.integers(0, 2**31 - 1),
)
def test_serialization_roundtrip_property(shapes, dt_idx, seed):
    rng = np.random.default_rng(seed)
    dt = _DTYPES[dt_idx]
    tensors = {}
    for i, shp in enumerate(shapes):
        a = rng.standard_normal(shp) * 100
        tensors[f"t{i}"] = a.astype(dt)
    out, _ = deserialize(serialize(tensors, {"s": seed}))
    for k, a in tensors.items():
        np.testing.assert_array_equal(out[k], a)


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       scale=st.floats(1e-3, 1e3),
       n=st.integers(1, 64))
def test_quantize_bounded_error_property(seed, scale, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((n, 16)) * scale, jnp.float32)
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    # per-vector error bounded by scale/2 = amax/254
    amax = np.maximum(np.abs(np.asarray(x)).max(axis=-1, keepdims=True), 1e-8)
    err = np.abs(np.asarray(back) - np.asarray(x))
    assert (err <= amax / 127.0 + 1e-6).all()


@settings(max_examples=25, deadline=None)
@given(doc_len=st.integers(1, 300), chunk=st.integers(1, 64))
def test_chunking_partitions_document(doc_len, chunk):
    toks = np.arange(doc_len, dtype=np.int32)
    chunks = chunk_document("d", toks, chunk_tokens=chunk)
    recon = np.concatenate([c.tokens for c in chunks])
    np.testing.assert_array_equal(recon, toks)
    assert all(len(c) <= chunk for c in chunks)
    assert [c.index for c in chunks] == list(range(len(chunks)))


@settings(max_examples=20, deadline=None)
@given(ops=st.lists(
    st.tuples(st.sampled_from(["put", "get"]), st.integers(0, 9),
              st.integers(1, 20)),
    max_size=60), cap=st.integers(10, 100))
def test_lru_capacity_invariant(ops, cap):
    c = LruBytesCache(cap)
    for op, key, size in ops:
        if op == "put":
            c.put(str(key), b"x" * size)
        else:
            v = c.get(str(key))
            assert v is None or set(v) == {ord("x")}
        assert c.size_bytes <= cap


@settings(max_examples=25, deadline=None)
@given(sq=st.integers(1, 16), sk=st.integers(1, 32),
       window=st.one_of(st.none(), st.integers(1, 16)),
       offset=st.integers(0, 16))
def test_position_mask_properties(sq, sk, window, offset):
    q_pos = jnp.arange(offset, offset + sq, dtype=jnp.int32)
    k_pos = jnp.arange(sk, dtype=jnp.int32)
    m = np.asarray(position_mask(q_pos, k_pos, window, True))
    assert m.shape == (sq, sk)
    # causality: no attention to the future
    for i in range(sq):
        for j in range(sk):
            if j > offset + i:
                assert not m[i, j]
            if window is not None and j <= offset + i - window:
                assert not m[i, j]
    # monotone: if (i, j) visible then (i+1, j) visible for no-window masks
    if window is None:
        for i in range(sq - 1):
            assert (~m[i] | m[i + 1]).all()


@settings(max_examples=30, deadline=None)
@given(gpu_price=st.floats(1e3, 1e6), kv_rate=st.floats(1.0, 1e4),
       ssd_price=st.floats(0.01, 10.0))
def test_break_even_monotonicity(gpu_price, kv_rate, ssd_price):
    """Pricier GPU -> longer break-even; pricier storage -> shorter."""
    gpu = GpuSpec("g", gpu_price, 300, kv_rate, 30)
    ssd = SsdSpec("s", ssd_price, 10.0, 7.0)
    t = break_even_interval_s(gpu, ssd, kv_bytes_per_token=1_000_000)
    gpu2 = GpuSpec("g", gpu_price * 2, 300, kv_rate, 30)
    ssd2 = SsdSpec("s", ssd_price * 2, 10.0, 7.0)
    assert break_even_interval_s(gpu2, ssd, 1_000_000) > t * 1.5
    assert break_even_interval_s(gpu, ssd2, 1_000_000) < t
