"""The end-to-end KV codec layer (DESIGN.md §11): wire round trips per
family, the codec-aware paged pool, the fused paged_decode_quant kernel vs
its oracle, and int8 serving parity/quality bounds."""

import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.materialize import (Materializer, load_artifact,
                                    load_artifact_encoded)
from repro.core.quantize import (Bf16Codec, Int8Codec, codec_for_meta,
                                 dequantize_kv, get_codec, quantize_kv)
from repro.kernels import ref
from repro.kernels.ops import paged_decode_quant_op
from repro.kernels.paged_decode_quant import paged_decode_quant
from repro.kvstore import FlashKVStore
from repro.kvstore.serialization import serialize
from repro.models import build_model
from repro.paged import PagedKvPool, gather_rows_quant
from repro.serving import (ContinuousScheduler, RagEngine, dense_row_path,
                           paged_row_path, teacher_forced_rel)

CORPUS = {
    "d1": "the amber gate stands in hall nine beyond the long stair. " * 4,
    "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
    "d3": "the brass lamp hums beside the tall window all night. " * 4,
}
QUESTIONS = ["where is the amber gate?", "where is the cedar door?",
             "where is the brass lamp?"]


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _engine(model, params, store, **kw):
    kw.setdefault("top_k", 2)
    eng = RagEngine(model, params, store, chunk_tokens=48, **kw)
    for d, text in CORPUS.items():
        eng.ingest(d, text)
    return eng


# ---------------------------------------------------------------------------
# codec registry + wire round trips per family
# ---------------------------------------------------------------------------

def test_get_codec_resolution():
    assert get_codec(None).codec_id == "bf16"
    assert get_codec("int8").codec_id == "int8"
    assert get_codec(Int8Codec()).codec_id == "int8"
    with pytest.raises(ValueError, match="unknown KV codec"):
        get_codec("fp4")
    # artifacts from before the codec layer carried a bool, not an id
    assert codec_for_meta({"quantized": True}).codec_id == "int8"
    assert codec_for_meta({"quantized": False}).codec_id == "bf16"
    assert codec_for_meta({"codec": "int8"}).codec_id == "int8"


def test_codec_kv_bytes_per_token(setup):
    """Encoded flash bytes per token: the quantity Eq. 1 prices. int8 is
    (hd + 2) / (2 * hd) of bf16 — the break-even interval lever."""
    cfg, _, _ = setup
    bf16 = Bf16Codec().kv_bytes_per_token(cfg)
    int8 = Int8Codec().kv_bytes_per_token(cfg)
    assert bf16 == cfg.kv_bytes_per_token(2)
    expect = (cfg.head_dim + 2) / (2 * cfg.head_dim)
    assert int8 / bf16 == pytest.approx(expect)
    ssm = get_config("falcon-mamba-7b")
    assert Int8Codec().kv_bytes_per_token(ssm) == 0   # state is O(1)
    # admission priced at encoded bytes: int8 stretches the Eq.-1 interval
    from repro.core.tiering import TenDayAdmission
    paper = get_config("llama-3.1-8b")
    with pytest.raises(ValueError, match="no per-token KV"):
        TenDayAdmission.for_config(ssm, "int8")   # would divide by zero
    adm8 = TenDayAdmission.for_config(paper, "int8")
    admb = TenDayAdmission.for_config(paper, "bf16")
    assert adm8.break_even_s > admb.break_even_s
    assert adm8.break_even_s / admb.break_even_s == pytest.approx(
        Bf16Codec().kv_bytes_per_token(paper)
        / Int8Codec().kv_bytes_per_token(paper), rel=1e-6)


def _family_tensors(fam, rng):
    """Synthetic artifact tensors in materializer layout (batch squeezed)."""
    l, s, kv, hd = 2, 20, 3, 16
    t = {}
    if fam in ("dense", "vlm", "moe", "hybrid"):
        t["k"] = rng.standard_normal((l, s, kv, hd)).astype(np.float32)
        t["v"] = rng.standard_normal((l, s, kv, hd)).astype(np.float32)
    if fam in ("ssm", "hybrid"):
        t["conv"] = rng.standard_normal((l, 8, 4)).astype(np.float32)
        t["h"] = rng.standard_normal((l, 8, 6)).astype(np.float32)
    if fam == "encdec":
        t["cross_k"] = rng.standard_normal((l, s, kv, hd)).astype(np.float32)
        t["cross_v"] = rng.standard_normal((l, s, kv, hd)).astype(np.float32)
    return t


@pytest.mark.parametrize("fam", ["dense", "ssm", "hybrid", "encdec"])
@pytest.mark.parametrize("codec_id", ["bf16", "int8"])
def test_roundtrip_encode_serialize_load_per_family(setup, fam, codec_id):
    """encode -> serialize -> load_artifact must reproduce every family's
    artifact: KV tensors within the codec's error, recurrent states exactly
    (the codec never touches conv/h)."""
    cfg, _, _ = setup
    codec = get_codec(codec_id)
    rng = np.random.default_rng(7)
    plain = _family_tensors(fam, rng)
    wire = {}
    for name, arr in plain.items():
        if name in ("k", "v", "cross_k", "cross_v"):
            wire.update(codec.encode_named(name, arr))
        else:
            wire[name] = arr
    payload = serialize(wire, {"family": fam, "codec": codec.codec_id,
                               "n_tokens": 20})
    art, meta = load_artifact(cfg, payload, dtype=jnp.float32)
    assert meta["codec"] == codec.codec_id
    tol = 0.0 if codec_id == "bf16" else 0.03
    if fam == "dense":
        k, v = art
        np.testing.assert_allclose(np.asarray(k[:, 0]), plain["k"], atol=tol)
        np.testing.assert_allclose(np.asarray(v[:, 0]), plain["v"], atol=tol)
    elif fam == "ssm":
        conv, h = art
        np.testing.assert_array_equal(np.asarray(conv[:, 0]), plain["conv"])
        np.testing.assert_array_equal(np.asarray(h[:, 0]), plain["h"])
    elif fam == "hybrid":
        (k, v), (conv, h) = art
        np.testing.assert_allclose(np.asarray(k[:, 0]), plain["k"], atol=tol)
        np.testing.assert_array_equal(np.asarray(h[:, 0]), plain["h"])
    else:
        ck, cv = art
        np.testing.assert_allclose(np.asarray(ck[:, 0]), plain["cross_k"],
                                   atol=tol)
        np.testing.assert_allclose(np.asarray(cv[:, 0]), plain["cross_v"],
                                   atol=tol)


def test_load_artifact_encoded_keeps_storage_dtype(setup):
    """The paged-pool read path: an int8 artifact comes off flash as int8
    values + f16 scales, never widened, and decodes to exactly what
    load_artifact widens to."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        mat = Materializer(model, params, store, codec="int8")
        eng = _engine(model, params, store, mode="matkv", codec="int8")
        cid = eng.retrieve(QUESTIONS[0])[0]
        payload = store.get(cid)
        enc, meta = load_artifact_encoded(cfg, payload)
        assert meta["codec"] == "int8"
        assert np.asarray(enc.k).dtype == np.int8
        assert np.asarray(enc.k_scale).dtype == np.float16
        assert enc.n_tokens == meta["n_tokens"]
        (k_wide, v_wide), _ = load_artifact(cfg, payload)
        np.testing.assert_array_equal(
            np.asarray(dequantize_kv(jnp.asarray(enc.k),
                                     jnp.asarray(enc.k_scale)), np.float32),
            np.asarray(k_wide[:, 0], np.float32))


def test_int8_artifact_bytes_ratio(setup):
    """Stored int8 artifacts must be ~0.52x bf16 (values + scales + header),
    the flash-byte lever the whole PR turns."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        e8 = _engine(model, params, FlashKVStore(d + "/8"), mode="matkv",
                     codec="int8")
        eb = _engine(model, params, FlashKVStore(d + "/b"), mode="matkv",
                     codec="bf16")
        ratio = e8.store.total_bytes() / eb.store.total_bytes()
        assert ratio < 0.56, f"int8 artifacts are {ratio:.3f}x bf16"


# ---------------------------------------------------------------------------
# codec-aware pool + gather/dequant runtime
# ---------------------------------------------------------------------------

def test_dram_tier_holds_2x_int8_chunks(setup):
    """The host cache tier accounts encoded bytes, so one DRAM budget holds
    ~2x the chunks under int8 — same doubling as the HBM pool."""
    from repro.kvstore import LruBytesCache
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        stores = {codec: _engine(model, params,
                                 FlashKVStore(f"{d}/{codec}"),
                                 mode="matkv", codec=codec).store
                  for codec in ("bf16", "int8")}
        # one byte budget for both tiers (8 bf16 chunks' worth)
        bf16_payload = len(stores["bf16"].get(stores["bf16"].list_ids()[0]))
        counts = {}
        for codec, store in stores.items():
            cache = LruBytesCache(capacity_bytes=8 * bf16_payload)
            for cid in store.list_ids():
                cache.put(cid, store.get(cid))
            counts[codec] = cache.n_entries
        assert counts["int8"] >= 1.7 * counts["bf16"]


def test_pool_int8_layout_and_budget(setup):
    cfg, _, _ = setup
    pool = PagedKvPool(cfg, n_blocks=8, block_size=16, codec="int8")
    assert pool.k.dtype == jnp.int8 and pool.k_scale.dtype == jnp.float16
    bf16 = PagedKvPool.block_bytes(cfg, 16, "bf16")
    int8 = PagedKvPool.block_bytes(cfg, 16, "int8")
    assert pool.bytes_per_block == int8
    # hd + 2 scale bytes per vector vs 2*hd: the residency doubling
    assert 1.7 < bf16 / int8 < 2.0
    budget = 10 * bf16
    assert (PagedKvPool.blocks_for_budget(cfg, budget, 16, "int8")
            > PagedKvPool.blocks_for_budget(cfg, budget, 16, "bf16"))


def test_pool_int8_insert_encoded_and_gather_dequant(setup):
    """Encoded insert writes int8 pages verbatim; the fused gather/dequant
    view is bit-identical to host dequantize_kv of the same artifact (the
    property that makes paged int8 match the dense int8 compose)."""
    cfg, _, _ = setup
    pool = PagedKvPool(cfg, n_blocks=8, block_size=16, codec="int8")
    shape = (cfg.num_layers, 20, cfg.num_kv_heads, cfg.head_dim)
    kf = jax.random.normal(jax.random.PRNGKey(0), shape)
    vf = kf + 1.0
    qk, sk = quantize_kv(kf)
    qv, sv = quantize_kv(vf)
    from repro.core.quantize import EncodedKV
    enc = EncodedKV(codec=get_codec("int8"), k=qk, v=qv, k_scale=sk,
                    v_scale=sv, n_tokens=20)
    assert pool.insert("c0", encoded=enc, nbytes=99) == 20
    slots = pool.chunk_slot_ids("c0")
    np.testing.assert_array_equal(np.asarray(pool.k[:, slots]),
                                  np.asarray(qk))
    gk, gv = gather_rows_quant(pool.k, pool.v, pool.k_scale, pool.v_scale,
                               jnp.asarray(slots)[None], dtype=pool.dtype)
    np.testing.assert_array_equal(
        np.asarray(gk[:, 0], np.float32),
        np.asarray(dequantize_kv(qk, sk, pool.dtype), np.float32))
    np.testing.assert_array_equal(
        np.asarray(gv[:, 0], np.float32),
        np.asarray(dequantize_kv(qv, sv, pool.dtype), np.float32))
    assert pool.stats.peak_resident_chunks == 1


def test_pool_transcodes_on_codec_mismatch(setup):
    """A bf16 artifact offered to an int8 pool (or vice versa) is transcoded
    rather than rejected — mixed stores stay servable."""
    cfg, _, _ = setup
    pool = PagedKvPool(cfg, n_blocks=8, block_size=16, codec="int8")
    shape = (cfg.num_layers, 12, cfg.num_kv_heads, cfg.head_dim)
    kf = jax.random.normal(jax.random.PRNGKey(1), shape)
    from repro.core.quantize import EncodedKV
    enc = EncodedKV(codec=get_codec("bf16"), k=kf, v=kf + 1.0, n_tokens=12)
    pool.insert("c0", encoded=enc)
    slots = pool.chunk_slot_ids("c0")
    gk, _ = gather_rows_quant(pool.k, pool.v, pool.k_scale, pool.v_scale,
                              jnp.asarray(slots)[None], dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(gk[:, 0]),
                               np.asarray(kf, np.float32), atol=0.05)


# ---------------------------------------------------------------------------
# fused kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kv,hd,block,n_pool,n_max", [
    (2, 8, 2, 64, 128, 10, 4),  # GQA (the serving shape)
    (1, 4, 4, 32, 64, 6, 3),    # MHA
    (2, 4, 1, 128, 128, 8, 2),  # MQA
    (1, 9, 3, 64, 128, 6, 3),   # smollm-style odd-head GQA
])
def test_paged_decode_quant_vs_ref(rng_key, b, h, kv, hd, block,
                                   n_pool, n_max):
    """The fused dequant+attention kernel vs its oracle: shared blocks,
    ragged interior lens, empty trailing blocks. Grouped-query shapes
    (group > 1, every serving config here) agree with the *jitted* oracle
    bit-for-bit — the acceptance bar, also asserted in the
    quant-residency benchmark; the degenerate group == 1 GEMV lowers
    through a different XLA path and holds to fp tolerance."""
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    k_pool, k_s = quantize_kv(jax.random.normal(ks[1], (n_pool, kv, block, hd)))
    v_pool, v_s = quantize_kv(jax.random.normal(ks[2], (n_pool, kv, block, hd)))
    k_s, v_s = k_s[..., 0], v_s[..., 0]
    tbl = np.zeros((b, n_max), np.int32)
    lens = np.zeros((b, n_max), np.int32)
    rng = np.random.default_rng(0)
    for i in range(b):
        tbl[i] = rng.permutation(n_pool)[:n_max]
        tbl[i, 0] = 1                        # every row shares block 1
        lens[i, 0] = block
        if n_max > 1:
            lens[i, 1] = block // 2          # ragged interior chunk tail
        if n_max > 2:
            lens[i, 2] = block
    out = paged_decode_quant(q, k_pool, v_pool, k_s, v_s,
                             jnp.asarray(tbl), jnp.asarray(lens))
    oracle = jax.jit(ref.paged_decode_quant_ref)(
        q, k_pool, v_pool, k_s, v_s, jnp.asarray(tbl), jnp.asarray(lens))
    if h // kv > 1:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(oracle))
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   rtol=3e-5, atol=3e-5)


def test_paged_decode_quant_matches_dequantized_paged_decode(rng_key):
    """Fused on-chip dequant == dequantize-then-attend (the unfused
    composition through the fp kernel), to fp tolerance."""
    b, h, kv, hd, block, n_pool = 2, 4, 2, 32, 64, 6
    from repro.kernels.paged_decode import paged_decode
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    kf = jax.random.normal(ks[1], (n_pool, kv, block, hd))
    vf = jax.random.normal(ks[2], (n_pool, kv, block, hd))
    qk, sk = quantize_kv(kf)
    qv, sv = quantize_kv(vf)
    tbl = jnp.asarray([[0, 3], [5, 0]], jnp.int32)
    lens = jnp.asarray([[block, 10], [30, 0]], jnp.int32)
    out = paged_decode_quant(q, qk, qv, sk[..., 0], sv[..., 0], tbl, lens)
    wide = paged_decode(q, dequantize_kv(qk, sk, jnp.float32),
                        dequantize_kv(qv, sv, jnp.float32), tbl, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(wide),
                               rtol=3e-5, atol=3e-5)


def test_paged_decode_quant_fully_masked_row_outputs_zeros(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 4, 32))
    k_pool, k_s = quantize_kv(jax.random.normal(ks[1], (4, 2, 64, 32)))
    v_pool, v_s = quantize_kv(jax.random.normal(ks[2], (4, 2, 64, 32)))
    tbl = jnp.asarray([[1, 2], [0, 0]], jnp.int32)
    lens = jnp.asarray([[64, 7], [0, 0]], jnp.int32)
    out = paged_decode_quant(q, k_pool, v_pool, k_s[..., 0], v_s[..., 0],
                             tbl, lens)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)


def test_paged_decode_quant_op_model_layout(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k_pool, k_s = quantize_kv(jax.random.normal(ks[1], (6, 2, 64, 32)))
    v_pool, v_s = quantize_kv(jax.random.normal(ks[2], (6, 2, 64, 32)))
    tbl = jnp.asarray([[0, 3], [5, 0]], jnp.int32)
    lens = jnp.asarray([[64, 10], [30, 0]], jnp.int32)
    out = paged_decode_quant_op(q, k_pool, v_pool, k_s[..., 0], v_s[..., 0],
                                tbl, lens, interpret=True)
    expect = ref.paged_decode_quant_ref(q[:, 0], k_pool, v_pool, k_s[..., 0],
                                        v_s[..., 0], tbl, lens)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# int8 serving: quality bound vs bf16, parity paged vs dense
# ---------------------------------------------------------------------------

def test_int8_quality_within_rel_bound_of_bf16(setup):
    """The stated end-to-end quality bound: int8 artifacts shift
    teacher-forced logits < 10% rel of the bf16 path (typically ~1%)."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        e8 = _engine(model, params, FlashKVStore(d + "/8"), mode="matkv",
                     codec="int8")
        eb = _engine(model, params, FlashKVStore(d + "/b"), mode="matkv",
                     codec="bf16")
        buf = 192
        rel = teacher_forced_rel(eb, dense_row_path(eb, buf),
                                 e8, dense_row_path(e8, buf),
                                 QUESTIONS[0], steps=4,
                                 require_same_first=False)
        assert rel < 0.10, f"int8 shifted logits {rel:.3f} rel vs bf16"


def test_paged_int8_matches_dense_int8_at_logits_level(setup):
    """Acceptance bar: the paged int8 path (int8 pages + quantized tail)
    tracks the non-paged int8 engine path within 5% rel, teacher-forced,
    and agrees on the first token."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv",
                      codec="int8")
        buf = 192
        rel = teacher_forced_rel(eng, dense_row_path(eng, buf),
                                 eng, paged_row_path(eng, buf,
                                                     block_size=32),
                                 QUESTIONS[0], steps=6)
        assert rel < 0.05, f"paged int8 drifted {rel:.3f} rel from dense"


def test_paged_int8_scheduler_answers_match_dense_engine(setup):
    """End to end: ContinuousScheduler(paged=True) over an int8 engine
    returns the same answers as the single-request int8 path, reading each
    unique chunk once."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv", codec="int8")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            refs = [eng.answer(q, max_new_tokens=5)[0] for q in QUESTIONS]
            cont = ContinuousScheduler(eng, max_slots=2, paged=True,
                                       block_size=32)
            ans, m = cont.run(QUESTIONS, max_new_tokens=5)
            cont.shutdown()
        assert ans == refs
        assert m.chunk_misses == len({c for q in QUESTIONS
                                      for c in eng.retrieve(q)})
        assert m.hbm_kv_bytes_resident > 0
