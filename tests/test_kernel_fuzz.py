"""Kernel-oracle fuzz harness for the paged-decode family (DESIGN.md §13).

Randomized property sweep running ``paged_decode``, ``paged_decode_quant``
and the fused single-launch kernels against their ``ref.py`` oracles over
ragged row lengths, block sizes, KV-head group sizes (MQA/GQA/MHA) and
codecs. Two engines drive the same parameterized checkers:

* an always-on seeded numpy sweep — deterministic parameter draws from a
  fixed-seed generator, bounded example budget — so the properties run even
  where hypothesis isn't installed;
* a hypothesis sweep (CI installs hypothesis) exploring the same space with
  ``derandomize=True`` (seeded, reproducible) and deterministic shrinking
  to a minimal failing geometry.

Every drawn case plants the known hard boundaries on top of the random
raggedness: a fully-masked (empty) row, a full first block, and a
zero-length trailing table entry. The fused checkers cover both codecs
(bf16 pool; int8 pool + f16 per-vector scales dequantized in VMEM) and both
activation dtypes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import quantize_kv
from repro.kernels import ref
from repro.kernels.paged_decode import paged_decode
from repro.kernels.paged_decode_fused import (paged_decode_fused,
                                              paged_decode_fused_quant)
from repro.kernels.paged_decode_quant import paged_decode_quant

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # local envs without hypothesis: numpy sweep only
    HAVE_HYPOTHESIS = False

TOLS = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
        jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}
_DTYPES = [jnp.float32, jnp.bfloat16]

# jitted oracle: under jit XLA contracts acc*alpha + dot to the same FMA the
# kernel uses, giving bit-equality where eager op-by-op drift would not
_quant_ref = jax.jit(ref.paged_decode_quant_ref)


def _ragged_tables(rng, b, n_max, block, n_pool):
    """Random page tables + ragged lens with the hard boundaries planted:
    row 0 starts with a full block, the last table entry is empty, and the
    last row (when b > 1) is fully masked."""
    tbl = rng.integers(0, n_pool, (b, n_max)).astype(np.int32)
    lens = rng.integers(0, block + 1, (b, n_max)).astype(np.int32)
    lens[0, 0] = block
    lens[:, n_max - 1] = rng.integers(0, 2) * lens[:, n_max - 1]
    if b > 1:
        lens[b - 1] = 0                      # empty row: attends to nothing
    return jnp.asarray(tbl), jnp.asarray(lens), lens


def _check_legacy(seed, b, kvh, group, hd, block, n_max, dt_idx, quant):
    """paged_decode / paged_decode_quant vs oracle on the (N,KV,block,hd)
    pool layout with per-entry ragged lens."""
    dtype = _DTYPES[dt_idx]
    rng = np.random.default_rng(seed)
    n_pool = n_max + 2
    h = kvh * group
    q = jnp.asarray(rng.standard_normal((b, h, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((n_pool, kvh, block, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((n_pool, kvh, block, hd)), dtype)
    tbl, lens, _ = _ragged_tables(rng, b, n_max, block, n_pool)
    if quant:
        k8, ks = quantize_kv(k)
        v8, vs = quantize_kv(v)
        ks = ks[..., 0].astype(jnp.float16)
        vs = vs[..., 0].astype(jnp.float16)
        out = paged_decode_quant(q, k8, v8, ks, vs, tbl, lens)
        expect = _quant_ref(q, k8, v8, ks, vs, tbl, lens)
    else:
        out = paged_decode(q, k, v, tbl, lens)
        expect = ref.paged_decode_ref(q, k, v, tbl, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOLS[dtype])
    if b > 1:       # the planted empty row must be exact zeros, not garbage
        np.testing.assert_array_equal(np.asarray(out[b - 1], np.float32), 0.0)


def _check_fused(seed, b, kvh, group, hd, block, n_max, dt_idx, quant):
    """Fused single-launch kernel vs its dense-softmax oracle on the serving
    pool layout (n_blocks, block, KV, hd), dense-order tables + new token."""
    dtype = _DTYPES[dt_idx]
    rng = np.random.default_rng(seed)
    n_blocks = n_max + 2
    buf = n_max * block
    h = kvh * group
    q = jnp.asarray(rng.standard_normal((b, h, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((n_blocks, block, kvh, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((n_blocks, block, kvh, hd)), dtype)
    kn = jnp.asarray(rng.standard_normal((b, kvh, hd)), dtype)
    vn = jnp.asarray(rng.standard_normal((b, kvh, hd)), dtype)
    tbl, lens, lens_np = _ragged_tables(rng, b, n_max, block, n_blocks)
    totals = jnp.asarray(np.clip(lens_np.sum(1) + 1, 1, buf), jnp.int32)
    if quant:
        k8, ks = quantize_kv(k)
        v8, vs = quantize_kv(v)
        ks = ks[..., 0].astype(jnp.float16)
        vs = vs[..., 0].astype(jnp.float16)
        out = paged_decode_fused_quant(q, k8, v8, ks, vs, kn, vn, tbl, lens,
                                       totals, buf_size=buf)
        expect = ref.paged_decode_fused_ref(q, k8, v8, kn, vn, tbl, lens,
                                            totals, buf_size=buf,
                                            k_scale=ks, v_scale=vs)
    else:
        out = paged_decode_fused(q, k, v, kn, vn, tbl, lens, totals,
                                 buf_size=buf)
        expect = ref.paged_decode_fused_ref(q, k, v, kn, vn, tbl, lens,
                                            totals, buf_size=buf)
    # the fused kernel replays the oracle's exact dense-order op sequence
    # (same staged view, same masked softmax) — bit-equal for grouped
    # layouts. group == 1 (MHA) degenerates the q x K dot to M=1, which XLA
    # lowers with a different accumulation order than the kernel's
    # dot_general (same caveat paged_decode_quant_ref documents): ulp-scale
    # drift, so tolerance there
    if group > 1:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    else:
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(expect, np.float32),
                                   **TOLS[dtype])


_CHECKERS = {"legacy": _check_legacy, "fused": _check_fused}


def _draw_np(rng):
    """One random geometry from the shared parameter space."""
    return dict(b=int(rng.integers(1, 4)),
                kvh=int(rng.integers(1, 4)),
                group=int(rng.choice([1, 2, 4])),   # MQA / GQA / MHA
                hd=int(rng.choice([8, 16, 32])),
                block=int(rng.choice([4, 8, 16])),
                n_max=int(rng.integers(1, 5)),
                dt_idx=int(rng.integers(0, 2)))


N_NUMPY_EXAMPLES = 6      # per (kernel family x codec): bounded tier-1 budget


@pytest.mark.parametrize("family", sorted(_CHECKERS))
@pytest.mark.parametrize("quant", [False, True])
def test_kernel_oracle_numpy_sweep(family, quant):
    """Always-on deterministic sweep (fixed seed, fixed budget)."""
    rng = np.random.default_rng(0xC0DEC + (family == "fused") * 7 + quant)
    for i in range(N_NUMPY_EXAMPLES):
        params = _draw_np(rng)
        if quant:
            params["dt_idx"] = 0             # int8 pages dequantize to f32
        seed = int(rng.integers(0, 2**31 - 1))
        try:
            _CHECKERS[family](seed, quant=quant, **params)
        except AssertionError as e:
            raise AssertionError(
                f"kernel-oracle mismatch: family={family} quant={quant} "
                f"seed={seed} params={params}") from e


if HAVE_HYPOTHESIS:
    _geometry = dict(
        seed=st.integers(0, 2**31 - 1),
        b=st.integers(1, 3),
        kvh=st.integers(1, 3),
        group=st.sampled_from([1, 2, 4]),
        hd=st.sampled_from([8, 16, 32]),
        block=st.sampled_from([4, 8, 16]),
        n_max=st.integers(1, 4),
    )

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(dt_idx=st.integers(0, 1), **_geometry)
    def test_paged_decode_matches_oracle_hyp(seed, b, kvh, group, hd, block,
                                             n_max, dt_idx):
        _check_legacy(seed, b, kvh, group, hd, block, n_max, dt_idx,
                      quant=False)

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(**_geometry)
    def test_paged_decode_quant_matches_oracle_hyp(seed, b, kvh, group, hd,
                                                   block, n_max):
        _check_legacy(seed, b, kvh, group, hd, block, n_max, 0, quant=True)

    @settings(max_examples=10, deadline=None, derandomize=True)
    @given(quant=st.booleans(), **_geometry)
    def test_fused_decode_matches_oracle_hyp(seed, b, kvh, group, hd, block,
                                             n_max, quant):
        _check_fused(seed, b, kvh, group, hd, block, n_max, 0, quant=quant)
else:
    @pytest.mark.skip(reason="hypothesis not installed; the seeded numpy "
                             "sweep above covers the same properties")
    def test_hypothesis_sweep_placeholder():
        pass
