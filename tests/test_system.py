"""End-to-end behaviour of the MatKV RAG system (paper Fig. 3 lifecycle)."""

import tempfile

import jax
import pytest

from repro.configs import get_config
from repro.kvstore import FlashKVStore, SimulatedReader
from repro.models import build_model
from repro.serving import BatchScheduler, RagEngine

DOCS = {
    "d1": "the amber key is under the blue mat. " * 4,
    "d2": "the cedar door opens with a brass song. " * 4,
    "d3": "the quartz lamp hums beside the window. " * 4,
}


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _engine(model, params, store, **kw):
    kw.setdefault("top_k", 2)
    eng = RagEngine(model, params, store, chunk_tokens=48, **kw)
    for d, text in DOCS.items():
        eng.ingest(d, text)
    return eng


def test_vanilla_vs_matkv_same_greedy_answer_single_doc(setup):
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        ev = _engine(model, params, store, mode="vanilla", top_k=1)
        em = _engine(model, params, store, mode="matkv", top_k=1)
        cids = em.retrieve("where is the amber key?")[:1]
        a_v, _ = ev.answer("where is the amber key?", chunk_ids=cids,
                           max_new_tokens=6)
        a_m, _ = em.answer("where is the amber key?", chunk_ids=cids,
                           max_new_tokens=6)
        assert a_v == a_m  # exact positional match for a single chunk


def test_matkv_phase_timings_recorded(setup):
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        _, t = eng.answer("where is the cedar door?", max_new_tokens=4)
        assert t.load_s > 0 and t.prefill_s > 0 and t.decode_s > 0
        assert t.kv_bytes_loaded > 0
        assert t.n_doc_tokens == 2 * 48


def test_ingest_is_idempotent_and_delete_removes_kv(setup):
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        puts_before = store.stats.puts
        eng.ingest("d1", DOCS["d1"])  # identical content -> chunk dedupe
        assert store.stats.puts == puts_before
        cid = eng.retrieve("amber key")[0]
        eng.delete(cid)
        assert not store.exists(cid)   # paper §IV delete(O)
        assert cid not in eng.retrieve("amber key")


def test_cacheblend_mode_runs(setup):
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="cacheblend",
                      blend_ratio=0.25)
        ans, t = eng.answer("where is the quartz lamp?", max_new_tokens=4)
        assert isinstance(ans, str)
        assert t.prefill_s > 0


def test_rerotate_mode_runs(setup):
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv",
                      rerotate=True)
        ans, _ = eng.answer("where is the amber key?", max_new_tokens=4)
        assert isinstance(ans, str)


def test_quantized_engine_runs(setup):
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv", codec="int8")
        ans, t = eng.answer("where is the amber key?", max_new_tokens=4)
        assert isinstance(ans, str)
        # quantized artifacts are smaller than the bf16 KV would be
        cid = store.list_ids()[0]
        bf16_kv_bytes = cfg.kv_bytes_per_token() * 48
        assert store.size_bytes(cid) < bf16_kv_bytes


def test_batch_scheduler_overlap_equivalence(setup):
    """Overlapped and serialized scheduling must give identical answers."""
    cfg, model, params = setup
    qs = ["where is the amber key?", "where is the cedar door?",
          "where is the quartz lamp?", "where is the amber key?"]
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        base = BatchScheduler(eng, batch_size=2, overlap=False)
        over = BatchScheduler(eng, batch_size=2, overlap=True)
        a1, t1 = base.run(qs, max_new_tokens=4)
        a2, t2 = over.run(qs, max_new_tokens=4)
        assert a1 == a2
        assert t1.kv_bytes_loaded == t2.kv_bytes_loaded > 0


def test_ssm_engine_prefix_and_chain(setup):
    """SSM serving: chunk-1 state loads from flash; later chunks chain."""
    cfg = get_config("falcon-mamba-7b").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv", top_k=2)
        ans, t = eng.answer("where is the amber key?", max_new_tokens=4)
        assert isinstance(ans, str)
        assert t.kv_bytes_loaded > 0


def test_simulated_reader_slows_load_phase(setup):
    cfg, model, params = setup
    from repro.core.economics import SsdSpec
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng_fast = _engine(model, params, store, mode="matkv")
        # 0.2 MB/s: the simulated sleep (~0.5s) dominates host-side work even
        # on a loaded CI machine, keeping the ordering assertion robust
        slow_reader = SimulatedReader(store, SsdSpec("slow", 0.1, 0.0002, 5.0))
        eng_slow = RagEngine(model, params, store, mode="matkv",
                             chunk_tokens=48, top_k=2, reader=slow_reader)
        eng_slow._chunks = eng_fast._chunks
        eng_slow.vdb = eng_fast.vdb
        # warm both engines: the first answer() pays one-time XLA dispatch /
        # compile inside its load phase, which otherwise swamps the
        # simulated-bandwidth sleep being asserted on
        eng_fast.answer("where is the amber key?", max_new_tokens=2)
        eng_slow.answer("where is the amber key?", max_new_tokens=2)
        _, t_fast = eng_fast.answer("where is the amber key?", max_new_tokens=2)
        _, t_slow = eng_slow.answer("where is the amber key?", max_new_tokens=2)
        assert t_slow.load_s > t_fast.load_s
