"""Whole-tree import smoke test + quickstart end-to-end.

The seed's failure mode was an entire test suite dead at collection because
one module (`repro.dist`) didn't exist. This test imports EVERY module under
``src/repro`` so the next missing-module (or syntax/import-cycle) regression
is caught at one glance, and runs ``examples/quickstart.py`` — the full
materialize -> store -> compose -> decode pipeline under a reduced config —
as a subprocess.
"""

import importlib
import os
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def _all_modules():
    for py in sorted((SRC / "repro").rglob("*.py")):
        rel = py.relative_to(SRC).with_suffix("")
        parts = list(rel.parts)
        if parts[-1] == "__init__":
            parts = parts[:-1]
        yield ".".join(parts)


def test_every_repro_module_imports():
    # repro.launch.dryrun mutates XLA_FLAGS at import (it must run before
    # jax init in its own process); keep this test side-effect free for the
    # other subprocess-spawning tests.
    saved = os.environ.get("XLA_FLAGS")
    mods = list(_all_modules())
    assert len(mods) > 50, "src/repro tree looks truncated"
    try:
        for mod in mods:
            importlib.import_module(mod)
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved


def test_quickstart_runs_reduced():
    existing = os.environ.get("PYTHONPATH")
    env = {**os.environ,
           "PYTHONPATH": "src" + (os.pathsep + existing if existing else "")}
    env.pop("XLA_FLAGS", None)  # single CPU device, whatever ran before
    proc = subprocess.run(
        [sys.executable, "examples/quickstart.py"],
        capture_output=True, text=True, timeout=900, cwd=REPO, env=env)
    assert proc.returncode == 0, proc.stderr[-3000:]
    out = proc.stdout
    for needle in ("[matkv", "[vanilla", "[cacheblend", "ten-day rule"):
        assert needle in out, f"missing {needle!r} in quickstart output:\n{out}"
