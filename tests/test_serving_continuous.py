"""Continuous-batching serving core + the serving/IO bug-cluster regressions.

Prompt lengths in CORPUS are deliberately equal (24 bytes per question) so the
fixed BatchScheduler's right-padding is a no-op and fixed-vs-continuous answer
parity is exact.
"""

import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.materialize import load_artifact
from repro.data.tokenizer import EOS
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.serving import BatchScheduler, ContinuousScheduler, RagEngine

CORPUS = {
    "d1": "the amber gate stands in hall nine beyond the long stair. " * 4,
    "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
    "d3": "the brass lamp hums beside the tall window all night. " * 4,
}
QUESTIONS = ["where is the amber gate?", "where is the cedar door?",
             "where is the brass lamp?"]


@pytest.fixture(autouse=True)
def _lockdep(lock_order):
    """Run under the lock-order detector (conftest ``lock_order``): any
    acquisition-order cycle observed during the test fails it."""
    yield


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _engine(model, params, store, **kw):
    kw.setdefault("top_k", 2)
    eng = RagEngine(model, params, store, chunk_tokens=48, **kw)
    for d, text in CORPUS.items():
        eng.ingest(d, text)
    return eng


# ---------------------------------------------------------------------------
# continuous scheduler behaviour
# ---------------------------------------------------------------------------

def test_continuous_matches_single_request_answers(setup):
    """Per-row answers under continuous batching must be identical to the
    single-request RagEngine.answer path (the acceptance bar)."""
    cfg, model, params = setup
    qs = [QUESTIONS[i % 3] for i in range(5)]
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        refs = [eng.answer(q, max_new_tokens=6)[0] for q in qs]
        cont = ContinuousScheduler(eng, max_slots=2)
        ans, m = cont.run(qs, max_new_tokens=6)
        cont.shutdown()
        assert ans == refs
        assert m.n_requests == 5 and len(m.latencies_s) == 5
        assert m.kv_bytes_loaded > 0


def test_continuous_fixed_parity_and_mixed_lengths(setup):
    """Fixed and continuous scheduling agree (equal-length prompts), with
    per-request decode budgets under continuous matching per-request
    single-engine runs."""
    cfg, model, params = setup
    qs = list(QUESTIONS)
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        fixed = BatchScheduler(eng, batch_size=3, overlap=True)
        a_fixed, _ = fixed.run(qs, max_new_tokens=5)
        cont = ContinuousScheduler(eng, max_slots=3)
        a_cont, _ = cont.run(qs, max_new_tokens=5)
        assert a_cont == a_fixed
        # mixed per-request budgets: each row matches its own reference
        mixed = [3, 7, 5]
        refs = [eng.answer(q, max_new_tokens=n)[0]
                for q, n in zip(qs, mixed)]
        ans, _ = cont.run(qs, max_new_tokens=mixed,
                          arrivals_s=[0.0, 0.005, 0.01])
        cont.shutdown()
        assert ans == refs


def test_continuous_mixed_final_chunk_lengths_one_batch(setup):
    """Rows whose retrieval includes a short final chunk coexist in one
    row-slotted batch with full-chunk rows and still answer exactly."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        # a short doc whose tail chunk is ragged (68 tokens -> 48 + 20)
        tail_cids = eng.ingest(
            "tail", "the zinc helm waits under the ninth arch today.  "
                    "only the zinc helm.")
        q_tail = "where is the zinc helm today?"
        orig = eng.retrieve
        eng.retrieve = lambda q: (list(tail_cids) if "zinc" in q else orig(q))
        lens = [load_artifact(cfg, store.get(c))[1]["n_tokens"]
                for c in tail_cids]
        assert any(l < 48 for l in lens), f"setup: no short chunk in {lens}"
        qs = [q_tail, QUESTIONS[0]]
        refs = [eng.answer(q, max_new_tokens=5)[0] for q in qs]
        cont = ContinuousScheduler(eng, max_slots=2)
        ans, _ = cont.run(qs, max_new_tokens=5)
        cont.shutdown()
        assert ans == refs


def test_continuous_eos_early_eviction_frees_slot(setup):
    """A row forced to EOS mid-stream is evicted early (truncated answer) and
    neighbouring full-length rows are unaffected."""
    cfg, model, params = setup
    qs = [QUESTIONS[0], QUESTIONS[1]]
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        refs = [eng.answer(q, max_new_tokens=8)[0] for q in qs]
        # reference token stream for row 0 (to predict the truncated answer)
        req = eng.prepare_request(qs[0], 8)
        row, _, _ = eng.compose_row(req, 160)
        from repro.serving.sampling import greedy
        first, row = eng.prefill_row(row, req.prompt)
        toks = [int(first[0])]
        cur = first
        for _ in range(7):
            lg, row = eng.step_rows(row, cur[:, None])
            cur = greedy(lg[:, -1])
            toks.append(int(cur[0]))
        expect_row0 = eng.tok.decode(toks[:2])   # EOS forced as 3rd token

        orig_step = eng.step_rows
        calls = {"n": 0}

        def forced(cache, tokens):
            logits, cache = orig_step(cache, tokens)
            calls["n"] += 1
            if calls["n"] >= 2:                  # from the 2nd decode step on
                logits = jnp.asarray(np.asarray(logits))
                logits = logits.at[0, :, EOS].set(1e9)  # slot 0 -> EOS
            return logits, cache
        eng.step_rows = forced
        try:
            cont = ContinuousScheduler(eng, max_slots=2)
            ans, m = cont.run(qs, max_new_tokens=8)
            cont.shutdown()
        finally:
            eng.step_rows = orig_step
        assert ans[0] == expect_row0             # truncated at forced EOS
        assert ans[1] == refs[1]                 # neighbour unaffected
        # early eviction: row 0 emitted 3 tokens (incl. EOS), row 1 all 8
        assert m.n_new_tokens == 3 + 8


def test_continuous_backfills_freed_slots(setup):
    """More requests than slots: later requests are admitted as earlier rows
    finish, and every answer still matches its single-request reference."""
    cfg, model, params = setup
    qs = [QUESTIONS[i % 3] for i in range(6)]
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        refs = [eng.answer(q, max_new_tokens=4)[0] for q in qs]
        cont = ContinuousScheduler(eng, max_slots=2)
        ans, m = cont.run(qs, max_new_tokens=4)
        cont.shutdown()
        assert ans == refs
        assert m.n_new_tokens == 4 * 6


# ---------------------------------------------------------------------------
# bug-cluster regressions: empty retrieval
# ---------------------------------------------------------------------------

def test_engine_answer_empty_retrieval_matkv(setup):
    """matkv-mode answer() with chunk_ids == [] serves query-only instead of
    crashing in compose."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        with pytest.warns(UserWarning, match="no chunks"):
            ans, t = eng.answer("where is the amber gate?", chunk_ids=[],
                                max_new_tokens=4)
        assert isinstance(ans, str)
        assert t.n_doc_tokens == 0 and t.kv_bytes_loaded == 0


def test_batch_scheduler_empty_retrieval_no_crash(setup):
    """Empty retrieval used to IndexError in _load_batch (cids[-1] on []);
    now those rows fall back to query-only answers."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        # no documents ingested -> every retrieval is empty
        eng = RagEngine(model, params, FlashKVStore(d), mode="matkv",
                        chunk_tokens=48, top_k=2)
        sched = BatchScheduler(eng, batch_size=2, overlap=False)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            ans, _ = sched.run(["anything?", "else gone?"], max_new_tokens=3)
        assert len(ans) == 2 and all(isinstance(a, str) for a in ans)


def test_batch_scheduler_mixed_empty_and_real_rows(setup):
    """One empty-retrieval row inside an otherwise loadable batch: the real
    rows keep the fixed-geometry path and match their solo answers."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        orig = eng.retrieve
        eng.retrieve = lambda q: [] if "nothing" in q else orig(q)
        ref, _ = eng.answer(QUESTIONS[0], max_new_tokens=3)
        sched = BatchScheduler(eng, batch_size=2, overlap=True)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            ans, t = sched.run(["where is nothing here??", QUESTIONS[0]],
                               max_new_tokens=3)
        assert all(isinstance(a, str) for a in ans)
        assert ans[1] == ref
        assert t.kv_bytes_loaded > 0


def test_continuous_empty_retrieval_row(setup):
    """Query-only rows (empty retrieval) serve alongside loaded rows under
    the continuous scheduler."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        orig = eng.retrieve
        eng.retrieve = lambda q: [] if "nothing" in q else orig(q)
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            ref_empty, _ = eng.answer("where is nothing here??",
                                      chunk_ids=[], max_new_tokens=4)
            ref_full, _ = eng.answer(QUESTIONS[1], max_new_tokens=4)
            cont = ContinuousScheduler(eng, max_slots=2)
            ans, _ = cont.run(["where is nothing here??", QUESTIONS[1]],
                              max_new_tokens=4)
            cont.shutdown()
        assert ans == [ref_empty, ref_full]


# ---------------------------------------------------------------------------
# bug-cluster regressions: n_doc_tokens over-report
# ---------------------------------------------------------------------------

def test_answer_reports_true_doc_tokens_for_short_final_chunk(setup):
    """matkv answer() used to report len(chunk_ids) * chunk_tokens, silently
    over-counting short final chunks; it must report the composed length."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = RagEngine(model, params, store, mode="matkv",
                        chunk_tokens=48, top_k=2)
        cids = eng.ingest("short", "x" * 60)     # chunks of 48 + 12 tokens
        assert len(cids) == 2
        _, t = eng.answer("where is x?", chunk_ids=cids, max_new_tokens=3)
        assert t.n_doc_tokens == 60              # not 2 * 48 = 96


# ---------------------------------------------------------------------------
# bug-cluster regressions: post-EOS padding counted as useful tokens
# ---------------------------------------------------------------------------

def test_batch_scheduler_counts_only_emitted_tokens(setup):
    """_serve_batch used to add ``max_new_tokens * B`` to n_new_tokens —
    post-EOS padding decoded by the fixed-shape loop inflated the reported
    tok/s. It must count per-row tokens actually emitted through EOS,
    aligned with ContinuousScheduler's ``len(r.tokens)`` accounting."""
    cfg, model, params = setup
    qs = [QUESTIONS[0], QUESTIONS[1]]
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        orig = eng._decode_loop

        def forced(cache, first, max_new):
            toks, cache = orig(cache, first, max_new)
            toks = [np.array(t) for t in toks]
            toks[2][0] = EOS             # row 0 emits EOS as its 3rd token
            return toks, cache

        eng._decode_loop = forced
        try:
            sched = BatchScheduler(eng, batch_size=2, overlap=False)
            _, t = sched.run(qs, max_new_tokens=6)
        finally:
            eng._decode_loop = orig
        # row 0: 3 emitted tokens (incl. EOS); row 1: all 6 — not 2 * 6
        assert t.n_new_tokens == 3 + 6
