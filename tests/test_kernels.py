"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import quantize_kv
from repro.kernels import ref
from repro.kernels.chunked_decode import chunked_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.kv_dequant import kv_dequant
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.ops import (chunked_decode_op, flash_prefill_op,
                               kv_dequant_op, mamba_scan_op)

TOLS = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
        jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("b,h,kv,s,hd", [
    (1, 4, 2, 256, 64),
    (2, 8, 8, 128, 32),   # MHA
    (1, 9, 3, 128, 64),   # smollm-style GQA (odd heads)
    (1, 4, 1, 256, 128),  # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(rng_key, b, h, kv, s, hd, dtype):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    out = flash_prefill(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("window", [None, 64])
def test_flash_prefill_window(rng_key, window):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 32))
    k = jax.random.normal(ks[1], (1, 2, 256, 32))
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    out = flash_prefill(q, k, v, window=window, block_q=64, block_k=64)
    expect = ref.flash_prefill_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,h,kv,s,hd,clen,win", [
    (2, 8, 2, 512, 64, 300, None),
    (1, 4, 4, 1024, 32, 1024, None),   # cache exactly full
    (1, 4, 4, 1024, 32, 700, 256),     # windowed
    (2, 2, 1, 256, 128, 1, None),      # nearly-empty cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_decode_sweep(rng_key, b, h, kv, s, hd, clen, win, dtype):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    out = chunked_decode(q, k, v, clen, window=win, block_k=128)
    expect = ref.chunked_decode_ref(q, k, v, clen, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("n,hd", [(256, 64), (512, 128), (1024, 32)])
@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
def test_kv_dequant_sweep(rng_key, n, hd, out_dtype):
    x = jax.random.normal(rng_key, (n, hd)) * 3.0
    q8, sc = quantize_kv(x)
    out = kv_dequant(np.asarray(q8), np.asarray(sc), out_dtype=out_dtype,
                     block_rows=128)
    expect = ref.kv_dequant_ref(q8, sc, out_dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("b,s,din,st,bd,bt", [
    (1, 128, 64, 16, 32, 32),
    (2, 256, 128, 8, 64, 128),
    (1, 64, 256, 16, 256, 64),
])
def test_mamba_scan_sweep(rng_key, b, s, din, st, bd, bt):
    ks = jax.random.split(rng_key, 6)
    x = jax.random.normal(ks[0], (b, s, din))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, din)) * 0.5 - 1.0)
    bm = jax.random.normal(ks[2], (b, s, st))
    cm = jax.random.normal(ks[3], (b, s, st))
    alog = jnp.log(jnp.abs(jax.random.normal(ks[4], (din, st))) + 0.5)
    h0 = jax.random.normal(ks[5], (b, din, st))
    y, h = mamba_scan(x, dt, bm, cm, alog, h0, block_d=bd, block_t=bt)
    ye, he = ref.mamba_scan_ref(x, dt, bm, cm, alog, jnp.zeros((din,)), h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he),
                               rtol=2e-4, atol=2e-4)


def test_mamba_scan_state_chaining(rng_key):
    """Chunked execution with carried state == one long scan (the MatKV
    prefix-state property for SSMs)."""
    ks = jax.random.split(rng_key, 6)
    b, s, din, st = 1, 128, 64, 8
    x = jax.random.normal(ks[0], (b, s, din))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, din)) * 0.3)
    bm = jax.random.normal(ks[2], (b, s, st))
    cm = jax.random.normal(ks[3], (b, s, st))
    alog = jnp.log(jnp.abs(jax.random.normal(ks[4], (din, st))) + 0.5)
    h0 = jnp.zeros((b, din, st))
    _, h_full = mamba_scan(x, dt, bm, cm, alog, h0, block_d=64, block_t=32)
    half = s // 2
    _, h1 = mamba_scan(x[:, :half], dt[:, :half], bm[:, :half], cm[:, :half],
                       alog, h0, block_d=64, block_t=32)
    _, h2 = mamba_scan(x[:, half:], dt[:, half:], bm[:, half:], cm[:, half:],
                       alog, h1, block_d=64, block_t=32)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_ops_wrappers_model_layout(rng_key):
    """ops.py layout adapters agree with the model-layout jnp paths."""
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))     # (B,S,H,hd)
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    out = flash_prefill_op(q, k, v, interpret=True)
    expect = ref.flash_prefill_ref(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(expect.transpose(0, 2, 1, 3)),
                               rtol=3e-5, atol=3e-5)

    qd = jax.random.normal(ks[0], (2, 1, 4, 32))
    cache_k = jax.random.normal(ks[1], (2, 128, 2, 32))
    cache_v = jax.random.normal(ks[2], (2, 128, 2, 32))
    out = chunked_decode_op(qd, cache_k, cache_v, 100, interpret=True)
    expect = ref.chunked_decode_ref(qd[:, 0], cache_k.transpose(0, 2, 1, 3),
                                    cache_v.transpose(0, 2, 1, 3), 100)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)
