"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantize import quantize_kv
from repro.kernels import ref
from repro.kernels.chunked_decode import chunked_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.kv_dequant import kv_dequant
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.ops import (chunked_decode_op, flash_prefill_op,
                               paged_decode_op)
from repro.kernels.paged_decode import paged_decode

TOLS = {jnp.float32: dict(rtol=3e-5, atol=3e-5),
        jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


@pytest.mark.parametrize("b,h,kv,s,hd", [
    (1, 4, 2, 256, 64),
    (2, 8, 8, 128, 32),   # MHA
    (1, 9, 3, 128, 64),   # smollm-style GQA (odd heads)
    (1, 4, 1, 256, 128),  # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_prefill_sweep(rng_key, b, h, kv, s, hd, dtype):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, s, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    out = flash_prefill(q, k, v, block_q=64, block_k=64, interpret=True)
    expect = ref.flash_prefill_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("window", [None, 64])
def test_flash_prefill_window(rng_key, window):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 32))
    k = jax.random.normal(ks[1], (1, 2, 256, 32))
    v = jax.random.normal(ks[2], (1, 2, 256, 32))
    out = flash_prefill(q, k, v, window=window, block_q=64, block_k=64)
    expect = ref.flash_prefill_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("b,h,kv,s,hd,clen,win", [
    (2, 8, 2, 512, 64, 300, None),
    (1, 4, 4, 1024, 32, 1024, None),   # cache exactly full
    (1, 4, 4, 1024, 32, 700, 256),     # windowed
    (2, 2, 1, 256, 128, 1, None),      # nearly-empty cache
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunked_decode_sweep(rng_key, b, h, kv, s, hd, clen, win, dtype):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, kv, s, hd), dtype)
    v = jax.random.normal(ks[2], (b, kv, s, hd), dtype)
    out = chunked_decode(q, k, v, clen, window=win, block_k=128)
    expect = ref.chunked_decode_ref(q, k, v, clen, window=win)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOLS[dtype])


@pytest.mark.parametrize("b,h,kv,hd,block,n_pool,n_max", [
    (2, 8, 2, 64, 128, 10, 4),
    (1, 4, 4, 32, 64, 6, 3),    # MHA
    (2, 4, 1, 128, 128, 8, 2),  # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_sweep(rng_key, b, h, kv, hd, block, n_pool, n_max,
                            dtype):
    """Page-table decode vs the oracle: shared blocks (rows referencing the
    same pool pages), ragged interior blocks, and empty trailing blocks."""
    ks = jax.random.split(rng_key, 4)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    k_pool = jax.random.normal(ks[1], (n_pool, kv, block, hd), dtype)
    v_pool = jax.random.normal(ks[2], (n_pool, kv, block, hd), dtype)
    # every row shares block 1 (the "hot chunk"), with a ragged length mid-row
    tbl = np.zeros((b, n_max), np.int32)
    lens = np.zeros((b, n_max), np.int32)
    rng = np.random.default_rng(0)
    for i in range(b):
        tbl[i] = rng.permutation(n_pool)[:n_max]
        tbl[i, 0] = 1
        lens[i, 0] = block
        if n_max > 1:
            lens[i, 1] = block // 2          # ragged interior chunk tail
        if n_max > 2:
            lens[i, 2] = block               # full block after the ragged one
    out = paged_decode(q, k_pool, v_pool, jnp.asarray(tbl), jnp.asarray(lens))
    expect = ref.paged_decode_ref(q, k_pool, v_pool, jnp.asarray(tbl),
                                  jnp.asarray(lens))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **TOLS[dtype])


def test_paged_decode_bit_identical_to_chunked_decode(rng_key):
    """On a block-aligned layout (full blocks then a partial tail — a dense
    composed cache viewed through a page table) the paged kernel must agree
    with ``chunked_decode`` bit-for-bit: same per-block op sequence, same
    running-softmax state."""
    b, h, kv, hd, block, n_pool, n_max = 2, 8, 2, 64, 128, 10, 4
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    k_pool = jax.random.normal(ks[1], (n_pool, kv, block, hd))
    v_pool = jax.random.normal(ks[2], (n_pool, kv, block, hd))
    tbl = jnp.asarray([[3, 1, 4, 0], [7, 2, 0, 0]], jnp.int32)
    lens = jnp.asarray([[block, block, 44, 0], [block, 77, 0, 0]], jnp.int32)
    out = paged_decode(q, k_pool, v_pool, tbl, lens)
    for i in range(b):
        dense_k = k_pool[tbl[i]].transpose(1, 0, 2, 3).reshape(
            1, kv, n_max * block, hd)
        dense_v = v_pool[tbl[i]].transpose(1, 0, 2, 3).reshape(
            1, kv, n_max * block, hd)
        out_c = chunked_decode(q[i:i + 1], dense_k, dense_v,
                               int(lens[i].sum()), block_k=block)
        np.testing.assert_array_equal(np.asarray(out[i:i + 1]),
                                      np.asarray(out_c))


def test_paged_decode_fully_masked_row_outputs_zeros(rng_key):
    """A padding row (all block_lens 0) attends to nothing: both kernel and
    oracle must emit exact zeros, not the mean of the gathered garbage V."""
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 4, 32))
    k_pool = jax.random.normal(ks[1], (4, 2, 64, 32))
    v_pool = jax.random.normal(ks[2], (4, 2, 64, 32))
    tbl = jnp.asarray([[1, 2], [0, 0]], jnp.int32)
    lens = jnp.asarray([[64, 7], [0, 0]], jnp.int32)   # row 1 fully masked
    out = paged_decode(q, k_pool, v_pool, tbl, lens)
    expect = ref.paged_decode_ref(q, k_pool, v_pool, tbl, lens)
    np.testing.assert_array_equal(np.asarray(out[1]), 0.0)
    np.testing.assert_array_equal(np.asarray(expect[1]), 0.0)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(expect[0]),
                               rtol=3e-5, atol=3e-5)


def test_paged_decode_op_model_layout(rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 1, 4, 32))
    k_pool = jax.random.normal(ks[1], (6, 2, 64, 32))
    v_pool = jax.random.normal(ks[2], (6, 2, 64, 32))
    tbl = jnp.asarray([[0, 3], [5, 0]], jnp.int32)
    lens = jnp.asarray([[64, 10], [30, 0]], jnp.int32)
    out = paged_decode_op(q, k_pool, v_pool, tbl, lens, interpret=True)
    expect = ref.paged_decode_ref(q[:, 0], k_pool, v_pool, tbl, lens)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("n,hd", [(256, 64), (512, 128), (1024, 32)])
@pytest.mark.parametrize("out_dtype", [jnp.bfloat16, jnp.float32])
def test_kv_dequant_sweep(rng_key, n, hd, out_dtype):
    x = jax.random.normal(rng_key, (n, hd)) * 3.0
    q8, sc = quantize_kv(x)
    out = kv_dequant(np.asarray(q8), np.asarray(sc), out_dtype=out_dtype,
                     block_rows=128)
    expect = ref.kv_dequant_ref(q8, sc, out_dtype)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("n", [300, 65, 1])
def test_kv_dequant_ragged_rows(rng_key, n):
    """Regression: row counts not divisible by block_rows (any trimmed
    ragged chunk, e.g. 300 rows vs block 256) used to raise; the wrapper
    now pads to the block multiple and slices, and padded rows never leak
    into the output."""
    x = jax.random.normal(rng_key, (n, 64)) * 2.0
    q8, sc = quantize_kv(x)
    out = kv_dequant(np.asarray(q8), np.asarray(sc), block_rows=256)
    assert out.shape == (n, 64)
    expect = ref.kv_dequant_ref(q8, sc)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(expect, np.float32))


@pytest.mark.parametrize("b,s,din,st,bd,bt", [
    (1, 128, 64, 16, 32, 32),
    (2, 256, 128, 8, 64, 128),
    (1, 64, 256, 16, 256, 64),
])
def test_mamba_scan_sweep(rng_key, b, s, din, st, bd, bt):
    ks = jax.random.split(rng_key, 6)
    x = jax.random.normal(ks[0], (b, s, din))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, din)) * 0.5 - 1.0)
    bm = jax.random.normal(ks[2], (b, s, st))
    cm = jax.random.normal(ks[3], (b, s, st))
    alog = jnp.log(jnp.abs(jax.random.normal(ks[4], (din, st))) + 0.5)
    h0 = jax.random.normal(ks[5], (b, din, st))
    y, h = mamba_scan(x, dt, bm, cm, alog, h0, block_d=bd, block_t=bt)
    ye, he = ref.mamba_scan_ref(x, dt, bm, cm, alog, jnp.zeros((din,)), h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(he),
                               rtol=2e-4, atol=2e-4)


def test_mamba_scan_state_chaining(rng_key):
    """Chunked execution with carried state == one long scan (the MatKV
    prefix-state property for SSMs)."""
    ks = jax.random.split(rng_key, 6)
    b, s, din, st = 1, 128, 64, 8
    x = jax.random.normal(ks[0], (b, s, din))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, din)) * 0.3)
    bm = jax.random.normal(ks[2], (b, s, st))
    cm = jax.random.normal(ks[3], (b, s, st))
    alog = jnp.log(jnp.abs(jax.random.normal(ks[4], (din, st))) + 0.5)
    h0 = jnp.zeros((b, din, st))
    _, h_full = mamba_scan(x, dt, bm, cm, alog, h0, block_d=64, block_t=32)
    half = s // 2
    _, h1 = mamba_scan(x[:, :half], dt[:, :half], bm[:, :half], cm[:, :half],
                       alog, h0, block_d=64, block_t=32)
    _, h2 = mamba_scan(x[:, half:], dt[:, half:], bm[:, half:], cm[:, half:],
                       alog, h1, block_d=64, block_t=32)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full),
                               rtol=1e-4, atol=1e-4)


def test_ops_wrappers_model_layout(rng_key):
    """ops.py layout adapters agree with the model-layout jnp paths."""
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (2, 64, 4, 32))     # (B,S,H,hd)
    k = jax.random.normal(ks[1], (2, 64, 2, 32))
    v = jax.random.normal(ks[2], (2, 64, 2, 32))
    out = flash_prefill_op(q, k, v, interpret=True)
    expect = ref.flash_prefill_ref(q.transpose(0, 2, 1, 3),
                                   k.transpose(0, 2, 1, 3),
                                   v.transpose(0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(expect.transpose(0, 2, 1, 3)),
                               rtol=3e-5, atol=3e-5)

    qd = jax.random.normal(ks[0], (2, 1, 4, 32))
    cache_k = jax.random.normal(ks[1], (2, 128, 2, 32))
    cache_v = jax.random.normal(ks[2], (2, 128, 2, 32))
    out = chunked_decode_op(qd, cache_k, cache_v, 100, interpret=True)
    expect = ref.chunked_decode_ref(qd[:, 0], cache_k.transpose(0, 2, 1, 3),
                                    cache_v.transpose(0, 2, 1, 3), 100)
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(expect),
                               rtol=3e-5, atol=3e-5)
