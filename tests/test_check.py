"""Tests for repro.check: the reprolint analyzer (RP101–RP106), the noqa
protocol, the CLI, and the runtime lock-order detector.

The per-rule corpus lives in ``tests/fixtures/check/``: each ``rpNNN_bad.py``
is a minimized reproduction of the historical bug the rule encodes (see
DESIGN.md §17) and MUST be flagged; each ``rpNNN_good.py`` holds the
accepted idioms and MUST come back clean — that pair is the
failing-before-verified contract for the analyzer itself.
"""

import json
import textwrap
import threading
import time
from pathlib import Path

import pytest

from repro.check import (LockOrderError, LockOrderRegistry, TrackedLock,
                         check_paths, check_source, instrumented)
from repro.check.__main__ import main as check_main
from repro.check.lockorder import install, uninstall

FIXTURES = Path(__file__).parent / "fixtures" / "check"
REPO = Path(__file__).resolve().parents[1]


def run_fixture(name, select=None):
    src = (FIXTURES / name).read_text()
    return check_source(src, path=name, select=select)


# ---------------------------------------------------------------------------
# per-rule fixture corpus: bad flagged, good clean
# ---------------------------------------------------------------------------

RULE_EXPECTATIONS = [
    # (rule, bad fixture findings: (line, message fragment))
    ("RP101", [(9, "no release"), (18, "conditional or jumped over"),
               (26, "conditional or jumped over")]),
    ("RP102", [(15, "donated")]),
    ("RP103", [(13, "f.exception()"), (22, "f.result()")]),
    ("RP104", [(23, "_done"), (26, "_pending"), (34, "_pending")]),
    ("RP105", [(11, "host module"), (12, "print()"),
               (13, "closure variable"), (14, "float64")]),
    ("RP106", [(12, "time.perf_counter")]),
]


@pytest.mark.parametrize("code,expected", RULE_EXPECTATIONS,
                         ids=[c for c, _ in RULE_EXPECTATIONS])
def test_bad_fixture_flagged(code, expected):
    findings = run_fixture(f"{code.lower()}_bad.py")
    got = [(f.line, f.code) for f in findings]
    assert got == [(line, code) for line, _ in expected], findings
    for f, (_, frag) in zip(findings, expected):
        assert frag in f.message


@pytest.mark.parametrize("code", [c for c, _ in RULE_EXPECTATIONS])
def test_good_fixture_clean(code):
    assert run_fixture(f"{code.lower()}_good.py") == []


def test_bad_fixture_only_its_own_rule_fires():
    # cross-rule noise in the corpus would make the pairs above fragile
    for code, _ in RULE_EXPECTATIONS:
        findings = run_fixture(f"{code.lower()}_bad.py")
        assert {f.code for f in findings} == {code}, (code, findings)


def test_syntax_error_reports_rp000():
    findings = check_source("def broken(:\n", path="x.py")
    assert [f.code for f in findings] == ["RP000"]
    assert "syntax error" in findings[0].message


# ---------------------------------------------------------------------------
# noqa protocol
# ---------------------------------------------------------------------------

LEAK = textwrap.dedent("""\
    def f(pool, key):
        pages = pool.acquire(key){noqa}
        return pages
""")


def test_noqa_with_matching_code_suppresses():
    assert check_source(LEAK.format(noqa="  # repro: noqa[RP101]")) == []


def test_noqa_blanket_suppresses():
    assert check_source(LEAK.format(noqa="  # repro: noqa")) == []


def test_noqa_wrong_code_does_not_suppress():
    findings = check_source(LEAK.format(noqa="  # repro: noqa[RP104]"))
    assert [f.code for f in findings] == ["RP101"]


def test_noqa_on_any_line_of_multiline_statement():
    src = textwrap.dedent("""\
        def f(pool, key):
            pages = pool.acquire(
                key)  # repro: noqa[RP101] ownership moves to the caller
            return pages
    """)
    assert check_source(src) == []


def test_noqa_ignored_with_respect_noqa_false():
    src = LEAK.format(noqa="  # repro: noqa[RP101]")
    findings = check_source(src, respect_noqa=False)
    assert [f.code for f in findings] == ["RP101"]


def test_select_runs_only_named_rules():
    src = LEAK.format(noqa="")
    assert check_source(src, select=["RP103"]) == []
    assert [f.code for f in check_source(src, select=["RP101"])] == ["RP101"]


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_exit_codes(capsys):
    assert check_main([str(FIXTURES / "rp101_good.py")]) == 0
    assert check_main([str(FIXTURES / "rp101_bad.py")]) == 1
    assert check_main(["--select", "RP999", "."]) == 2
    assert check_main([str(FIXTURES / "no_such_file.py")]) == 2
    capsys.readouterr()


def test_cli_json_report(capsys):
    rc = check_main(["--format", "json", str(FIXTURES / "rp102_bad.py")])
    assert rc == 1
    report = json.loads(capsys.readouterr().out)
    assert report["schema"] == 1
    assert report["checked_files"] == 1
    assert [f["code"] for f in report["findings"]] == ["RP102"]
    assert {"code", "path", "line", "col", "message"} <= \
        set(report["findings"][0])


def test_cli_list_rules(capsys):
    assert check_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for code in ("RP101", "RP102", "RP103", "RP104", "RP105", "RP106"):
        assert code in out


def test_cli_no_noqa_surfaces_suppressed(tmp_path, capsys):
    p = tmp_path / "m.py"
    p.write_text(LEAK.format(noqa="  # repro: noqa[RP101]"))
    assert check_main([str(p)]) == 0
    assert check_main(["--no-noqa", str(p)]) == 1
    capsys.readouterr()


# ---------------------------------------------------------------------------
# the repo itself is clean — the CI gate this PR installs
# ---------------------------------------------------------------------------

def test_src_repro_is_clean():
    findings = check_paths([str(REPO / "src" / "repro")])
    assert findings == [], "\n".join(f.render() for f in findings)


# ---------------------------------------------------------------------------
# lock-order detector
# ---------------------------------------------------------------------------

def test_lockorder_consistent_order_is_clean():
    reg = LockOrderRegistry()
    a = TrackedLock(reg, name="A")
    b = TrackedLock(reg, name="B")
    for _ in range(3):
        with a:
            with b:
                pass
    reg.assert_clean()


def test_lockorder_cycle_detected_without_deadlocking():
    reg = LockOrderRegistry()
    a = TrackedLock(reg, name="A")
    b = TrackedLock(reg, name="B")
    with a:
        with b:
            pass
    with b:                      # reverse order, uncontended: no hang,
        with a:                  # but the graph now has a cycle
            pass
    assert reg.violations, "reverse acquisition order must be recorded"
    with pytest.raises(LockOrderError, match="cycle"):
        reg.assert_clean()


def test_lockorder_cycle_across_threads():
    reg = LockOrderRegistry()
    a = TrackedLock(reg, name="A")
    b = TrackedLock(reg, name="B")

    def forward():
        with a:
            with b:
                pass

    def backward():
        with b:
            with a:
                pass

    t = threading.Thread(target=forward)
    t.start()
    t.join()
    backward()                   # opposite order on the main thread
    with pytest.raises(LockOrderError):
        reg.assert_clean()


def test_lockorder_three_lock_cycle():
    reg = LockOrderRegistry()
    locks = [TrackedLock(reg, name=n) for n in "ABC"]
    for i in range(3):           # A->B, B->C, C->A
        with locks[i]:
            with locks[(i + 1) % 3]:
                pass
    with pytest.raises(LockOrderError):
        reg.assert_clean()


def test_lockorder_self_deadlock_detected():
    reg = LockOrderRegistry()
    a = TrackedLock(reg, name="A")
    # simulate re-entry on a non-reentrant lock without actually blocking
    reg.note_acquire("A")
    reg.note_acquire("A")
    reg.note_release("A")
    reg.note_release("A")
    assert any("self-deadlock" in v for v in reg.violations)
    assert not a.locked()


def test_lockorder_rlock_reentry_is_legal():
    reg = LockOrderRegistry()
    r = TrackedLock(reg, name="R", reentrant=True)
    with r:
        with r:
            pass
    reg.assert_clean()
    assert not r.locked()


def test_tracked_lock_is_a_real_lock():
    reg = LockOrderRegistry()
    lk = TrackedLock(reg, name="L")
    assert not lk.locked()
    hits = []

    def worker():
        with lk:
            hits.append(1)

    with lk:
        t = threading.Thread(target=worker)
        t.start()
        time.sleep(0.02)
        assert hits == []        # blocked: mutual exclusion holds
    t.join()
    assert hits == [1]
    reg.assert_clean()


def test_instrumented_shims_and_restores_module():
    import repro.kvstore.async_loader as mod
    original = mod.threading
    reg = LockOrderRegistry()
    with instrumented(reg, mod):
        lk = mod.threading.Lock()
        assert isinstance(lk, TrackedLock)
        with lk:
            pass
        assert mod.threading.current_thread() is threading.current_thread()
    assert mod.threading is original
    reg.assert_clean()


def test_instrumented_rejects_module_without_threading():
    import repro.check.core as mod
    reg = LockOrderRegistry()
    with pytest.raises(ValueError, match="does not import threading"):
        install(reg, [mod])


def test_install_uninstall_roundtrip():
    import repro.serving.queue as mod
    reg = LockOrderRegistry()
    original = mod.threading
    saved = install(reg, [mod])
    try:
        assert mod.threading is not original
    finally:
        uninstall(saved)
    assert mod.threading is original
