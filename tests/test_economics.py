"""The ten-day rule + cost model (paper §II-C, Eq. 1)."""


from repro.configs import get_config
from repro.core.economics import (H100, RTX4090, SAMSUNG_9100_PRO,
                                  break_even_interval_days,
                                  cost_ratio_per_access, kv_mb_per_gpu_second,
                                  load_cost, prefill_cost)


def test_ten_day_rule_headline():
    """H100 + 9100 Pro + LLaMA-70B ~ paper's 'ten-day rule' (~11.6 days)."""
    cfg = get_config("llama-3.1-70b")
    # paper's worked example: 1,024 tokens -> ~250MB in ~500ms => ~500MB/s.
    # With our analytical kv_bytes (335MB fp16) the rate is the same order.
    days = break_even_interval_days(H100, SAMSUNG_9100_PRO,
                                    cfg.kv_bytes_per_token(2))
    assert 5 <= days <= 20, days


def test_kv_rate_order_of_magnitude():
    cfg = get_config("llama-3.1-70b")
    rate = kv_mb_per_gpu_second(cfg.kv_bytes_per_token(2),
                                H100.prefill_tokens_per_s)
    assert 300 <= rate <= 1000  # paper: ~500 MB/s


def test_hourly_access_cost_ratio():
    """Paper: 1 access/hour -> MatKV ~100x more cost-efficient."""
    cfg = get_config("llama-3.1-70b")
    r = cost_ratio_per_access(H100, SAMSUNG_9100_PRO,
                              cfg.kv_bytes_per_token(2), 1024, 3600.0)
    assert 30 <= r <= 300, r


def test_prefill_vs_load_energy():
    """Paper §III-D: SSD load is orders of magnitude more energy-efficient."""
    cfg = get_config("llama-3.1-70b")
    _, j_gpu = prefill_cost(H100, 1024)
    _, j_ssd = load_cost(SAMSUNG_9100_PRO, cfg.kv_bytes_per_token(2) * 1024)
    assert j_gpu / j_ssd > 500


def test_smaller_model_longer_break_even():
    """Less KV compute per byte -> recompute is relatively cheaper -> the
    break-even interval SHORTENS for bigger models (more benefit)."""
    small = get_config("llama-3.2-3b")
    big = get_config("llama-3.1-70b")
    d_small = break_even_interval_days(H100, SAMSUNG_9100_PRO,
                                       small.kv_bytes_per_token(2))
    d_big = break_even_interval_days(H100, SAMSUNG_9100_PRO,
                                     big.kv_bytes_per_token(2))
    assert d_small > d_big


def test_low_end_gpu_changes_economics():
    cfg = get_config("llama-3.1-8b")
    d_h100 = break_even_interval_days(H100, SAMSUNG_9100_PRO,
                                      cfg.kv_bytes_per_token(2))
    d_4090 = break_even_interval_days(RTX4090, SAMSUNG_9100_PRO,
                                      cfg.kv_bytes_per_token(2))
    # cheap GPU => recompute cheaper => storage justified only at higher rates
    assert d_4090 < d_h100
