"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned arch runs one forward + one train step on CPU; output shapes are
checked and outputs/grads must be finite."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import build_model
from repro.training import (AdamWConfig, TrainConfig, init_state,
                            make_train_step)

ARCHS = sorted(ASSIGNED)


def _batch(cfg, model, key, b=2, s=24):
    if model.is_encdec:
        return {"frontend": jax.random.normal(key, (b, 16, cfg.d_model)),
                "tokens": jax.random.randint(key, (b, 8), 0, cfg.vocab_size),
                "labels": jax.random.randint(key, (b, 8), 0, cfg.vocab_size)}
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.frontend:
        batch["frontend"] = jax.random.normal(key, (b, 8, cfg.d_model))
        batch["tokens"] = batch["tokens"][:, :s - 8]
        batch["labels"] = batch["labels"][:, :s - 8]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = (model.init(rng_key, enc_len=16, dec_len=16)
              if model.is_encdec else model.init(rng_key))
    batch = _batch(cfg, model, rng_key)
    logits, aux, _ = model.forward(params, batch)
    b = batch["tokens"].shape[0]
    total = batch["tokens"].shape[1] + (
        batch["frontend"].shape[1] if (cfg.frontend and not model.is_encdec)
        else 0)
    assert logits.shape == (b, total, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = (model.init(rng_key, enc_len=16, dec_len=16)
              if model.is_encdec else model.init(rng_key))
    batch = {k: jnp.asarray(v) for k, v in _batch(cfg, model, rng_key).items()}
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3, warmup_steps=1))
    step = make_train_step(model, tcfg)
    opt = init_state(params)
    new_params, _, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    moved = any(
        float(jnp.max(jnp.abs(a.astype(jnp.float32)
                              - b.astype(jnp.float32)))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params)))
    assert moved


@pytest.mark.parametrize("arch", ["smollm-135m", "falcon-mamba-7b",
                                  "recurrentgemma-2b", "whisper-tiny",
                                  "deepseek-moe-16b"])
def test_decode_step_shapes(arch, rng_key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    if model.is_encdec:
        params = model.init(rng_key, enc_len=16, dec_len=32)
        cache = model.init_cache(2, 32, enc_len=16)
        # materialize cross-KV first
        frames = jax.random.normal(rng_key, (2, 16, cfg.d_model))
        _, (ck, cv) = model.prefill(params, {"frontend": frames})
        import dataclasses
        cache = dataclasses.replace(cache, cross_k=ck, cross_v=cv)
    else:
        params = model.init(rng_key)
        cache = model.init_cache(2, 32)
    toks = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, toks)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache2.length) == int(cache.length) + 1
