"""Flash store / serialization / tiers / async loading."""

import threading
import time

import numpy as np
import pytest

from repro.core.economics import SsdSpec
from repro.kvstore import (AsyncKvLoader, FlashKVStore, LruBytesCache,
                           PrefetchPipeline, SimulatedReader, TieredStore,
                           deserialize, serialize)


def test_serialize_roundtrip_mixed_dtypes():
    import ml_dtypes
    tensors = {
        "k": np.random.randn(3, 5, 2, 8).astype(ml_dtypes.bfloat16),
        "v": np.random.randn(3, 5, 2, 8).astype(np.float32),
        "q8": np.random.randint(-127, 127, (4, 4), dtype=np.int8),
        "ids": np.arange(7, dtype=np.int32),
    }
    data = serialize(tensors, {"n_tokens": 5, "arch": "x"})
    out, meta = deserialize(data)
    assert meta == {"n_tokens": 5, "arch": "x"}
    for name, a in tensors.items():
        assert out[name].dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(out[name], np.float32),
                                      np.asarray(a, np.float32))


def test_serialize_rejects_bad_magic():
    with pytest.raises(ValueError):
        deserialize(b"XXXXgarbage")


def test_store_put_get_delete(tmp_path):
    store = FlashKVStore(tmp_path)
    store.put("abc123", b"payload")
    assert store.get("abc123") == b"payload"
    assert store.exists("abc123")
    assert store.list_ids() == ["abc123"]
    assert store.total_bytes() == 7
    assert store.delete("abc123")
    assert not store.exists("abc123")
    assert not store.delete("abc123")  # idempotent
    assert store.stats.puts == 1 and store.stats.gets == 1


def test_store_rejects_path_traversal(tmp_path):
    store = FlashKVStore(tmp_path)
    with pytest.raises(ValueError):
        store.put("../evil", b"x")


def test_lru_eviction_order():
    c = LruBytesCache(capacity_bytes=30)
    c.put("a", b"x" * 10)
    c.put("b", b"x" * 10)
    c.put("c", b"x" * 10)
    assert c.get("a") is not None      # refresh a
    c.put("d", b"x" * 10)              # evicts b (LRU)
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("c") is not None
    assert c.size_bytes <= 30


def test_lru_oversize_item_not_cached():
    c = LruBytesCache(capacity_bytes=5)
    c.put("big", b"x" * 10)
    assert c.get("big") is None


def test_tiered_store_hits_dram(tmp_path):
    flash = FlashKVStore(tmp_path)
    tiered = TieredStore(flash, dram_capacity_bytes=1 << 20)
    tiered.put("k1", b"data")
    flash_reads_before = flash.stats.gets
    assert tiered.get("k1") == b"data"       # served from DRAM
    assert flash.stats.gets == flash_reads_before
    tiered.delete("k1")
    assert tiered.dram.get("k1") is None


def test_simulated_reader_enforces_bandwidth(tmp_path):
    store = FlashKVStore(tmp_path)
    store.put("c", b"x" * 1_000_000)
    slow = SimulatedReader(store, SsdSpec("slow", 0.1, 0.01, 5.0))  # 10 MB/s
    t0 = time.perf_counter()
    slow.get("c")
    elapsed = time.perf_counter() - t0
    assert elapsed >= 0.09  # 1MB / 10MB/s = 0.1s
    assert slow.total_simulated_s >= 0.09
    assert slow.energy_joules() > 0


def test_async_loader_parallel(tmp_path):
    store = FlashKVStore(tmp_path)
    for i in range(8):
        store.put(f"c{i}", bytes([i]) * 100)
    loader = AsyncKvLoader(store, n_workers=4)
    fut = loader.load_many([f"c{i}" for i in range(8)])
    payloads = fut.result(timeout=5)
    assert [p[0] for p in payloads] == list(range(8))
    loader.shutdown()


def test_prefetch_pipeline_overlaps():
    """Loads for item i+1 must start before item i finishes consuming."""
    events = []
    lock = threading.Lock()

    def load(item):
        with lock:
            events.append(("load_start", item))
        time.sleep(0.05)
        with lock:
            events.append(("load_end", item))
        return item * 10

    pipe = PrefetchPipeline([1, 2, 3], load, depth=1)
    results = []
    for item, payload in pipe:
        with lock:
            events.append(("consume", item))
        time.sleep(0.05)  # simulate decode
        results.append(payload)
    assert results == [10, 20, 30]
    # item 2's load must start before item 1 is consumed -> overlap happened
    i_load2 = events.index(("load_start", 2))
    i_consume1 = events.index(("consume", 1))
    assert i_load2 < i_consume1, events
