"""Flash store / serialization / tiers / async loading."""

import gc
import threading
import time
import weakref

import numpy as np
import pytest

from repro.core.economics import SsdSpec
from repro.kvstore import (AsyncKvLoader, FlashKVStore, LruBytesCache,
                           PrefetchPipeline, SimulatedReader, TieredStore,
                           deserialize, read_meta, serialize)


@pytest.fixture(autouse=True)
def _lockdep(lock_order):
    """Every test here runs under the lock-order detector (conftest
    ``lock_order``): a cycle in loader/tier lock acquisition fails the
    test even if this run never deadlocked."""
    yield


def test_serialize_roundtrip_mixed_dtypes():
    import ml_dtypes
    tensors = {
        "k": np.random.randn(3, 5, 2, 8).astype(ml_dtypes.bfloat16),
        "v": np.random.randn(3, 5, 2, 8).astype(np.float32),
        "q8": np.random.randint(-127, 127, (4, 4), dtype=np.int8),
        "ids": np.arange(7, dtype=np.int32),
    }
    data = serialize(tensors, {"n_tokens": 5, "arch": "x"})
    out, meta = deserialize(data)
    assert meta == {"n_tokens": 5, "arch": "x"}
    for name, a in tensors.items():
        assert out[name].dtype == a.dtype
        np.testing.assert_array_equal(np.asarray(out[name], np.float32),
                                      np.asarray(a, np.float32))


def test_serialize_rejects_bad_magic():
    with pytest.raises(ValueError):
        deserialize(b"XXXXgarbage")
    with pytest.raises(ValueError):
        read_meta(b"XXXXgarbage")


def test_read_meta_header_only():
    """read_meta works on a header-sized prefix — schedulers can inspect
    n_tokens/codec without reading (or holding) the payload bytes."""
    import struct
    tensors = {"k": np.random.randn(8, 64).astype(np.float32)}
    data = serialize(tensors, {"n_tokens": 8, "codec": "bf16", "doc": "d"})
    hlen = struct.unpack("<I", data[4:8])[0]
    prefix = data[:8 + hlen]                   # no payload bytes at all
    assert len(prefix) < len(data)
    meta = read_meta(prefix)
    assert meta == {"n_tokens": 8, "codec": "bf16", "doc": "d"}
    assert read_meta(data) == meta             # full artifact works too
    with pytest.raises(ValueError, match="truncated"):
        read_meta(data[:10])
    with pytest.raises(ValueError, match="truncated"):
        read_meta(data[:6])                    # magic ok, length word cut


def test_store_put_get_delete(tmp_path):
    store = FlashKVStore(tmp_path)
    store.put("abc123", b"payload")
    assert store.get("abc123") == b"payload"
    assert store.exists("abc123")
    assert store.list_ids() == ["abc123"]
    assert store.total_bytes() == 7
    assert store.delete("abc123")
    assert not store.exists("abc123")
    assert not store.delete("abc123")  # idempotent
    assert store.stats.puts == 1 and store.stats.gets == 1


def test_store_rejects_path_traversal(tmp_path):
    store = FlashKVStore(tmp_path)
    with pytest.raises(ValueError):
        store.put("../evil", b"x")


def test_store_concurrent_puts_same_chunk_id(tmp_path):
    """Regression: concurrent puts of one chunk_id used to share the single
    ``<id>.tmp`` name — one writer renamed the other's half-written file (or
    crashed on FileNotFoundError when its tmp was stolen). With unique tmp
    suffixes every put is self-contained: no exception, the surviving
    payload is one of the written values, intact, and no tmp litter."""
    store = FlashKVStore(tmp_path)
    payloads = [bytes([i]) * 5000 for i in range(4)]
    errs = []

    def hammer(i):
        try:
            for _ in range(30):
                store.put("hot", payloads[i])
        except Exception as e:                 # pragma: no cover - fail path
            errs.append(e)

    threads = [threading.Thread(target=hammer, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
    assert not errs, errs
    data = store.get("hot")
    assert data in payloads                    # intact, not interleaved
    assert not list(tmp_path.glob("*.tmp"))    # every tmp consumed/cleaned


def test_store_get_meta_reads_header_only(tmp_path):
    from repro.kvstore import serialize
    store = FlashKVStore(tmp_path)
    tensors = {"k": np.zeros((4, 1000), np.float32)}
    store.put("c1", serialize(tensors, {"n_tokens": 7, "codec": "int8"}))
    read0 = store.stats.bytes_read
    meta = store.get_meta("c1")
    assert meta["n_tokens"] == 7 and meta["codec"] == "int8"
    header_bytes = store.stats.bytes_read - read0
    assert 0 < header_bytes < 200              # payload (16KB) untouched


def test_lru_eviction_order():
    c = LruBytesCache(capacity_bytes=30)
    c.put("a", b"x" * 10)
    c.put("b", b"x" * 10)
    c.put("c", b"x" * 10)
    assert c.get("a") is not None      # refresh a
    c.put("d", b"x" * 10)              # evicts b (LRU)
    assert c.get("b") is None
    assert c.get("a") is not None and c.get("c") is not None
    assert c.size_bytes <= 30


def test_lru_oversize_item_not_cached():
    c = LruBytesCache(capacity_bytes=5)
    c.put("big", b"x" * 10)
    assert c.get("big") is None


def test_tiered_store_hits_dram(tmp_path):
    flash = FlashKVStore(tmp_path)
    tiered = TieredStore(flash, dram_capacity_bytes=1 << 20)
    tiered.put("k1", b"data")
    flash_reads_before = flash.stats.gets
    assert tiered.get("k1") == b"data"       # served from DRAM
    assert flash.stats.gets == flash_reads_before
    tiered.delete("k1")
    assert tiered.dram.get("k1") is None


def test_simulated_reader_enforces_bandwidth(tmp_path):
    store = FlashKVStore(tmp_path)
    store.put("c", b"x" * 1_000_000)
    slow = SimulatedReader(store, SsdSpec("slow", 0.1, 0.01, 5.0))  # 10 MB/s
    t0 = time.perf_counter()
    slow.get("c")
    elapsed = time.perf_counter() - t0
    assert elapsed >= 0.09  # 1MB / 10MB/s = 0.1s
    assert slow.total_simulated_s >= 0.09
    assert slow.energy_joules() > 0


def test_async_loader_parallel(tmp_path):
    store = FlashKVStore(tmp_path)
    for i in range(8):
        store.put(f"c{i}", bytes([i]) * 100)
    loader = AsyncKvLoader(store, n_workers=4)
    fut = loader.load_many([f"c{i}" for i in range(8)])
    payloads = fut.result(timeout=5)
    assert [p[0] for p in payloads] == list(range(8))
    loader.shutdown()


def test_lru_oversized_overwrite_keeps_existing_entry():
    """put() of an oversized value used to first evict the key's resident
    entry and then drop the insert — silent data loss. The resident entry
    must survive (values are immutable per chunk_id)."""
    c = LruBytesCache(capacity_bytes=10)
    c.put("k", b"x" * 8)
    c.put("k", b"y" * 20)                    # oversized: must be a no-op
    assert c.get("k") == b"x" * 8
    assert c.size_bytes == 8


def test_async_loader_gather_consumes_no_pool_worker(tmp_path):
    """Regression for the load_many self-deadlock: the gather used to be a
    closure submitted to the same pool as the per-chunk loads (blocking a
    worker per in-flight load_many). It must now be callback-driven: exactly
    one pool submission per chunk, none for the gather."""
    store = FlashKVStore(tmp_path)
    for i in range(3):
        store.put(f"c{i}", bytes([i]) * 10)
    loader = AsyncKvLoader(store, n_workers=1)
    submitted = []
    orig_submit = loader.pool.submit

    def counting_submit(fn, *a, **kw):
        submitted.append(fn)
        return orig_submit(fn, *a, **kw)

    loader.pool.submit = counting_submit
    fut = loader.load_many(["c0", "c1", "c2"])
    assert fut.result(timeout=5) == [bytes([i]) * 10 for i in range(3)]
    assert len(submitted) == 3               # loads only, no gather task
    assert all(f == store.get for f in submitted)
    loader.shutdown()


def test_async_loader_many_concurrent_gathers_single_worker(tmp_path):
    """The issue scenario: >= n_workers concurrent load_many calls on a slow
    reader must all complete with n_workers=1 (no gather wedging the pool)."""
    store = FlashKVStore(tmp_path)
    for i in range(4):
        store.put(f"c{i}", bytes([i]) * 50)

    class SlowReader:
        def get(self, cid):
            time.sleep(0.02)
            return store.get(cid)

    loader = AsyncKvLoader(SlowReader(), n_workers=1)
    results, errs = {}, []

    def call(i):
        try:
            results[i] = loader.load_many(
                [f"c{j}" for j in range(4)]).result(timeout=10)
        except Exception as e:               # pragma: no cover - fail path
            errs.append(e)

    threads = [threading.Thread(target=call, args=(i,)) for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=15)
    assert not errs and len(results) == 4
    assert all(v == [bytes([j]) * 50 for j in range(4)]
               for v in results.values())
    loader.shutdown()


def test_async_loader_load_many_empty_and_error(tmp_path):
    store = FlashKVStore(tmp_path)
    loader = AsyncKvLoader(store, n_workers=1)
    assert loader.load_many([]).result(timeout=2) == []
    with pytest.raises(FileNotFoundError):
        loader.load_many(["missing"]).result(timeout=5)
    loader.shutdown()


def test_async_loader_shutdown_races_inflight_load_many(tmp_path):
    """Regression: ``shutdown(cancel=True)`` racing an in-flight
    ``load_many``. Per-chunk done callbacks used to call ``f.exception()``
    bare; on a cancelled future that RAISES CancelledError — a BaseException
    since py3.8 — which escapes ``Future._invoke_callbacks``'s ``except
    Exception`` and silently aborts every later callback on the future, so
    the gather never resolved (this test then timed out) and the in-flight
    dedup registry kept the cancelled entry."""
    import concurrent.futures as cf

    store = FlashKVStore(tmp_path)
    store.put("a", b"x" * 64)
    store.put("b", b"y" * 64)
    picked_up = threading.Event()
    release = threading.Event()

    class BlockingReader:
        def get(self, cid):
            picked_up.set()
            assert release.wait(timeout=10)
            return store.get(cid)

    loader = AsyncKvLoader(BlockingReader(), n_workers=1)
    fut = loader.load_many(["a", "b"])   # "a" occupies the only worker;
    assert picked_up.wait(timeout=10)    # "b" sits queued behind it
    loader.shutdown(wait=False, cancel=True)   # cancels queued "b"
    release.set()                              # ... then "a" completes
    with pytest.raises(cf.CancelledError):
        fut.result(timeout=10)           # hung forever before the fix
    deadline = time.monotonic() + 5      # callbacks may still be finishing
    while loader._inflight and time.monotonic() < deadline:
        time.sleep(0.01)
    assert loader._inflight == {}        # cancelled reads must not leak


def test_prefetch_pipeline_releases_consumed_payloads():
    """Completed futures used to stay in ``inflight`` for the whole run,
    pinning every payload in memory. Live payloads must stay bounded by the
    pipeline depth."""

    class Payload:                           # weakref-able payload stand-in
        def __init__(self, i):
            self.data = bytes([i % 256]) * 1000

    live = weakref.WeakSet()

    def load(i):
        p = Payload(i)
        live.add(p)
        return p

    pipe = PrefetchPipeline(list(range(12)), load, depth=1)
    seen = 0
    for item, payload in pipe:
        del payload
        gc.collect()
        seen += 1
        # current inflight window only: depth + 1 loading + 1 slack
        assert len(live) <= 3, f"{len(live)} payloads alive at item {item}"
    assert seen == 12


def test_prefetch_pipeline_early_exit_shuts_down_pool():
    started = []

    def load(i):
        started.append(i)
        time.sleep(0.01)
        return i

    pipe = PrefetchPipeline(list(range(50)), load, depth=1)
    it = iter(pipe)
    next(it)
    it.close()                               # early exit -> cancel + shutdown
    with pytest.raises(RuntimeError):
        pipe._pool.submit(load, 99)          # pool must be shut down
    assert len(started) < 50                 # queued tail was cancelled


def test_prefetch_pipeline_overlaps():
    """Loads for item i+1 must start before item i finishes consuming."""
    events = []
    lock = threading.Lock()

    def load(item):
        with lock:
            events.append(("load_start", item))
        time.sleep(0.05)
        with lock:
            events.append(("load_end", item))
        return item * 10

    pipe = PrefetchPipeline([1, 2, 3], load, depth=1)
    results = []
    for item, payload in pipe:
        with lock:
            events.append(("consume", item))
        time.sleep(0.05)  # simulate decode
        with lock:
            events.append(("consume_done", item))
        results.append(payload)
    assert results == [10, 20, 30]
    # item 2's load (submitted at item 1's handoff, within the depth bound)
    # must start before item 1 finishes consuming -> overlap happened
    i_load2 = events.index(("load_start", 2))
    i_done1 = events.index(("consume_done", 1))
    assert i_load2 < i_done1, events


def test_async_loader_coalesces_duplicate_inflight_loads():
    """Two concurrent load_many calls (or two requests in one batch) asking
    for the same chunk_id must share one future / one flash read instead of
    issuing independent reads."""
    gate = threading.Event()
    reads = []

    class CountingReader:
        def get(self, cid):
            reads.append(cid)
            gate.wait(timeout=5)             # keep the read in flight
            return cid.encode()

    loader = AsyncKvLoader(CountingReader(), n_workers=4)
    f1 = loader.load_many(["a", "b", "a"])   # duplicate inside one batch
    f2 = loader.load_many(["a", "b"])        # duplicates across batches
    f3 = loader.load("a")
    time.sleep(0.05)                         # let the workers pick them up
    gate.set()
    assert f1.result(timeout=5) == [b"a", b"b", b"a"]
    assert f2.result(timeout=5) == [b"a", b"b"]
    assert f3.result(timeout=5) == b"a"
    assert sorted(reads) == ["a", "b"]       # exactly one read per chunk
    loader.shutdown()


def test_async_loader_accounts_encoded_bytes(tmp_path):
    """Loader stats count one read of the *encoded* payload per initiated
    load — coalesced duplicates cost nothing, and nothing is ever counted
    at widened size (the payload IS the flash/PCIe traffic)."""
    store = FlashKVStore(tmp_path)
    store.put("a", b"x" * 100)
    store.put("b", b"y" * 50)
    loader = AsyncKvLoader(store, n_workers=2)

    def settle(pred):                          # stats land in a done-callback
        deadline = time.time() + 5
        while not pred() and time.time() < deadline:
            time.sleep(0.001)

    loader.load_many(["a", "b", "a"]).result(timeout=5)
    settle(lambda: loader.stats.reads == 2)
    assert loader.stats.reads == 2
    assert loader.stats.bytes_loaded == 150
    loader.load("a").result(timeout=5)         # registry dropped: fresh read
    settle(lambda: loader.stats.reads == 3)
    assert loader.stats.reads == 3 and loader.stats.bytes_loaded == 250
    loader.shutdown()


def test_async_loader_dedup_is_inflight_only():
    """The coalescing registry tracks in-flight reads only — once a load
    completes, a later load for the same chunk issues a fresh read (the
    paged pool, not the loader, owns persistent reuse)."""
    reads = []

    class CountingReader:
        def get(self, cid):
            reads.append(cid)
            return cid.encode()

    loader = AsyncKvLoader(CountingReader(), n_workers=2)
    assert loader.load("a").result(timeout=5) == b"a"
    assert loader.load("a").result(timeout=5) == b"a"
    assert reads == ["a", "a"]
    loader.shutdown()


def test_prefetch_pipeline_inflight_bounded_by_depth():
    """Regression: the initial fill submitted loads while
    ``len(inflight) <= depth`` — depth+1 payloads concurrently in flight
    against the documented "bounded by the pipeline depth". Peak concurrent
    loads must never exceed ``depth`` (the top-up loop shares the bound)."""
    lock = threading.Lock()
    active = [0]
    peak = [0]

    def load(i):
        with lock:
            active[0] += 1
            peak[0] = max(peak[0], active[0])
        time.sleep(0.05)          # long enough for every submitted load to
        with lock:                # actually start on a worker thread
            active[0] -= 1
        return i

    pipe = PrefetchPipeline(list(range(8)), load, depth=2, n_workers=8)
    assert [p for _, p in pipe] == list(range(8))
    assert peak[0] <= 2, f"{peak[0]} concurrent loads for depth=2"
