"""Training substrate: optimizer math, loss goes down, checkpoints roundtrip."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import KvQaTask, PrefetchIterator, batched, lm_stream
from repro.models import build_model
from repro.models.model import chunked_cross_entropy, cross_entropy
from repro.training import (AdamWConfig, TrainConfig, init_state,
                            latest_checkpoint, restore_checkpoint,
                            save_checkpoint, train)


def test_adamw_reduces_quadratic():
    from repro.training.optimizer import apply_updates
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, weight_decay=0.0,
                      total_steps=100)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = init_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, state, m = apply_updates(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1.0
    assert int(state.step) == 60


def test_lr_schedule_shape():
    from repro.training.optimizer import schedule
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, s)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0           # warmup
    assert lrs[50] > lrs[99]                # cosine decay
    assert lrs[99] >= 0.099                 # floor


def test_grad_clip_limits_update():
    from repro.training.optimizer import apply_updates
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0, warmup_steps=1)
    params = {"w": jnp.zeros(4)}
    state = init_state(params)
    _, _, m = apply_updates(cfg, params, {"w": jnp.full((4,), 1e6)}, state)
    assert float(m["grad_norm"]) > 1e6 - 1


def test_chunked_ce_matches_full(rng_key):
    cfg = get_config("smollm-135m").reduced(vocab_size=128)
    model = build_model(cfg)
    params = model.init(rng_key)
    hidden = jax.random.normal(rng_key, (2, 16, cfg.d_model),
                               jnp.dtype(cfg.activation_dtype))
    labels = jax.random.randint(rng_key, (2, 16), 0, 128)
    from repro.models.transformer import unembed
    full = cross_entropy(unembed(cfg, params, hidden), labels)
    chunked = chunked_cross_entropy(cfg, params, hidden, labels, chunk=4)
    np.testing.assert_allclose(float(full), float(chunked), rtol=1e-4)


def test_train_loop_reduces_loss(rng_key):
    cfg = get_config("smollm-135m").reduced(vocab_size=300, num_layers=2)
    model = build_model(cfg)
    params = model.init(rng_key)
    task = KvQaTask(n_docs=4, n_facts=4, seed=0)
    data = iter(batched(task, batch=8, max_len=96, n_context=1))
    tcfg = TrainConfig(steps=30, log_every=29,
                       adamw=AdamWConfig(lr=3e-3, warmup_steps=5,
                                         total_steps=30))
    _, _, history = train(model, params, data, tcfg)
    assert history[-1]["ce"] < history[0]["ce"] * 0.9


def test_grad_accum_matches_large_batch(rng_key):
    # f32: grad-accum == large-batch is an *algebraic* property; in bf16 the
    # two paths batch matmul reductions differently and drift by ~1 ulp
    cfg = get_config("smollm-135m").reduced(vocab_size=64, num_layers=1,
                                            param_dtype="float32",
                                            activation_dtype="float32")
    model = build_model(cfg)
    params = model.init(rng_key)
    batch = {"tokens": jax.random.randint(rng_key, (4, 16), 0, 64),
             "labels": jax.random.randint(rng_key, (4, 16), 0, 64)}
    from repro.training import make_train_step
    tc1 = TrainConfig(grad_accum=1, adamw=AdamWConfig(lr=1e-2, warmup_steps=1))
    tc2 = TrainConfig(grad_accum=2, adamw=AdamWConfig(lr=1e-2, warmup_steps=1))
    p1, _, m1 = make_train_step(model, tc1)(params, init_state(params), batch)
    p2, _, m2 = make_train_step(model, tc2)(params, init_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)


def test_checkpoint_roundtrip(tmp_path, rng_key):
    cfg = get_config("smollm-135m").reduced(vocab_size=64, num_layers=1)
    model = build_model(cfg)
    params = model.init(rng_key)
    opt = init_state(params)
    path = save_checkpoint(tmp_path, 7, params, opt)
    assert latest_checkpoint(tmp_path) == path
    step, p2, o2 = restore_checkpoint(path, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    assert int(o2.step) == int(opt.step)


def test_prefetch_iterator_order():
    it = PrefetchIterator(iter(range(10)), depth=3)
    assert list(it) == list(range(10))


def test_lm_stream_shapes():
    it = lm_stream(vocab_size=100, batch=2, seq_len=32)
    b = next(it)
    assert b["tokens"].shape == (2, 32) and b["labels"].shape == (2, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
