import os

# Tests run on the single real CPU device (the dry-run alone forces 512 host
# devices, inside its own process). Keep kernels in interpret mode.
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


@pytest.fixture
def lock_order():
    """Lockdep for the serving tier (repro.check.lockorder): every lock
    created by the concurrency-bearing modules during the test is tracked,
    and the test fails at teardown if any acquisition-order cycle (a
    potential deadlock) was observed — even one this run never hit.
    """
    import repro.kvstore.async_loader as async_loader
    import repro.kvstore.cache_tier as cache_tier
    import repro.kvstore.simulated as simulated
    import repro.kvstore.store as store
    import repro.obs.trace as trace
    import repro.serving.queue as queue_mod
    from repro.check.lockorder import LockOrderRegistry, instrumented

    reg = LockOrderRegistry()
    with instrumented(reg, async_loader, cache_tier, simulated, store,
                      trace, queue_mod):
        yield reg
    reg.assert_clean()
