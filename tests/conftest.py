import os

# Tests run on the single real CPU device (the dry-run alone forces 512 host
# devices, inside its own process). Keep kernels in interpret mode.
os.environ.setdefault("REPRO_PALLAS_INTERPRET", "1")

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
