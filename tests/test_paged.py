"""Paged KV subsystem: pool refcount lifecycle, page-table parity with the
row-slotted continuous path, eviction isolation, and load dedup.

Parity tests reuse the CORPUS/QUESTIONS shape of test_serving_continuous so
paged answers are compared against the same single-request references.
"""

import tempfile
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.tokenizer import EOS
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.models.cache import insert_cache_row
from repro.paged import PagedKvPool
from repro.serving import ContinuousScheduler, RagEngine
from repro.serving.sampling import greedy

CORPUS = {
    "d1": "the amber gate stands in hall nine beyond the long stair. " * 4,
    "d2": "the cedar door opens with a brass song at dusk hour. " * 4,
    "d3": "the brass lamp hums beside the tall window all night. " * 4,
}
QUESTIONS = ["where is the amber gate?", "where is the cedar door?",
             "where is the brass lamp?"]


@pytest.fixture(autouse=True)
def _lockdep(lock_order):
    """Run under the lock-order detector (conftest ``lock_order``): any
    acquisition-order cycle observed during the test fails it."""
    yield


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-135m").reduced(vocab_size=300)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    return cfg, model, params


def _engine(model, params, store, **kw):
    kw.setdefault("top_k", 2)
    eng = RagEngine(model, params, store, chunk_tokens=48, **kw)
    for d, text in CORPUS.items():
        eng.ingest(d, text)
    return eng


# ---------------------------------------------------------------------------
# pool: refcounts, reclaim, slot arithmetic
# ---------------------------------------------------------------------------

def _art(cfg, n_tokens, seed=0):
    shape = (cfg.num_layers, 1, n_tokens, cfg.num_kv_heads, cfg.head_dim)
    k = jax.random.normal(jax.random.PRNGKey(seed), shape)
    return k, k + 1.0


def test_pool_refcount_lifecycle(setup):
    cfg, _, _ = setup
    pool = PagedKvPool(cfg, n_blocks=8, block_size=16)
    k, v = _art(cfg, 20)
    assert pool.acquire("c0") is None
    assert pool.insert("c0", k, v, nbytes=123) == 20
    assert pool.refcount("c0") == 1 and pool.used_blocks == 2
    assert pool.acquire("c0") == 20          # second sharer
    assert pool.refcount("c0") == 2
    pool.release("c0")
    assert pool.refcount("c0") == 1          # zero ONLY after the last row
    pool.release("c0")
    assert pool.refcount("c0") == 0
    assert pool.has("c0")                    # stays resident (HBM cache)
    assert pool.acquire("c0") == 20          # re-pin without a flash read
    assert pool.stats.chunk_hits == 2 and pool.stats.chunk_misses == 1
    assert pool.stats.flash_bytes_loaded == 123
    with pytest.raises(ValueError):
        pool.insert("c0", k, v)              # double insert is a bug
    pool.release("c0")
    with pytest.raises(ValueError):
        pool.release("c0")                   # over-release is a bug


def test_pool_reclaims_unreferenced_pages_under_pressure(setup):
    cfg, _, _ = setup
    pool = PagedKvPool(cfg, n_blocks=4, block_size=16)
    k, v = _art(cfg, 32)
    pool.insert("cold", k, v)
    pool.release("cold")                     # refs 0 -> reclaimable
    pool.insert("pinned", k, v)              # fills the pool
    assert pool.has("cold")
    blocks = pool.alloc_private(20)          # needs 2 -> must reclaim "cold"
    assert not pool.has("cold") and pool.stats.reclaims == 1
    pool.free_private(blocks)
    # pinned pages are never reclaimed: exhaustion raises instead
    pool.alloc_private(32)
    with pytest.raises(RuntimeError, match="exhausted"):
        pool.alloc_private(16)


def test_pool_partial_block_slot_ids(setup):
    cfg, _, _ = setup
    pool = PagedKvPool(cfg, n_blocks=8, block_size=16)
    k, v = _art(cfg, 20)                     # 16 + 4 -> ragged final block
    pool.insert("rag", k, v)
    slots = pool.chunk_slot_ids("rag")
    assert len(slots) == 20                  # only valid tokens are mapped
    b0, b1 = pool._entries["rag"].block_ids
    expect = np.concatenate([b0 * 16 + np.arange(16), b1 * 16 + np.arange(4)])
    np.testing.assert_array_equal(slots, expect)
    np.testing.assert_array_equal(
        np.asarray(pool.k[:, slots].astype(jnp.float32)),
        np.asarray(k[:, 0].astype(pool.dtype).astype(jnp.float32)))


# ---------------------------------------------------------------------------
# parity: paged continuous serving == single-request references
# ---------------------------------------------------------------------------

def test_paged_matches_row_slotted_mixed_workload(setup):
    """Mixed top_k / ragged final chunk / empty retrieval rows under
    paged=True match their single-request answers (the acceptance bar)."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        tail_cids = eng.ingest(
            "tail", "the zinc helm waits under the ninth arch today.  "
                    "only the zinc helm.")       # ragged final chunk
        orig = eng.retrieve
        eng.retrieve = lambda q: (
            [] if "nothing" in q
            else list(tail_cids)[:1] if "zinc" in q     # top_k == 1 row
            else orig(q))
        qs = ["where is the zinc helm today?", QUESTIONS[0],
              "where is nothing here??", QUESTIONS[1]]
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", UserWarning)
            refs = [eng.answer(q, max_new_tokens=5)[0] for q in qs]
            cont = ContinuousScheduler(eng, max_slots=2, paged=True,
                                       block_size=32)
            ans, m = cont.run(qs, max_new_tokens=5)
            cont.shutdown()
        assert ans == refs
        assert m.hbm_kv_bytes_resident > 0


def test_paged_step_logits_bit_identical_to_row_slotted(setup):
    """The paged gather->step->scatter pipeline runs the SAME jitted decode
    executable as the dense path — logits agree bit-for-bit, not just to
    tolerance."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        buf = 192
        reqs = [eng.prepare_request(q, 8) for q in QUESTIONS[:2]]

        cache = eng.model.init_row_cache(2, buf)
        pcache = eng.init_paged_cache(2, buf, block_size=32)
        toks = np.zeros((2,), np.int32)
        for slot, req in enumerate(reqs):
            row, _, _ = eng.compose_row(req, buf)
            first, row = eng.prefill_row(row, req.prompt)
            cache = insert_cache_row(cache, slot, row)

            eng.compose_row_paged(req, pcache, slot)
            first_p = eng.prefill_row_paged(pcache, slot, req.prompt)
            np.testing.assert_array_equal(np.asarray(first),
                                          np.asarray(first_p))
            toks[slot] = int(first[0])
        for _ in range(4):
            t = jnp.asarray(toks)[:, None]
            logits, cache = eng.step_rows(cache, t)
            logits_p = eng.step_rows_paged(pcache, t)
            np.testing.assert_array_equal(np.asarray(logits),
                                          np.asarray(logits_p))
            toks = np.asarray(greedy(logits[:, -1]))


# ---------------------------------------------------------------------------
# lifecycle: shared refcounts, eviction isolation, load dedup
# ---------------------------------------------------------------------------

def test_shared_chunk_refs_drop_only_when_last_row_retires(setup):
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        buf = 192
        pcache = eng.init_paged_cache(2, buf, block_size=32)
        req0 = eng.prepare_request(QUESTIONS[0], 8)
        req1 = eng.prepare_request(QUESTIONS[0], 8)   # same retrieval
        assert req0.chunk_ids == req1.chunk_ids and req0.chunk_ids
        eng.compose_row_paged(req0, pcache, 0)
        eng.compose_row_paged(req1, pcache, 1)
        cid = req0.chunk_ids[0]
        assert pcache.pool.refcount(cid) == 2
        assert pcache.pool.stats.chunk_misses == len(set(req0.chunk_ids))
        eng.release_row_paged(pcache, 0)
        assert pcache.pool.refcount(cid) == 1         # still pinned by row 1
        assert pcache.pool.has(cid)
        eng.release_row_paged(pcache, 1)
        assert pcache.pool.refcount(cid) == 0         # last sharer retired
        assert pcache.pool.has(cid)                   # cached, reclaimable


def test_evicting_one_request_never_corrupts_coresident_rows(setup):
    """Retire row 0 mid-decode and recycle its slot with a new request
    (forcing its freed private blocks to be reused) — the co-resident row 1,
    which shares chunk pages with the evicted row, must keep decoding the
    exact single-request token stream."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        eng = _engine(model, params, FlashKVStore(d), mode="matkv")
        buf = 192
        # reference stream for row 1's question
        ref, _ = eng.answer(QUESTIONS[0], max_new_tokens=8)

        pcache = eng.init_paged_cache(2, buf, block_size=32)
        req0 = eng.prepare_request(QUESTIONS[0], 8)   # same chunks as row 1
        req1 = eng.prepare_request(QUESTIONS[0], 8)
        eng.compose_row_paged(req0, pcache, 0)
        eng.compose_row_paged(req1, pcache, 1)
        f0 = eng.prefill_row_paged(pcache, 0, req0.prompt)
        f1 = eng.prefill_row_paged(pcache, 1, req1.prompt)
        toks = np.asarray([int(f0[0]), int(f1[0])], np.int32)
        stream1 = [int(f1[0])]
        for step in range(7):
            if step == 2:
                # evict row 0; its private tail blocks return to the free
                # list and are immediately recycled by a new admit
                eng.release_row_paged(pcache, 0)
                req2 = eng.prepare_request(QUESTIONS[2], 8)
                eng.compose_row_paged(req2, pcache, 0)
                f2 = eng.prefill_row_paged(pcache, 0, req2.prompt)
                toks[0] = int(f2[0])
            logits = eng.step_rows_paged(pcache, jnp.asarray(toks)[:, None])
            toks = np.array(greedy(logits[:, -1]))
            stream1.append(int(toks[1]))
        ids = stream1
        if EOS in ids:
            ids = ids[:ids.index(EOS)]
        assert eng.tok.decode(ids) == ref


def test_paged_duplicate_chunk_ids_in_one_request(setup):
    """A retriever returning the same chunk twice must not deadlock the
    paged arrival path (the second occurrence used to be marked 'expected'
    behind a wanted count the request itself held) — and the duplicate
    occupies two refs / one set of pages."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        cid = eng.retrieve(QUESTIONS[0])[0]
        orig = eng.retrieve
        eng.retrieve = lambda q: [cid, cid]
        try:
            ref, _ = eng.answer(QUESTIONS[0], max_new_tokens=4)
            gets0 = store.stats.gets
            cont = ContinuousScheduler(eng, max_slots=2, paged=True,
                                       block_size=32)
            ans, m = cont.run([QUESTIONS[0]], max_new_tokens=4)
            cont.shutdown()
        finally:
            eng.retrieve = orig
        assert ans == [ref]
        assert store.stats.gets - gets0 == 1     # one read serves both
        assert m.chunk_hits == 1 and m.chunk_misses == 1


def test_paged_run_reads_each_hot_chunk_once(setup):
    """N concurrent requests for the same hot chunks: one flash read and one
    GPU copy per chunk, not one per request."""
    cfg, model, params = setup
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng = _engine(model, params, store, mode="matkv")
        qs = [QUESTIONS[0]] * 6                       # all-hot workload
        refs = [eng.answer(q, max_new_tokens=4)[0] for q in qs]
        n_unique = len(set(eng.retrieve(qs[0])))
        gets0 = store.stats.gets
        cont = ContinuousScheduler(eng, max_slots=3, paged=True,
                                   block_size=32)
        ans, m = cont.run(qs, max_new_tokens=4)
        cont.shutdown()
        assert ans == refs
        assert store.stats.gets - gets0 == n_unique
        assert m.chunk_misses == n_unique
        assert m.chunk_hits == (6 - 1) * n_unique
        assert m.flash_bytes_per_request.count(0) == 5


def test_pool_free_private_double_free_guard(setup):
    """Regression: free_private had no double-free/ownership guard — freeing
    the same ids twice put duplicates on the free list, and two later
    allocations silently aliased one page, corrupting co-resident requests'
    KV. Invalid frees must raise, and post-free allocations never alias."""
    cfg, _, _ = setup
    pool = PagedKvPool(cfg, n_blocks=8, block_size=16)
    blocks = pool.alloc_private(32)
    pool.free_private(blocks)
    with pytest.raises(ValueError, match="not outstanding"):
        pool.free_private(blocks)            # the old corruption entry point
    # the corruption itself no longer reproduces: after the (rejected)
    # double free, two fresh allocations share no block id
    a = pool.alloc_private(32)
    b = pool.alloc_private(32)
    assert not set(a) & set(b), f"aliased blocks {set(a) & set(b)}"
    assert pool.pinned_blocks == len(a) + len(b)
    pool.free_private(a)
    pool.free_private(b)
    # shared chunk pages are pool-owned, never free_private-able
    k, v = _art(cfg, 16)
    pool.insert("c0", k, v)
    with pytest.raises(ValueError, match="not outstanding"):
        pool.free_private(pool._entries["c0"].block_ids)
    assert pool.has("c0")                    # entry untouched by the reject
