"""Selective materialization + eviction (paper §III-E): admission by the
per-object ten-day rule, capacity-bounded eviction, TCO-ordered victims."""


from repro.core.economics import GpuSpec, SsdSpec
from repro.core.tiering import (CostAwarePolicy, LfuPolicy, LruPolicy,
                                TenDayAdmission, TieredStore)


class MemStore:
    def __init__(self):
        self.d = {}

    def put(self, cid, payload):
        self.d[cid] = payload

    def get(self, cid):
        return self.d[cid]

    def delete(self, cid):
        self.d.pop(cid, None)


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def make(capacity=100, admission=None, eviction=None, clock=None):
    clock = clock or Clock()
    ts = TieredStore(MemStore(), capacity, admission=admission,
                     eviction=eviction, now_fn=clock)
    return ts, clock


def test_always_admit_stores_and_hits():
    ts, _ = make()
    assert ts.offer("a", b"x" * 10)
    assert ts.get("a") == b"x" * 10
    assert ts.stats.hits == 1 and ts.stats.admissions == 1


def test_miss_returns_none_and_counts():
    ts, _ = make()
    assert ts.get("nope") is None
    assert ts.stats.misses == 1


def test_capacity_forces_eviction_lru():
    ts, clock = make(capacity=25, eviction=LruPolicy())
    ts.offer("a", b"x" * 10)
    clock.t = 1.0
    ts.offer("b", b"x" * 10)
    clock.t = 2.0
    ts.get("a")                       # refresh a; b becomes LRU
    clock.t = 3.0
    ts.offer("c", b"x" * 10)          # must evict b
    assert "a" in ts and "c" in ts and "b" not in ts
    assert ts.stats.evictions == 1
    assert ts.used_bytes == 20


def test_lfu_prefers_dropping_cold():
    ts, clock = make(capacity=25, eviction=LfuPolicy())
    ts.offer("hot", b"x" * 10)
    ts.offer("cold", b"x" * 10)
    for i in range(5):
        clock.t += 1
        ts.get("hot")
    clock.t += 1
    ts.offer("new", b"x" * 10)
    assert "hot" in ts and "cold" not in ts


def test_cost_aware_evicts_lowest_value_per_byte():
    clock = Clock()
    ts, _ = make(capacity=30, eviction=CostAwarePolicy(now_fn=clock),
                 clock=clock)
    ts.offer("big_cold", b"x" * 20)   # 20 bytes, will get 1 hit
    ts.offer("small_hot", b"x" * 5)   # 5 bytes, many hits
    clock.t = 1.0
    ts.get("big_cold")
    for i in range(6):
        clock.t += 1
        ts.get("small_hot")
    ts.offer("next", b"x" * 10)       # over budget -> evict big_cold
    assert "small_hot" in ts and "big_cold" not in ts


def test_ten_day_admission_requires_reaccess_within_interval():
    # tiny GPU/SSD constants -> break-even interval = $1 / (1MB/s * $1e-6/MB)
    gpu = GpuSpec("toy", 1.0, 1.0, prefill_tokens_per_s=1.0,
                  decode_tokens_per_s=1.0)
    ssd = SsdSpec("toy", 1e-3, 1.0, 1.0)   # $/GB -> $1e-6/MB
    adm = TenDayAdmission(gpu, ssd, kv_bytes_per_token=1_000_000)
    T = adm.break_even_s
    assert not adm.on_access("a", 0.0)          # first access: cold start
    assert adm.on_access("a", T * 0.5)          # re-access inside T: admit
    assert not adm.on_access("b", 0.0)
    assert not adm.on_access("b", T * 2.0)      # outside T: still cold


def test_tiered_store_with_admission_gate():
    gpu = GpuSpec("toy", 1.0, 1.0, 1.0, 1.0)
    ssd = SsdSpec("toy", 1e-3, 1.0, 1.0)
    clock = Clock()
    ts, _ = make(capacity=1000,
                 admission=TenDayAdmission(gpu, ssd, 1_000_000), clock=clock)
    assert not ts.offer("a", b"kv")             # first offer rejected (cold)
    assert ts.stats.rejections == 1
    clock.t = 1.0
    assert ts.offer("a", b"kv")                 # hot now -> admitted
    assert ts.get("a") == b"kv"


def test_zipf_workload_hit_rate_improves_with_cost_aware():
    """Under a skewed workload with a tight budget, CostAware >= LRU."""
    import numpy as np
    rng = np.random.default_rng(0)
    ids = [f"c{i}" for i in range(50)]
    probs = 1.0 / np.arange(1, 51)
    probs /= probs.sum()
    accesses = rng.choice(50, size=2000, p=probs)

    def run(policy_cls):
        clock = Clock()
        policy = (policy_cls(now_fn=clock) if policy_cls is CostAwarePolicy
                  else policy_cls())
        ts, _ = make(capacity=10 * 8, eviction=policy, clock=clock)
        for step, i in enumerate(accesses):
            clock.t = float(step + 1)
            cid = ids[i]
            if ts.get(cid) is None:
                ts.offer(cid, b"x" * 8)         # recompute + offer
        return ts.stats.hit_rate

    lru = run(LruPolicy)
    cost = run(CostAwarePolicy)
    assert lru > 0.3                            # skew makes caching worthwhile
    assert cost >= lru - 0.05                   # cost-aware not worse


def test_ten_day_admission_injectable_clock():
    """Standalone use without explicit timestamps runs on the injected
    now_fn — admission decisions are deterministic, no sleeps."""
    gpu = GpuSpec("toy", 1.0, 1.0, prefill_tokens_per_s=1.0,
                  decode_tokens_per_s=1.0)
    ssd = SsdSpec("toy", 1e-3, 1.0, 1.0)
    clock = Clock()
    adm = TenDayAdmission(gpu, ssd, kv_bytes_per_token=1_000_000,
                          now_fn=clock)
    assert not adm.on_access("a")               # cold start at t=0
    clock.t = adm.break_even_s * 0.5
    assert adm.on_access("a")                   # re-access inside T
    clock.t = adm.break_even_s * 10
    assert not adm.on_access("a")               # interval stretched past T
    # TieredStore threads its own clock through as the explicit timestamp
    store_clock = Clock()
    ts = TieredStore(MemStore(), 1000,
                     admission=TenDayAdmission(gpu, ssd, 1_000_000),
                     now_fn=store_clock)
    assert not ts.offer("x", b"kv")
    store_clock.t = 1.0
    assert ts.offer("x", b"kv")


def test_hits_feed_admission_clock_for_post_eviction_readmit():
    """Regression: get() on a hit never fed admission.on_access, so
    TenDayAdmission._last_seen froze at the admitting offer while the chunk
    stayed resident. A chunk kept hot by steady hits, evicted long after its
    admission, was then wrongly rejected at its next offer — the inter-access
    interval was measured from the long-ago admission instead of the last
    access."""
    gpu = GpuSpec("toy", 1.0, 1.0, prefill_tokens_per_s=1.0,
                  decode_tokens_per_s=1.0)
    ssd = SsdSpec("toy", 1e-3, 1.0, 1.0)
    clock = Clock()
    adm = TenDayAdmission(gpu, ssd, kv_bytes_per_token=1_000_000)
    ts, _ = make(capacity=20, admission=adm, eviction=LruPolicy(),
                 clock=clock)
    T = adm.break_even_s
    assert not ts.offer("hot", b"x" * 10)          # cold start
    clock.t = 0.4 * T
    assert ts.offer("hot", b"x" * 10)              # re-access inside T
    # steady resident hits keep the chunk hot long past T-from-admission
    for i in range(1, 6):
        clock.t = 0.4 * T + i * 0.5 * T
        assert ts.get("hot") is not None
    t_last_hit = clock.t
    # capacity pressure admits "other" (two offers inside T) and evicts "hot"
    clock.t = t_last_hit + 0.05 * T
    assert not ts.offer("other", b"y" * 15)
    clock.t = t_last_hit + 0.10 * T
    assert ts.offer("other", b"y" * 15)
    assert "hot" not in ts and ts.stats.evictions == 1
    # re-offer inside the break-even window of the LAST HIT: must admit
    clock.t = t_last_hit + 0.20 * T
    assert ts.get("hot") is None                   # miss -> caller recomputes
    assert ts.offer("hot", b"x" * 10), (
        "hot chunk evicted after steady hits was rejected at re-offer: "
        "hits are not feeding the admission clock")
