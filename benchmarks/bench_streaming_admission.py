"""Streaming decode-under-load admission (DESIGN.md §16).

A cold request's admission used to be all-or-nothing: wait for every chunk
artifact to finish its flash read, then compose + prefill in one burst. With
``ContinuousScheduler(streaming=True)`` the per-chunk reads land block by
block — each block extends the chunk's resident frontier in the pool and
folds into the request's online-softmax carry between decode steps — so by
the time the last page lands, admission is just the finalize step and the
first token comes out ~the link time, not link + compose + prefill.

Four phases, all asserted:

* **TTFT** — one cold request per run, served over a *shared-link*
  ``SimulatedReader`` whose bandwidth is calibrated against this machine's
  measured baseline admission window (``LINK_FRAC``), so the equal-bandwidth
  comparison is meaningful on any host. Two bounds:

  - the analytic join (``streaming_ttft_model`` fed the MEASURED link,
    compose, prefill and finalize) must predict streamed TTFT <=
    ``TTFT_RATIO_BOUND_MODEL`` x baseline — this is the paper-level claim,
    with the fold riding the link's shadow and only finalize left serial;
  - the raw wall-clock median must come in <= ``TTFT_RATIO_BOUND_MEASURED``
    x baseline. The measured bound is looser than the model's because a
    single-core host serializes the fold against the link simulator's
    sleeps and adds a fixed ~20-30 ms thread-handoff + admit tail after
    the last block that no amount of link time hides; on multi-core hosts
    the measured ratio converges toward the model's.
* **answers** — the streamed run's answers are IDENTICAL to the
  non-streaming paged scheduler's under both codecs (bf16: the carry fold is
  greedy-token-exact vs the all-at-once prefill; int8: both paths decode the
  same stored quantized pages, which bounds any drift below an argmax flip
  on this workload).
* **host tier** — a deliberately tight pool with a host-DRAM demotion tier:
  reclaimed refs-0 pages pack into host bytes, and a later request for the
  demoted chunk re-promotes with ZERO flash bytes (``promotions >= 1`` and
  the repeat request's flash attribution is 0).
* **overlap** — with spaced arrivals, later requests' flash reads run in
  earlier requests' decode shadow: the trace-derived
  ``load_overlap_frac`` must be > 0.

Each phase appends machine-readable records to results.jsonl
(``emit_result``), including the ``streaming_ttft_model`` analytic join and
the PR-8 ``predicted_vs_measured`` per-step KV-bytes join, so
``analysis/report.py --serving`` renders this bench alongside the other
serving suites.
"""

from __future__ import annotations

import sys
import tempfile

import numpy as np
from benchmarks.common import QUESTIONS, emit_result, make_engine, row

from repro.analysis.roofline import streaming_ttft_model
from repro.core.economics import SsdSpec
from repro.kvstore import SimulatedReader
from repro.obs import Tracer, predicted_vs_measured
from repro.serving import ContinuousScheduler, RagEngine

BLOCK = 64               # == chunk_tokens: whole-token-axis blocks coalesce
                         # to one range read per tensor (streaming.py)
TTFT_RATIO_BOUND_MODEL = 0.6
TTFT_RATIO_BOUND_MEASURED = 0.9
TTFT_TOP_K = 24          # TTFT probes retrieve wide: admission work scales
                         # with k while the streamed finalize step does not,
                         # so this is the compose-dominated regime streaming
                         # admission is for (and the finalize floor — fixed
                         # dispatch overhead on this toy model — stays small
                         # against the admission window)
LINK_FRAC = 1.0          # calibrated link time / baseline admission window:
                         # ~1 so the fold (which shares this host's core
                         # with the link simulator) keeps pace with arrival
                         # while the link still isn't the whole TTFT
HOST_TIER_MB = 8


def _clone_engine(base, codec: str, reader=None, top_k=None) -> RagEngine:
    """Same store/retrieval state, fresh reader (each arm gets its own
    simulated flash link) and optionally a wider retrieval fan-out."""
    eng = RagEngine(base.model, base.params, base.store, mode="matkv",
                    chunk_tokens=base.chunk_tokens,
                    top_k=top_k or base.top_k, codec=codec, reader=reader)
    eng._chunks, eng.vdb = base._chunks, base.vdb
    return eng


def _serve(eng, qs, max_new, *, streaming, arrivals=None, slots=2,
           tracer=None, workers=2, **kw):
    # two loader workers: enough to keep the shared link saturated while
    # arrival order still tracks submission (= retrieval) order, which the
    # strict in-order carry fold wants
    sched = ContinuousScheduler(eng, max_slots=slots, paged=True,
                                block_size=BLOCK, streaming=streaming,
                                tracer=tracer, n_load_workers=workers, **kw)
    answers, m = sched.run(qs, max_new_tokens=max_new, arrivals_s=arrivals)
    sched.shutdown()
    return answers, m, sched


def _ttft_phase(base, out, max_new: int, n_probes: int):
    """Calibrate the link against the measured all-or-nothing admission
    window, then race the two admission modes over identical shared links.
    One cold request per run (fresh pool every ``run()``), median over
    repeated disjoint-doc probes."""
    qs = QUESTIONS[:n_probes]
    # unthrottled baseline: measures this machine's admission window and the
    # cold payload bytes, and warms every jitted shape both arms will hit
    eng0 = _clone_engine(base, "bf16", top_k=TTFT_TOP_K)
    for q in qs:                                       # compile every shape
        _serve(eng0, [q], max_new, streaming=False)
        _serve(eng0, [q], max_new, streaming=True)
    w0, fins, payload = [], [], 0
    for q in qs:
        _, m, _ = _serve(eng0, [q], max_new, streaming=False)
        # the admission window is the hideable work: compose + prefill.
        # Phase timings are much stabler than end-to-end TTFT here, and
        # taking the max biases the link long — an undersized link starves
        # the fold (which shares this core) and stalls EVERY streamed run,
        # while an oversized one just shifts both arms equally
        w0.append((m.phase_s.get("compose", 0.0),
                   m.phase_s.get("prefill", 0.0)))
        payload = max(payload, m.flash_bytes_loaded)
        # the streamed arm's finalize (its "prefill" phase) measured the
        # same way: unthrottled and warm, so the analytic model below is
        # fed clean CPU timings rather than link-contended ones
        _, m, _ = _serve(eng0, [q], max_new, streaming=True)
        fins.append(m.phase_s.get("prefill", 0.0))
    compose_s = float(np.median([c for c, _ in w0]))
    prefill_s = float(np.median([p for _, p in w0]))
    finalize_s = float(np.median(fins))
    window = float(np.max([c + p for c, p in w0]))
    gbps = payload / (LINK_FRAC * window) / 1e9
    spec = SsdSpec("calibrated", 0.1, gbps, 7.0)

    ttft = {}
    for name, streaming in (("baseline", False), ("streamed", True)):
        eng = _clone_engine(base, "bf16", top_k=TTFT_TOP_K,
                            reader=SimulatedReader(base.store, spec,
                                                   shared_link=True))
        for q in qs:                                   # warm this clone
            _serve(eng, [q], max_new, streaming=streaming)
        vals = []
        for q in qs:
            for _ in range(2):
                _, m, sched = _serve(eng, [q], max_new, streaming=streaming)
                vals.append(m.ttft_s[0])
        ttft[name] = float(np.median(vals))
        out.append(row(f"streaming_admission/{name}/ttft_us",
                       ttft[name] * 1e6,
                       f"link_gbps={gbps:.4f};payload={payload}"))
        pm = predicted_vs_measured(sched.last_registry, pool=sched.last_pool,
                                   buf_size=sched.last_buf_size,
                                   expected_row_tokens=TTFT_TOP_K
                                   * base.chunk_tokens + max_new)
        emit_result("streaming_admission", name, metrics=m,
                    ttft_s=ttft[name], link_gbps=gbps, **pm)

    ratio = ttft["streamed"] / ttft["baseline"]
    # the analytic side of the claim: same payload/link, with the
    # compose/prefill/finalize medians measured warm and unthrottled above
    # (the streamed arm's admission-side compose rides the link's shadow,
    # leaving finalize as its only serial admission work)
    model = streaming_ttft_model(payload, gbps, compose_s=compose_s,
                                 prefill_s=prefill_s,
                                 finalize_s=finalize_s)
    out.append(row("streaming_admission/ttft_ratio", 0.0,
                   f"measured={ratio:.3f};"
                   f"bound={TTFT_RATIO_BOUND_MEASURED};"
                   f"predicted={model['predicted_ratio']:.3f};"
                   f"model_bound={TTFT_RATIO_BOUND_MODEL}"))
    emit_result("streaming_admission", "ttft_ratio", measured_ratio=ratio,
                bound=TTFT_RATIO_BOUND_MEASURED,
                model_bound=TTFT_RATIO_BOUND_MODEL, **model)
    assert model["predicted_ratio"] <= TTFT_RATIO_BOUND_MODEL, (
        f"analytic streamed/baseline TTFT ratio is "
        f"{model['predicted_ratio']:.3f} (bound {TTFT_RATIO_BOUND_MODEL}) "
        f"at the measured link/compose/prefill/finalize — the finalize "
        f"step grew until streaming stopped paying for itself")
    assert ratio <= TTFT_RATIO_BOUND_MEASURED, (
        f"streamed cold-request TTFT is {ratio:.3f}x the all-or-nothing "
        f"baseline at equal flash bandwidth "
        f"(bound {TTFT_RATIO_BOUND_MEASURED}) — block-granular admission "
        f"stopped hiding the compose/prefill work")
    return ratio


def _answers_phase(tmp, out, codecs, max_new: int):
    for codec in codecs:
        eng = make_engine("matkv", f"{tmp}/ans-{codec}", codec=codec)
        a0, m0, _ = _serve(eng, QUESTIONS, max_new, streaming=False)
        a1, m1, s1 = _serve(eng, QUESTIONS, max_new, streaming=True)
        assert a0 == a1, (
            f"streamed admission changed answers under codec={codec} — the "
            f"online-softmax carry fold must be token-exact vs the "
            f"all-at-once prefill")
        n_str = int(s1.last_registry.value("serve.streamed_admits"))
        out.append(row(f"streaming_admission/{codec}/answers", 0.0,
                       f"identical=True;streamed_admits={n_str}"))
        emit_result("streaming_admission", f"answers-{codec}", metrics=m1,
                    answers_identical=True, streamed_admits=n_str)


def _host_tier_phase(tmp, out, max_new: int):
    """Tight pool + host tier, one slot: by the time the later requests
    serve, the first doc's pages were reclaimed and demoted to host DRAM;
    the repeat request re-promotes them with zero flash bytes."""
    eng = make_engine("matkv", f"{tmp}/host", codec="bf16")
    qs = QUESTIONS[:3] + [QUESTIONS[0]]
    # fix the row geometry so the pool can be sized exactly: one active
    # row + one request's chunk pages + a single spare block. Serving the
    # next request then MUST reclaim the previous one's refs-0 pages,
    # which is what routes them through the demotion tier
    buf = -(-(eng.top_k * eng.chunk_tokens + 64 + max_new + 8) // 64) * 64
    per_row = -(-buf // BLOCK)
    chunk_blocks = -(-eng.chunk_tokens // BLOCK)
    pool_blocks = per_row + eng.top_k * chunk_blocks + 1
    _, m, sched = _serve(eng, qs, max_new, streaming=True, slots=1,
                         pool_blocks=pool_blocks, buf_size=buf,
                         host_tier=HOST_TIER_MB * 2**20)
    stats = sched.last_pool.stats
    repeat_flash = m.flash_bytes_per_request[-1]
    out.append(row("streaming_admission/host_tier", 0.0,
                   f"demotions={stats.demotions};"
                   f"promotions={stats.promotions};"
                   f"repeat_flash_bytes={repeat_flash}"))
    emit_result("streaming_admission", "host_tier", metrics=m,
                demotions=stats.demotions, promotions=stats.promotions,
                repeat_flash_bytes=int(repeat_flash),
                pool_blocks=pool_blocks)
    assert stats.promotions >= 1, (
        f"host tier never re-promoted (demotions={stats.demotions}): the "
        f"pool was sized too large to reclaim, or demotion broke")
    assert repeat_flash == 0, (
        f"re-requesting a demoted chunk read {repeat_flash} flash bytes — "
        f"host-tier re-promotion must skip flash entirely")


def _overlap_phase(base, out, max_new: int):
    """Spaced arrivals: the later requests' block streams run while the
    first request decodes, so part of the flash-read wall time is hidden
    behind decode_step spans."""
    spec = SsdSpec("overlap", 0.1, 0.002, 7.0)       # slow link: reads last
    eng = _clone_engine(base, "bf16",
                        reader=SimulatedReader(base.store, spec,
                                               shared_link=True))
    qs = QUESTIONS[:4]
    _serve(eng, qs[:1], max_new, streaming=True)     # warm
    tr = Tracer(role="bench")
    arrivals = [0.0, 0.03, 0.06, 0.09]
    _, m, _ = _serve(eng, qs, max_new, streaming=True, arrivals=arrivals,
                     tracer=tr)
    out.append(row("streaming_admission/load_overlap_frac",
                   m.load_overlap_frac,
                   f"n_flash_reads={len(m.flash_read_s)}"))
    emit_result("streaming_admission", "overlap", metrics=m,
                load_overlap_frac=m.load_overlap_frac)
    assert m.load_overlap_frac > 0.0, (
        "no flash-read time overlapped decode steps under spaced arrivals "
        "— the streaming pump stopped hiding loads behind decode")


def run(max_new: int = 8, smoke: bool = False):
    out = []
    n_probes = 2 if smoke else 3
    codecs = ["bf16"] if smoke else ["bf16", "int8"]
    if smoke:
        max_new = 4
    # shrink the GIL switch interval for the duration: the link simulator,
    # loader workers and the fold all share one core here, and the default
    # 5 ms slice is the same order as a block's link time
    prev_switch = sys.getswitchinterval()
    sys.setswitchinterval(0.0005)
    try:
        with tempfile.TemporaryDirectory() as d:
            base = make_engine("matkv", f"{d}/base", codec="bf16")
            _ttft_phase(base, out, max_new, n_probes)
            _answers_phase(d, out, codecs, max_new)
            _host_tier_phase(d, out, max_new)
            _overlap_phase(base, out, max_new)
    finally:
        sys.setswitchinterval(prev_switch)
    return out


if __name__ == "__main__":
    print("\n".join(run()))
