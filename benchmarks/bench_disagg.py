"""Disaggregated materializer/decode serving (DESIGN.md §14).

MatKV's second headline result: once chunk KVs are materialized, decode
speed barely depends on GPU grade — so prefill and decode capacity should
scale on SEPARATE axes. This suite stands the split up on a forced
8-host-device platform (subprocess, like bench_tp_serving) and measures:

* materializer throughput as its mesh scales (the prefill fleet axis),
  with the role's own ``materialize_tokens_per_s`` metrics asserted;
* a WEAK decode mesh (half the prefill mesh's devices) holding decode
  tok/s against a decode mesh the prefill fleet's size — the paper's
  claim that decode capacity is cheap, asserted at >= 0.9x;
* per-role ``ServeMetrics``: the decode role reports zero materializer
  work and vice versa (the blended ``tokens_per_s`` is not consulted);
* materialize-on-miss: with a chunk's artifact deleted, the decode worker
  parks the affected request behind a queue job that a materializer pump
  thread serves, keeps decoding everything else, and still produces
  answers bit-identical to the all-hot composed engine;
* observability (DESIGN.md §15): tracing-enabled decode must hold >= 0.95x
  of the untraced rate, the fused kernel's measured per-step KV bytes must
  land within 1.25x of the roofline model, and the miss run exports
  per-role Chrome traces to ``experiments/traces/`` that merge into one
  timeline joined on the victim chunk / request ids.
"""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys

WEAK_DECODE_RATIO = 0.9     # weak decode mesh must hold this much tok/s


def _child(smoke: bool):
    """Runs inside the forced-8-device subprocess; prints CSV rows."""
    import json
    import tempfile
    import threading
    import time

    import jax

    from benchmarks.common import DOCS, QUESTIONS, emit_result, row
    from repro.configs import get_config
    from repro.kvstore import FlashKVStore
    from repro.launch.mesh import make_role_meshes, make_serving_mesh
    from repro.obs import (Tracer, arg_values, merge_chrome,
                           predicted_vs_measured, validate_chrome)
    from repro.serving import (ContinuousScheduler, DecodeWorker,
                               HandoffRecord, MaterializerWorker, RagEngine,
                               WorkQueue)
    from repro.models import build_model

    assert len(jax.devices()) >= 8, "child must run with 8 forced devices"
    out = []
    n_requests, max_new = (6, 3) if smoke else (12, 5)
    scale_meshes = (1, 4) if smoke else (1, 2, 4)
    scale_docs = dict(sorted(DOCS.items())[:3 if smoke else 6])
    # KV-head count divisible by every mesh size used here (2 and 4-way
    # decode, up to 4-way prefill) so pool and projections really shard
    cfg = get_config("smollm-135m").reduced(
        vocab_size=320, num_heads=8, num_kv_heads=8, head_dim=16,
        d_model=128, d_ff=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qs = [QUESTIONS[i % len(QUESTIONS)] for i in range(n_requests)]

    # -- materializer fleet scaling: same corpus, growing prefill mesh --------
    rates = []
    for n in scale_meshes:
        with tempfile.TemporaryDirectory() as d:
            mat = MaterializerWorker(model, params, FlashKVStore(d),
                                     chunk_tokens=48, queue=WorkQueue(),
                                     mesh=make_serving_mesh(n))
            for doc, text in sorted(scale_docs.items()):
                mat.ingest_document(doc, text)
            m = mat.metrics
            assert m.role == "materialize", m.role
            assert m.n_materialized_tokens > 0 and m.materialize_s > 0
            assert m.materialize_tokens_per_s > 0
            # the materializer role never decodes — its metrics must say so
            assert m.decode_s == 0 and m.n_new_tokens == 0
            rates.append(m.materialize_tokens_per_s)
            out.append(row(f"disagg/materialize/mesh{n}/tokens_per_s",
                           m.materialize_tokens_per_s,
                           f"chunks_tokens={m.n_materialized_tokens};"
                           f"flash_mb={m.flash_bytes_written / 2**20:.2f}"))
    # forced host devices share one CPU, so mesh growth buys no real FLOPs
    # here — report the scaling curve, assert it on real accelerators only
    out.append(row("disagg/materialize/scaling",
                   rates[-1] / rates[0] if rates[0] else 0.0,
                   f"meshes={list(scale_meshes)}"))

    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        queue = WorkQueue()
        # composed single-device engine: materializes the shared artifact
        # plane at ingest, provides retrieval for the hand-offs, and is the
        # bit-parity reference for the decode role's answers
        eng0 = RagEngine(model, params, store, mode="matkv",
                         chunk_tokens=48, top_k=2)
        for doc, text in sorted(DOCS.items()):
            eng0.ingest(doc, text)
        handoff_sets = {q: eng0.retrieve(q) for q in qs}

        def submit_handoffs(n_warm: int):
            for q in qs[:n_warm]:
                queue.submit_handoff(HandoffRecord(q, handoff_sets[q],
                                                   max_new))
            for q in qs:
                queue.submit_handoff(HandoffRecord(q, handoff_sets[q],
                                                   max_new))

        def serve_decode(mesh, tag, pump_mat=None, pre_main=None,
                         tracer=None):
            worker = DecodeWorker(model, params, store, chunk_tokens=48,
                                  top_k=2, queue=queue, mesh=mesh,
                                  tracer=tracer)
            if tracer is not None:
                queue.tracer = tracer      # queue_job/handoff instants land
            submit_handoffs(n_warm=4)      # in this run's decode trace
            sched = ContinuousScheduler(worker, max_slots=4, paged=True,
                                        block_size=32)
            stop = threading.Event()
            pump = None
            if pump_mat is not None:
                # the materializer fleet, reduced to a thread: drains miss
                # jobs off the shared queue while the decode role runs
                def _drain():
                    while not stop.is_set():
                        pump_mat.process_jobs()
                        time.sleep(0.002)
                pump = threading.Thread(target=_drain, daemon=True)
                pump.start()
            sched.run(qs[:4], max_new_tokens=max_new)          # warm jit
            if tracer is not None:
                tracer.clear()             # trace the timed run only
            if pre_main is not None:
                pre_main()
            t0 = time.perf_counter()
            answers, m = sched.run(qs, max_new_tokens=max_new)
            wall = time.perf_counter() - t0
            stop.set()
            if pump is not None:
                pump.join()
            sched.shutdown()
            worker.shutdown()
            if tracer is not None:
                from repro.obs import NULL_TRACER
                queue.tracer = NULL_TRACER
            # per-role metrics: a decode worker reports decode work only
            assert m.role == "decode", m.role
            assert m.decode_tokens_per_s > 0 and m.n_new_tokens > 0
            assert m.materialize_s == 0 and m.n_materialized_tokens == 0
            out.append(row(f"disagg/decode/{tag}/tokens_per_s",
                           m.decode_tokens_per_s,
                           f"wall_s={wall:.2f};blended={m.tokens_per_s:.1f};"
                           f"hit_rate={m.chunk_hit_rate:.2f}"))
            return answers, m, sched

        # reference: the composed engine over the same paged path
        sched0 = ContinuousScheduler(eng0, max_slots=4, paged=True,
                                     block_size=32)
        sched0.run(qs[:4], max_new_tokens=max_new)             # warm jit
        ans_ref, m_ref = sched0.run(qs, max_new_tokens=max_new)
        sched0.shutdown()
        assert m_ref.role == "both", m_ref.role
        out.append(row("disagg/both/tokens_per_s", m_ref.tokens_per_s,
                       f"decode_rate={m_ref.decode_tokens_per_s:.1f}"))

        # single-device decode role: must be bit-identical to the engine
        ans1, m1, _ = serve_decode(None, "mesh0_single_device")
        assert ans1 == ans_ref, (
            "single-device decode-role answers diverged from the composed "
            "engine — the role split changed numerics")
        out.append(row("disagg/decode/bit_parity_vs_both", 0.0, "exact=True"))

        # -- tracing overhead + predicted-vs-measured (DESIGN.md §15) ---------
        # tracing on must cost < 5% decode tok/s (retry: CPU wall-clock at
        # this tiny scale is noisy; what we reject is a systematic slowdown)
        overhead = 0.0
        for attempt in range(3):
            tr_probe = Tracer(role="decode")
            ans_tr, m_tr, sched_tr = serve_decode(
                None, f"traced_try{attempt}", tracer=tr_probe)
            overhead = (m_tr.decode_tokens_per_s / m1.decode_tokens_per_s
                        if m1.decode_tokens_per_s else 0.0)
            if overhead >= 0.95:
                break
            _, m1, _ = serve_decode(None, f"untraced_try{attempt}")
        assert overhead >= 0.95, (
            f"tracing-enabled decode holds only {overhead:.2f}x of the "
            f"untraced rate after retries — span overhead regressed")
        assert ans_tr == ans_ref, (
            "tracing changed decode numerics — spans must be pure observers")
        out.append(row("disagg/trace/overhead_ratio", overhead,
                       f"bound=0.95;events={len(tr_probe.events)}"))

        # the roofline byte model vs the bytes the fused kernel's block
        # tables actually staged, per decode step. Expected row footprint:
        # chunk pages round up to block granularity in the pool, so doc
        # tokens count at their page-rounded size
        blk = sched_tr.last_pool.block_size
        exp_rows = []
        for q in qs:
            doc = sum((len(eng0._chunks[c].tokens) + blk - 1) // blk * blk
                      for c in handoff_sets[q])
            exp_rows.append(doc + len(eng0._prompt(q)) + max_new / 2)
        pm = predicted_vs_measured(
            sched_tr.last_registry, pool=sched_tr.last_pool,
            buf_size=sched_tr.last_buf_size,
            expected_row_tokens=int(round(sum(exp_rows) / len(exp_rows))))
        assert pm["steps"] > 0, "traced run recorded no decode steps"
        assert 1 / 1.25 <= pm["ratio"] <= 1.25, (
            f"fused decode measured {pm['measured_step_bytes']:.0f} B/step "
            f"vs roofline-predicted {pm['predicted_step_bytes']:.0f} "
            f"(ratio {pm['ratio']:.3f}) — model and measurement drifted "
            f"beyond 1.25x")
        out.append(row("disagg/trace/predicted_vs_measured", pm["ratio"],
                       f"pred={pm['predicted_step_bytes']:.0f};"
                       f"meas={pm['measured_step_bytes']:.0f};"
                       f"occ={pm['occupancy']:.2f};steps={pm['steps']}"))
        emit_result("disagg", "decode_traced", metrics=m_tr,
                    trace_overhead_ratio=overhead, **pm)

        # the headline: a decode mesh HALF the prefill fleet's size must
        # hold decode tok/s vs one the prefill fleet's size. Role meshes
        # are disjoint device sets (prefill fleet on devices 0-3, decode
        # on 4-5 / 4-7), as a real deployment would carve them
        _, decode_weak = make_role_meshes(4, 2)
        _, decode_strong = make_role_meshes(4, 4)
        ans_w, m_w, _ = serve_decode(decode_weak, "mesh2_weak")
        ans_s, m_s, _ = serve_decode(decode_strong, "mesh4_strong")
        ratio = (m_w.decode_tokens_per_s / m_s.decode_tokens_per_s
                 if m_s.decode_tokens_per_s else 0.0)
        assert ratio >= WEAK_DECODE_RATIO, (
            f"weak decode mesh (2 dev) holds only {ratio:.2f}x of the "
            f"strong mesh (4 dev) decode tok/s; decode should be "
            f"grade-insensitive once KVs are loaded")
        out.append(row("disagg/decode/weak_vs_strong_ratio", ratio,
                       f"bound={WEAK_DECODE_RATIO};weak_mesh=2;strong_mesh=4"))
        emit_result("disagg", "weak_vs_strong", weak_vs_strong_ratio=ratio,
                    bound=WEAK_DECODE_RATIO)

        # materialize-on-miss: delete one served chunk's artifact; a
        # materializer pump (sharing only store + queue with the decode
        # worker) must re-materialize it mid-run instead of the decode
        # worker stalling or crashing — and answers stay bit-identical
        tr_dec = Tracer(role="decode")
        tr_mat = Tracer(role="materialize")
        mat = MaterializerWorker(model, params, store, chunk_tokens=48,
                                 queue=queue, tracer=tr_mat)
        for c in eng0._chunks.values():
            mat.register_chunk(c)
        victim = handoff_sets[qs[0]][0]
        store.delete(victim)
        assert not store.exists(victim)
        # delete again between warm and timed run so the measured run also
        # takes the miss — AND gets a fresh generation while the warm run's
        # pages sit resident (the stale-page contract, exercised live)
        ans_miss, m_miss, _ = serve_decode(
            None, "miss_remat", pump_mat=mat,
            pre_main=lambda: store.delete(victim), tracer=tr_dec)
        assert ans_miss == ans_ref, (
            "answers diverged after a mid-run re-materialization")
        assert mat.metrics.n_materialize_jobs >= 2, (
            "the deleted chunk never became a materialize job")
        assert store.exists(victim), "re-materialized artifact not on flash"
        out.append(row("disagg/miss/rematerialized_jobs",
                       float(mat.metrics.n_materialize_jobs),
                       f"exact_answers=True;"
                       f"mat_tok_per_s={mat.metrics.materialize_tokens_per_s:.0f}"))

        # -- per-role trace export + cross-role join (DESIGN.md §15) ----------
        # each role writes its own Chrome trace; merged, they form one
        # timeline where the victim chunk appears on BOTH role lanes (the
        # decode role's miss/flash-read and the materializer's re-prefill)
        # and every request id appears on the decode lane
        tdir = pathlib.Path(__file__).resolve().parent.parent \
            / "experiments" / "traces"
        tdir.mkdir(parents=True, exist_ok=True)
        p_dec = tdir / "disagg_decode.trace.json"
        p_mat = tdir / "disagg_materialize.trace.json"
        p_merged = tdir / "disagg_merged.trace.json"
        doc_dec = tr_dec.to_chrome(p_dec)
        doc_mat = tr_mat.to_chrome(p_mat)
        validate_chrome(doc_dec)
        validate_chrome(doc_mat)
        merged = merge_chrome(doc_dec, doc_mat)
        validate_chrome(merged)
        p_merged.write_text(json.dumps(merged))
        dec_chunks = arg_values(doc_dec, "chunk")
        mat_chunks = arg_values(doc_mat, "chunk")
        assert victim in dec_chunks and victim in mat_chunks, (
            f"victim chunk {victim} must appear in both role traces "
            f"(decode saw {sorted(dec_chunks)[:4]}..., materializer "
            f"{sorted(mat_chunks)[:4]}...)")
        reqs = arg_values(doc_dec, "req")
        assert set(range(n_requests)) <= reqs, (
            f"decode trace is missing request ids: {sorted(reqs)}")
        out.append(row("disagg/trace/role_merge", float(len(
            merged["traceEvents"])),
            f"decode_ev={len(doc_dec['traceEvents'])};"
            f"mat_ev={len(doc_mat['traceEvents'])};victim_joined=True"))
        emit_result("disagg", "miss_remat", metrics=m_miss,
                    traces=[str(p_dec), str(p_mat), str(p_merged)],
                    victim=victim)
    print("\n".join(out))


def run(smoke: bool = False):
    """Spawn the forced-8-host-device child and relay its CSV rows (the
    parent may already hold a single-device jax runtime)."""
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.setdefault("REPRO_PALLAS_INTERPRET", "1")
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root), str(root / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, "-m", "benchmarks.bench_disagg", "--child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"disagg child failed:\n{proc.stderr[-4000:]}")
    return [line for line in proc.stdout.splitlines() if line.strip()]


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child(smoke="--smoke" in sys.argv)
    else:
        print("\n".join(run()))
