"""Paper Eq. 1 + §II-C: the ten-day rule and per-access cost ratios, for every
assigned architecture and the paper's LLaMAs, across storage tiers. Also the
int8-on-flash extension: halved bytes => doubled break-even interval."""

from __future__ import annotations

from benchmarks.common import row

from repro.configs import REGISTRY
from repro.core.economics import (H100, PM9A3, RTX4090, SAMSUNG_9100_PRO,
                                  break_even_interval_days,
                                  cost_ratio_per_access)


def run():
    out = []
    for name, cfg in sorted(REGISTRY.items()):
        kv = cfg.kv_bytes_per_token(2)
        if kv == 0:  # attention-free ssm: O(1) state, rule trivially satisfied
            out.append(row(f"eq1/{name}", 0.0, "kv_bytes=0;state_only"))
            continue
        days = break_even_interval_days(H100, SAMSUNG_9100_PRO, kv)
        days_q8 = break_even_interval_days(H100, SAMSUNG_9100_PRO, kv // 2)
        ratio_hourly = cost_ratio_per_access(H100, SAMSUNG_9100_PRO, kv,
                                             1024, 3600.0)
        out.append(row(f"eq1/{name}", 0.0,
                       f"break_even_days={days:.1f};int8_days={days_q8:.1f};"
                       f"hourly_cost_ratio_x={ratio_hourly:.0f}"))
    # headline: the paper's configuration
    kv70 = REGISTRY["llama-3.1-70b"].kv_bytes_per_token(2)
    out.append(row("eq1/ten_day_rule", 0.0,
                   f"llama70b_h100_9100pro_days="
                   f"{break_even_interval_days(H100, SAMSUNG_9100_PRO, kv70):.1f}"))
    out.append(row("eq1/low_end", 0.0,
                   f"llama8b_4090_pm9a3_days="
                   f"{break_even_interval_days(RTX4090, PM9A3, REGISTRY['llama-3.1-8b'].kv_bytes_per_token(2)):.1f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
