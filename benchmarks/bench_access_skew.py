"""Paper Fig. 2: access skew in RAG retrieval — run Zipf-distributed queries
against a synthetic vector DB and measure how many distinct chunks are
accessed 2+ times (the population for which materialization pays off)."""

from __future__ import annotations

import numpy as np
from benchmarks.common import row

from repro.retrieval import HashingEmbedder, VectorDB


def run(n_docs: int = 3000, n_queries: int = 10_000, top_k: int = 10):
    rng = np.random.default_rng(0)
    emb = HashingEmbedder()
    db = VectorDB(emb.dim)
    doc_vecs = []
    for i in range(n_docs):
        toks = rng.integers(0, 1 << 15, size=32)
        v = emb.embed_tokens(toks)
        db.add(f"c{i:05d}", v)
        doc_vecs.append(v)
    doc_vecs = np.stack(doc_vecs)

    # Zipf-skewed query topics: queries are noisy copies of popular docs
    ranks = np.arange(1, n_docs + 1, dtype=np.float64)
    popularity = 1.0 / ranks
    popularity /= popularity.sum()
    counts = np.zeros(n_docs, np.int64)
    order = rng.permutation(n_docs)
    batch_hits = []
    for _ in range(n_queries):
        topic = order[rng.choice(n_docs, p=popularity)]
        q = doc_vecs[topic] + 0.25 * rng.standard_normal(emb.dim)
        for cid, _ in db.search(q.astype(np.float32), top_k=top_k):
            counts[int(cid[1:])] += 1
    accessed = counts > 0
    reused = counts >= 2
    out = [
        row("fig2/accessed_frac", 0.0,
            f"frac={accessed.mean():.3f}"),
        row("fig2/reused_2plus_frac", 0.0,
            f"frac={reused.mean():.3f}"),
        row("fig2/top1pct_access_share", 0.0,
            f"share={np.sort(counts)[::-1][:n_docs // 100].sum() / counts.sum():.3f}"),
    ]
    return out


if __name__ == "__main__":
    print("\n".join(run()))
