"""Paper Fig. 10: MatKV on a low-end GPU vs full recompute on a high-end GPU.

Analytic device-class model (H100 vs RTX4090 prefill/decode rates from
§II-C/§V): once KVs load from flash, the low-end GPU's weak prefill no longer
matters — MatKV-on-4090 lands within ~1.5x of Vanilla-on-H100 while
Vanilla-on-4090 is ~3x slower (the paper's headline)."""

from __future__ import annotations

from benchmarks.common import row

from repro.configs import get_config
from repro.core.economics import (H100, PM9A3, RAID0_9100_PRO_X4, RTX4090,
                                  load_cost, prefill_cost)

N_REQ = 200
CHUNKS = 1
CHUNK_TOKENS = 1024
ANSWER = 20


def run():
    cfg = get_config("llama-3.1-8b")
    kv_bytes = cfg.kv_bytes_per_token(2) * CHUNK_TOKENS * CHUNKS
    combos = {
        "vanilla_h100": (H100, RAID0_9100_PRO_X4, 32, False),
        "matkv_h100": (H100, RAID0_9100_PRO_X4, 32, True),
        "vanilla_4090": (RTX4090, PM9A3, 2, False),
        "matkv_4090": (RTX4090, PM9A3, 2, True),
    }
    walls = {}
    out = []
    for name, (gpu, ssd, batch, matkv) in combos.items():
        n_batches = N_REQ // batch
        t_pref, _ = prefill_cost(gpu, CHUNK_TOKENS * CHUNKS * batch)
        t_dec = ANSWER / gpu.decode_tokens_per_s
        if matkv:
            t_load, _ = load_cost(ssd, kv_bytes * batch)
            t_qpref = t_pref * 20 / (CHUNK_TOKENS * CHUNKS)
            wall = n_batches * (t_load + t_qpref + t_dec)
        else:
            wall = n_batches * (t_pref + t_dec)
        walls[name] = wall
        out.append(row(f"fig10/{name}", wall / N_REQ * 1e6,
                       f"total_s={wall:.1f}"))
    out.append(row("fig10/matkv4090_vs_vanillah100", 0.0,
                   f"slowdown_x={walls['matkv_4090']/walls['vanilla_h100']:.2f}"))
    out.append(row("fig10/vanilla4090_vs_vanillah100", 0.0,
                   f"slowdown_x={walls['vanilla_4090']/walls['vanilla_h100']:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
