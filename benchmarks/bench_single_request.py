"""Paper Fig. 5: single-request latency breakdown, Vanilla vs MatKV.

Sequential requests; phase breakdown load / (sub)prefill / decode. The paper's
headline: MatKV cuts the prefill phase by >2x; end-to-end ~1.7x at short
outputs (decode dominates single requests)."""

from __future__ import annotations

import tempfile

from benchmarks.common import QUESTIONS, make_engine, row


def run(n_requests: int = 6, max_new_tokens: int = 8):
    out = []
    with tempfile.TemporaryDirectory() as d:
        for mode in ("vanilla", "matkv"):
            eng = make_engine(mode, d)
            for i in range(n_requests):      # warm jit for every prompt shape
                eng.answer(QUESTIONS[i % len(QUESTIONS)],
                           max_new_tokens=max_new_tokens)
            agg = {"load": 0.0, "prefill": 0.0, "decode": 0.0}
            for i in range(n_requests):
                _, t = eng.answer(QUESTIONS[i % len(QUESTIONS)],
                                  max_new_tokens=max_new_tokens)
                agg["load"] += t.load_s
                agg["prefill"] += t.prefill_s
                agg["decode"] += t.decode_s
            total = sum(agg.values())
            for phase, s in agg.items():
                out.append(row(f"fig5/{mode}/{phase}",
                               s / n_requests * 1e6,
                               f"frac={s / total:.3f}"))
            out.append(row(f"fig5/{mode}/total", total / n_requests * 1e6))
    # derived: prefill-phase ratio (paper: >2x)
    van = [r for r in out if r.startswith("fig5/vanilla/prefill")][0]
    mat = [r for r in out if r.startswith("fig5/matkv/prefill")][0]
    v = float(van.split(",")[1])
    m_load = float([r for r in out if "matkv/load" in r][0].split(",")[1])
    m_pre = float(mat.split(",")[1])
    out.append(row("fig5/prefill_speedup_x", 0.0,
                   f"ratio={v / max(m_load + m_pre, 1e-9):.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
