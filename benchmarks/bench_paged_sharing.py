"""Paged vs row-slotted serving under Zipfian chunk reuse (DESIGN.md §10).

The paper's Fig. 2 premise — RAG retrieval is heavily skewed, so a few hot
chunks serve most requests — is exactly the workload where the paged pool
wins: N concurrent requests retrieving one hot chunk share a single flash
read and a single GPU-resident copy of its pages, instead of N of each.

A Zipf(1.0) topic distribution over the corpus drives an open-loop request
stream served twice per concurrency level — ``ContinuousScheduler`` with the
dense row-slotted cache, then with ``paged=True`` — and per scheduler we
report useful tokens/sec, flash bytes actually read (ground truth from the
store's counters), peak HBM KV bytes resident, and the paged chunk hit rate.
Under skew at >= 8 slots paged must read strictly fewer flash bytes and hold
strictly fewer HBM KV bytes than row-slotted (the acceptance bar).
"""

from __future__ import annotations

import tempfile

import numpy as np
from benchmarks.common import DOCS, emit_result, make_engine, row

from repro.analysis.roofline import paged_step_kv_bytes_for_pool
from repro.serving import ContinuousScheduler


def _zipf_workload(eng, n_requests: int, seed: int):
    """Distinct question strings mapped to Zipf-popular docs' chunks (the
    mapping pins retrieval so both schedulers serve identical rows)."""
    rng = np.random.default_rng(seed)
    doc_ids = sorted(DOCS)
    ranks = np.arange(1, len(doc_ids) + 1, dtype=np.float64)
    popularity = (1.0 / ranks) / (1.0 / ranks).sum()
    chunks_by_doc = {d: [cid for cid, c in eng._chunks.items()
                         if c.doc_id == d] for d in doc_ids}
    qs, mapping = [], {}
    for i in range(n_requests):
        d = doc_ids[int(rng.choice(len(doc_ids), p=popularity))]
        q = f"q{i}: where is the {d} artifact?"
        qs.append(q)
        mapping[q] = chunks_by_doc[d][:eng.top_k]
    eng.retrieve = lambda q: list(mapping.get(q, []))
    # open-loop Poisson arrivals: successive requests for a hot chunk land
    # after earlier loads completed, so the row-slotted path re-reads from
    # flash while the paged pool serves them from resident pages (requests
    # arriving inside one in-flight window are deduped by the loader in
    # both schedulers)
    arrivals = np.cumsum(rng.exponential(0.02, n_requests)).tolist()
    return qs, arrivals


def _serve(eng, qs, arrivals, max_new, slots, paged):
    store = eng.store
    sched = ContinuousScheduler(eng, max_slots=slots, paged=paged,
                                block_size=32)
    sched.run(qs, max_new_tokens=max_new)                    # warm jit
    read0 = store.stats.bytes_read
    _, m = sched.run(qs, max_new_tokens=max_new, arrivals_s=arrivals)
    sched.shutdown()
    return m, store.stats.bytes_read - read0


def run(n_requests: int = 24, slot_sweep=(4, 8), max_new: int = 4,
        seed: int = 0, smoke: bool = False):
    if smoke:
        n_requests, slot_sweep, max_new = 8, (8,), 2
    out = []
    with tempfile.TemporaryDirectory() as d:
        eng = make_engine("matkv", d + "/m")
        qs, arrivals = _zipf_workload(eng, n_requests, seed)
        for slots in slot_sweep:
            m_row, flash_row = _serve(eng, qs, arrivals, max_new, slots,
                                      paged=False)
            m_pg, flash_pg = _serve(eng, qs, arrivals, max_new, slots,
                                    paged=True)
            tag = f"slots={slots};n={n_requests}"
            out.append(row(f"row_slotted/s{slots}/tokens_per_s",
                           m_row.tokens_per_s, tag))
            out.append(row(f"row_slotted/s{slots}/flash_bytes", flash_row,
                           f"hbm_resident={m_row.hbm_kv_bytes_resident}"))
            out.append(row(f"paged/s{slots}/tokens_per_s",
                           m_pg.tokens_per_s, tag))
            out.append(row(
                f"paged/s{slots}/flash_bytes", flash_pg,
                f"hbm_resident={m_pg.hbm_kv_bytes_resident};"
                f"hit_rate={m_pg.chunk_hit_rate:.2f}"))
            emit_result("paged_sharing", f"row_slotted/s{slots}",
                        metrics=m_row, flash_bytes=int(flash_row),
                        slots=slots, n_requests=n_requests)
            emit_result("paged_sharing", f"paged/s{slots}",
                        metrics=m_pg, flash_bytes=int(flash_pg),
                        slots=slots, n_requests=n_requests)
            out.append(row(
                f"paged_vs_row/s{slots}/savings", 0.0,
                f"flash_ratio={flash_pg / max(flash_row, 1):.3f};"
                f"hbm_ratio={m_pg.hbm_kv_bytes_resident / max(m_row.hbm_kv_bytes_resident, 1):.3f};"
                f"speedup={m_pg.tokens_per_s / max(m_row.tokens_per_s, 1e-9):.2f}"))
            if slots >= 8:
                # the acceptance bar: at >= 8 concurrent slots under skew,
                # strictly fewer flash bytes AND strictly lower HBM KV
                # residency (at tiny concurrency, block-granularity rounding
                # can eat the sharing win — reported above, not asserted)
                assert flash_pg < flash_row, (
                    f"paged read {flash_pg} flash bytes vs row-slotted "
                    f"{flash_row} at {slots} slots — dedup regressed")
                assert (m_pg.hbm_kv_bytes_resident
                        < m_row.hbm_kv_bytes_resident), (
                    "paged HBM residency must undercut the dense "
                    "per-slot cache")
        # fused single-launch decode (the default paged step above) must
        # also beat the three-phase pipeline on per-step HBM KV traffic
        # under the DESIGN §Roofline-accounting byte model, with widths
        # read off a live pool at this workload's geometry
        buf, block, slots = 192, 32, max(slot_sweep)
        pool = eng.init_paged_cache(slots, buf, block_size=block).pool
        b3 = paged_step_kv_bytes_for_pool(pool, [buf] * slots, buf_size=buf,
                                          fused=False)
        bf = paged_step_kv_bytes_for_pool(pool, [buf] * slots, buf_size=buf,
                                          fused=True)
        assert bf < b3, (
            f"roofline model: fused paged step moves {bf} KV bytes vs "
            f"three-phase {b3} — the single-launch fusion lost its "
            f"HBM-traffic win")
        out.append(row("paged/fused_kv_bytes_per_step", float(bf),
                       f"three_phase={b3};ratio={bf / b3:.3f};"
                       f"buf={buf};block={block};slots={slots}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
