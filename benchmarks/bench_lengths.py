"""Paper Fig. 8: varying input (retrieved chunks 1..4) and output length
(4..32 tokens). MatKV's relative gain grows with input size and shrinks with
output length (decode dominates) but never inverts."""

from __future__ import annotations

import tempfile
import time

from benchmarks.common import QUESTIONS, make_engine, row


def run():
    out = []
    with tempfile.TemporaryDirectory() as d:
        engines = {m: make_engine(m, d + "/" + m, top_k=4) for m in
                   ("vanilla", "matkv")}
        # (a) input size sweep: 1..4 chunks
        for n_chunks in (1, 2, 4):
            totals = {}
            for mode, eng in engines.items():
                cids = eng.retrieve(QUESTIONS[0])[:n_chunks]
                while len(cids) < n_chunks:
                    cids.append(cids[-1])
                eng.answer(QUESTIONS[0], chunk_ids=cids,
                           max_new_tokens=4)      # warm jit for this shape
                t0 = time.perf_counter()
                eng.answer(QUESTIONS[0], chunk_ids=cids, max_new_tokens=4)
                totals[mode] = time.perf_counter() - t0
                out.append(row(f"fig8a/{mode}/chunks{n_chunks}",
                               totals[mode] * 1e6))
            out.append(row(f"fig8a/speedup/chunks{n_chunks}", 0.0,
                           f"ratio={totals['vanilla'] / totals['matkv']:.2f}"))
        # (b) output length sweep
        for n_out in (4, 16, 32):
            totals = {}
            for mode, eng in engines.items():
                cids = eng.retrieve(QUESTIONS[1])[:2]
                eng.answer(QUESTIONS[1], chunk_ids=cids,
                           max_new_tokens=n_out)  # warm jit for this shape
                t0 = time.perf_counter()
                eng.answer(QUESTIONS[1], chunk_ids=cids,
                           max_new_tokens=n_out)
                totals[mode] = time.perf_counter() - t0
                out.append(row(f"fig8b/{mode}/out{n_out}",
                               totals[mode] * 1e6))
            out.append(row(f"fig8b/speedup/out{n_out}", 0.0,
                           f"ratio={totals['vanilla'] / totals['matkv']:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
