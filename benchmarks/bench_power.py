"""Paper Tables IV & V: system-wide and GPU-only power/energy, Vanilla vs
MatKV vs MatKV+overlap.

This container has no H100/IPMI, so energy is the paper's measured power
constants x our *modeled phase times at paper scale* (H100 prefill rate, SSD
read bandwidth, fixed decode rate), for the paper's workload: 256 requests,
batch 8, 2x1,024-token chunks, 20-token answers. Reproduces the shape of
Tables IV/V: MatKV ~0.5x the energy of Vanilla, overlap slightly better."""

from __future__ import annotations

from benchmarks.common import row

from repro.configs import get_config
from repro.core.economics import (H100, RAID0_9100_PRO_X4, load_cost,
                                  prefill_cost)

IDLE_SYSTEM_W = 550.0
GPU_IDLE_W = 50.0
N_REQUESTS = 256
BATCH = 8
CHUNK_TOKENS = 1024
N_CHUNKS = 2
ANSWER_TOKENS = 20


def run():
    cfg = get_config("llama-3.1-70b")
    kv_bytes = cfg.kv_bytes_per_token(2) * CHUNK_TOKENS * N_CHUNKS
    n_batches = N_REQUESTS // BATCH

    # per-batch phase times at paper scale
    t_prefill, _ = prefill_cost(H100, CHUNK_TOKENS * N_CHUNKS * BATCH)
    t_load, _ = load_cost(RAID0_9100_PRO_X4, kv_bytes * BATCH)
    t_query_prefill = t_prefill * (20 / (CHUNK_TOKENS * N_CHUNKS))
    t_decode = ANSWER_TOKENS / H100.decode_tokens_per_s  # batched decode

    scenarios = {
        "vanilla": n_batches * (t_prefill + t_decode),
        "matkv": n_batches * (t_load + t_query_prefill + t_decode),
        "matkv_overlap": n_batches * (max(t_load, t_decode)
                                      + t_query_prefill) + t_load,
    }
    gpu_busy = {
        "vanilla": n_batches * (t_prefill + t_decode),
        "matkv": n_batches * (t_query_prefill + t_decode),
        "matkv_overlap": n_batches * (t_query_prefill + t_decode),
    }
    out = []
    for name, wall in scenarios.items():
        busy = gpu_busy[name]
        gpu_j = busy * H100.peak_power_w + (wall - busy) * GPU_IDLE_W
        ssd_w = RAID0_9100_PRO_X4.active_power_w if "matkv" in name else 0.0
        sys_j = wall * IDLE_SYSTEM_W + gpu_j + \
            (n_batches * t_load) * ssd_w
        out.append(row(f"table4/{name}/system", wall * 1e6,
                       f"kJ={sys_j / 1e3:.0f};time_s={wall:.0f}"))
        out.append(row(f"table5/{name}/gpu", busy * 1e6,
                       f"kJ={gpu_j / 1e3:.0f}"))
    v = float(out[0].split("kJ=")[1].split(";")[0])
    m = float(out[4].split("kJ=")[1].split(";")[0])
    out.append(row("table4/energy_ratio", 0.0, f"vanilla_over_overlap={v/m:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
