"""Tensor-parallel paged serving over a device mesh (DESIGN.md §12).

The KV-offloading bottleneck analysis (PAPERS.md) puts serving capacity
behind two walls — HBM residency and the flash load link. Sharding the
paged block pool and the decode step along the KV-head axis of a mesh
multiplies both: each device holds 1/N of every resident chunk's pages and
serves 1/N of the attention heads. This suite validates the whole stack on
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (no accelerators
needed), in a subprocess so the forced device count never leaks into the
parent benchmark process:

* 1-device mesh answers must be BIT-IDENTICAL to the plain single-device
  paged path (the mesh machinery adds sharding constraints, not math);
* 8-device mesh logits must pass the shared teacher-forced parity bound
  against the single-device dense path (``serving/parity.py`` — the same
  harness tests use, so bench and tests measure one protocol);
* per-shard pool bytes (ground truth from the device buffers) must sum to
  the single-device pool footprint;
* the ``shard_map`` paged-decode kernel must match the single-device kernel
  bit-for-bit.
"""

from __future__ import annotations

import os
import pathlib
import subprocess
import sys

REL_BOUND = 0.05        # teacher-forced max relative logits diff @ 8 devices


def _child(smoke: bool):
    """Runs inside the forced-8-device subprocess; prints CSV rows."""
    import tempfile
    import time

    import jax

    from benchmarks.common import DOCS, row
    from repro.configs import get_config
    from repro.kvstore import FlashKVStore
    from repro.launch.mesh import make_serving_mesh
    from repro.models import build_model
    from repro.serving import (ContinuousScheduler, RagEngine,
                               dense_row_path, paged_row_path,
                               teacher_forced_rel)

    assert len(jax.devices()) >= 8, "child must run with 8 forced devices"
    out = []
    n_requests, max_new = (8, 3) if smoke else (16, 5)
    # KV-head count divisible by the 8-way mesh so the pool really shards
    cfg = get_config("smollm-135m").reduced(
        vocab_size=320, num_heads=8, num_kv_heads=8, head_dim=16,
        d_model=128, d_ff=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        eng0 = RagEngine(model, params, store, mode="matkv",
                         chunk_tokens=48, top_k=2)
        for doc, text in sorted(DOCS.items()):
            eng0.ingest(doc, text)
        words = sorted(DOCS)
        qs = [f"where is the {words[i % len(words)]} artifact?"
              for i in range(n_requests)]

        def serve(eng, tag):
            sched = ContinuousScheduler(eng, max_slots=4, paged=True,
                                        block_size=32)
            sched.run(qs[:4], max_new_tokens=max_new)          # warm jit
            t0 = time.perf_counter()
            answers, m = sched.run(qs, max_new_tokens=max_new)
            wall = time.perf_counter() - t0
            sched.shutdown()
            out.append(row(f"tp_serving/{tag}/tokens_per_s", m.tokens_per_s,
                           f"wall_s={wall:.2f};hit_rate={m.chunk_hit_rate:.2f}"))
            return answers, m

        ans0, m0 = serve(eng0, "mesh0_single_device")

        def mesh_engine(n):
            eng = RagEngine(model, params, store, mode="matkv",
                            chunk_tokens=48, top_k=2,
                            mesh=make_serving_mesh(n))
            eng._chunks, eng.vdb = eng0._chunks, eng0.vdb
            return eng

        # 1-device mesh: the sharding machinery must be a numeric no-op
        ans1, m1 = serve(mesh_engine(1), "mesh1")
        assert ans1 == ans0, (
            "1-device-mesh paged answers diverged from the single-device "
            "path — the mesh threading changed numerics")
        out.append(row("tp_serving/mesh1/bit_parity", 0.0, "exact=True"))

        # 8-device mesh: sharded pool + TP decode
        eng8 = mesh_engine(8)
        ans8, m8 = serve(eng8, "mesh8")
        shard_bytes = m8.pool_shard_bytes
        assert len(shard_bytes) == 8, shard_bytes
        assert sum(shard_bytes) == sum(m0.pool_shard_bytes), (
            f"per-shard pool bytes {shard_bytes} do not sum to the "
            f"single-device footprint {m0.pool_shard_bytes}")
        out.append(row(
            "tp_serving/mesh8/pool_bytes_per_shard", float(shard_bytes[0]),
            f"n_shards=8;sum={sum(shard_bytes)};"
            f"single_device={m0.pool_shard_bytes[0]}"))

        # teacher-forced logits parity: single-device dense vs 8-device paged
        buf = 192
        rel = teacher_forced_rel(eng0, dense_row_path(eng0, buf),
                                 eng8, paged_row_path(eng8, buf),
                                 qs[0], steps=2 if smoke else 4)
        assert rel < REL_BOUND, (
            f"8-device teacher-forced rel diff {rel:.4f} over {REL_BOUND}")
        out.append(row("tp_serving/mesh8/teacher_forced_rel", rel,
                       f"bound={REL_BOUND}"))

        # shard_map kernel: bit parity against the single-device kernel
        # (one probe shared with tests/test_dist_serving.py)
        from repro.kernels.paged_decode import tp_parity_probe
        assert tp_parity_probe(make_serving_mesh(8)), (
            "paged_decode_tp diverged from the single-device kernel")
        out.append(row("tp_serving/mesh8/kernel_bit_parity", 0.0,
                       "exact=True"))

        # fused single-launch kernel: the serves above ran it (scheduler
        # default); pin the three-phase pipeline on the same 8-way engine
        # and require identical answers, plus bit parity of the shard_map
        # fused twin against its single-device kernel
        from repro.kernels.paged_decode_fused import fused_tp_parity_probe
        sched3 = ContinuousScheduler(eng8, max_slots=4, paged=True,
                                     block_size=32, fused=False)
        ans8_3p, _ = sched3.run(qs, max_new_tokens=max_new)
        sched3.shutdown()
        assert ans8_3p == ans8, (
            "8-device fused paged decode diverged from the three-phase "
            "parity oracle")
        assert fused_tp_parity_probe(make_serving_mesh(8)), (
            "paged_decode_fused_tp diverged from the single-device fused "
            "kernel")
        out.append(row("tp_serving/mesh8/fused_kernel_bit_parity", 0.0,
                       "exact=True;answers_exact=True"))

        # DESIGN §Roofline-accounting: the fused step must move strictly
        # fewer HBM KV bytes than three-phase at this engine's geometry
        from repro.analysis.roofline import paged_step_kv_bytes
        buf, block = 192, 32
        b3 = paged_step_kv_bytes(cfg.num_layers, cfg.num_kv_heads,
                                 cfg.head_dim, [buf] * 4, block, buf,
                                 storage_bytes=2, act_bytes=2, fused=False)
        bf = paged_step_kv_bytes(cfg.num_layers, cfg.num_kv_heads,
                                 cfg.head_dim, [buf] * 4, block, buf,
                                 storage_bytes=2, act_bytes=2, fused=True)
        assert bf < b3, (
            f"roofline model: fused step {bf} KV bytes vs three-phase {b3}")
        out.append(row("tp_serving/fused_kv_bytes_per_step", float(bf),
                       f"three_phase={b3};ratio={bf / b3:.3f}"))
    print("\n".join(out))


def run(smoke: bool = False):
    """Spawn the forced-8-host-device child and relay its CSV rows. The
    parent process may already hold a single-device jax runtime, so the
    device-count flag has to be set before a fresh interpreter boots."""
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    env.setdefault("REPRO_PALLAS_INTERPRET", "1")
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root), str(root / "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    cmd = [sys.executable, "-m", "benchmarks.bench_tp_serving", "--child"]
    if smoke:
        cmd.append("--smoke")
    proc = subprocess.run(cmd, capture_output=True, text=True, env=env,
                          cwd=root, timeout=1800)
    if proc.returncode != 0:
        raise RuntimeError(f"tp_serving child failed:\n{proc.stderr[-4000:]}")
    return [line for line in proc.stdout.splitlines() if line.strip()]


if __name__ == "__main__":
    if "--child" in sys.argv:
        _child(smoke="--smoke" in sys.argv)
    else:
        print("\n".join(run()))
