"""Int8 vs bf16 KV codec under one HBM byte budget (DESIGN.md §11).

MatKV's economics scale with flash bytes, and the paged pool's sharing win
(DESIGN.md §10) scales with how many chunks one HBM budget keeps resident.
The codec layer moves both at once: int8 artifacts are ~0.52x the flash
bytes, and an int8 pool packs ~1.94x the blocks into the same budget, so
under the PR-3 Zipf workload the int8 run keeps the hot set resident where
the bf16 run is forced to reclaim and re-read.

Two ``ContinuousScheduler(paged=True)`` runs serve the same Zipf request
stream — one engine per codec, pools sized from the SAME ``pool_budget_bytes``
— and we report flash bytes actually read (ground truth from the store
counters), peak distinct resident chunks, and the pool hit rate. The
acceptance bar asserts, at equal budget, int8 vs bf16:

* <= 0.55x flash bytes loaded,
* >= 1.8x peak resident chunks (the higher hit rate follows),
* ``paged_decode_quant`` bit-exact vs its (jitted) ref oracle,
* paged int8 logits within a 5% rel bound of the non-paged int8 engine
  path (identical answers on this workload), teacher-forced so the
  comparison cannot diverge on an argmax flip.
"""

from __future__ import annotations

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from benchmarks.common import DOCS, emit_result, make_engine, row

from repro.core.quantize import quantize_kv
from repro.kernels import ref
from repro.kernels.paged_decode_quant import paged_decode_quant
from repro.paged import PagedKvPool
from repro.serving import (ContinuousScheduler, dense_row_path,
                           paged_row_path, teacher_forced_rel)

BLOCK = 32
SLOTS = 4
LOGITS_REL_BOUND = 0.05      # stated bound: paged int8 vs dense int8 logits


def _zipf_workload(eng, n_requests: int, seed: int):
    """Distinct questions mapped to Zipf-popular docs' chunks (mapping pins
    retrieval so every engine serves identical rows). Each request reads a
    random ``top_k``-chunk window of its doc, so the touched set is large
    enough that BOTH pools are capacity-limited — the comparison then
    measures how many chunks each codec keeps resident, not the workload's
    ceiling."""
    rng = np.random.default_rng(seed)
    doc_ids = sorted(DOCS)
    ranks = np.arange(1, len(doc_ids) + 1, dtype=np.float64)
    popularity = (1.0 / ranks) / (1.0 / ranks).sum()
    chunks_by_doc = {d: [cid for cid, c in eng._chunks.items()
                         if c.doc_id == d] for d in doc_ids}
    qs, mapping = [], {}
    for i in range(n_requests):
        d = doc_ids[int(rng.choice(len(doc_ids), p=popularity))]
        chunks = chunks_by_doc[d]
        j = int(rng.integers(0, max(1, len(chunks) - eng.top_k + 1)))
        q = f"q{i}: where is the {d} artifact?"
        qs.append(q)
        mapping[q] = chunks[j:j + eng.top_k]
    arrivals = np.cumsum(rng.exponential(0.02, n_requests)).tolist()
    return qs, mapping, arrivals


def _serve(eng, qs, arrivals, max_new, budget_bytes, warm=True):
    store = eng.store
    sched = ContinuousScheduler(eng, max_slots=SLOTS, paged=True,
                                block_size=BLOCK,
                                pool_budget_bytes=budget_bytes)
    if warm:                       # jit warm-up so tokens_per_s is honest;
        sched.run(qs, max_new_tokens=max_new)   # flash/residency don't care
    read0 = store.stats.bytes_read
    _, m = sched.run(qs, max_new_tokens=max_new, arrivals_s=arrivals)
    sched.shutdown()
    return m, store.stats.bytes_read - read0


def _assert_kernel_bit_exact(rng_key):
    """``paged_decode_quant`` vs its oracle on shared / ragged / padding
    blocks — jitted oracle, bitwise equality."""
    b, h, kv, hd, block, n_pool = 2, 8, 2, 64, BLOCK, 8
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    k_pool, k_s = quantize_kv(jax.random.normal(ks[1], (n_pool, kv, block, hd)))
    v_pool, v_s = quantize_kv(jax.random.normal(ks[2], (n_pool, kv, block, hd)))
    k_s, v_s = k_s[..., 0], v_s[..., 0]
    tbl = jnp.asarray([[3, 1, 4, 0], [1, 2, 0, 0]], jnp.int32)   # block 1 shared
    lens = jnp.asarray([[block, block, 14, 0], [block, 7, 0, 0]], jnp.int32)
    out = paged_decode_quant(q, k_pool, v_pool, k_s, v_s, tbl, lens)
    oracle = jax.jit(ref.paged_decode_quant_ref)(q, k_pool, v_pool, k_s, v_s,
                                                 tbl, lens)
    assert bool(jnp.all(out == oracle)), (
        "paged_decode_quant must be bit-exact vs paged_decode_quant_ref")


def _logits_parity(eng, question: str, buf: int, steps: int) -> float:
    """Teacher-forced max relative logits diff: dense int8 engine path vs
    the paged int8 path — the same harness the acceptance test runs
    (``repro.serving.parity``), so bench and test measure one protocol."""
    return teacher_forced_rel(eng, dense_row_path(eng, buf),
                              eng, paged_row_path(eng, buf,
                                                  block_size=BLOCK),
                              question, steps=steps)


def run(n_requests: int = 32, max_new: int = 4, seed: int = 0,
        budget_blocks_bf16: int = 28, smoke: bool = False):
    warm = not smoke
    if smoke:
        # same workload shape (the residency ratio needs the full touched
        # set), shorter decode and no jit warm-up pass
        max_new = 2
    out = []
    _assert_kernel_bit_exact(jax.random.PRNGKey(seed))
    out.append(row("kernel/paged_decode_quant_vs_ref", 0.0, "bit_exact=1"))
    with tempfile.TemporaryDirectory() as d:
        engines = {c: make_engine("matkv", f"{d}/{c}", codec=c)
                   for c in ("bf16", "int8")}
        # one HBM byte budget for both pools; the codec decides how many
        # blocks (and so resident chunks) it buys
        budget = budget_blocks_bf16 * PagedKvPool.block_bytes(
            engines["bf16"].cfg, BLOCK, "bf16")
        qs, mapping, arrivals = _zipf_workload(engines["bf16"], n_requests,
                                               seed)
        metrics, flash, stored = {}, {}, {}
        for codec, eng in engines.items():
            eng.retrieve = lambda q, m=mapping: list(m.get(q, []))
            stored[codec] = eng.store.total_bytes()
            metrics[codec], flash[codec] = _serve(eng, qs, arrivals,
                                                  max_new, budget, warm=warm)
            m = metrics[codec]
            out.append(row(
                f"{codec}/flash_bytes", flash[codec],
                f"budget={budget};resident_chunks={m.resident_chunks_peak};"
                f"hit_rate={m.chunk_hit_rate:.2f};"
                f"tokens_per_s={m.tokens_per_s:.1f}"))
            emit_result("quant_residency", codec, metrics=m,
                        flash_bytes=int(flash[codec]), budget_bytes=budget,
                        resident_chunks_peak=m.resident_chunks_peak,
                        chunk_hit_rate=m.chunk_hit_rate)
        flash_ratio = flash["int8"] / max(flash["bf16"], 1)
        chunks_ratio = (metrics["int8"].resident_chunks_peak
                        / max(metrics["bf16"].resident_chunks_peak, 1))
        out.append(row(
            "int8_vs_bf16/savings", 0.0,
            f"flash_ratio={flash_ratio:.3f};chunks_ratio={chunks_ratio:.2f};"
            f"stored_ratio={stored['int8'] / max(stored['bf16'], 1):.3f};"
            f"hit_rate_bf16={metrics['bf16'].chunk_hit_rate:.2f};"
            f"hit_rate_int8={metrics['int8'].chunk_hit_rate:.2f}"))
        emit_result("quant_residency", "int8_vs_bf16",
                    flash_ratio=flash_ratio, chunks_ratio=chunks_ratio,
                    stored_ratio=stored["int8"] / max(stored["bf16"], 1))
        # acceptance: equal budget, int8 must halve flash traffic and
        # near-double residency (the hit-rate gain follows from the latter)
        assert flash_ratio <= 0.55, (
            f"int8 read {flash_ratio:.3f}x the bf16 flash bytes at equal "
            f"HBM budget — the codec stopped paying for itself")
        assert chunks_ratio >= 1.8, (
            f"int8 held only {chunks_ratio:.2f}x the bf16 resident chunks "
            f"at equal HBM budget (expected ~1.94x from the byte ratio)")
        assert (metrics["int8"].chunk_hit_rate
                >= metrics["bf16"].chunk_hit_rate), (
            "int8's larger effective pool must not lower the hit rate")
        # paged int8 vs the non-paged int8 engine path, at the logits level
        eng8 = engines["int8"]
        max_rel = _logits_parity(eng8, qs[0], buf=192,
                                 steps=2 if smoke else 6)
        out.append(row("int8/paged_vs_dense_logits_rel", 0.0,
                       f"max_rel={max_rel:.2e};bound={LOGITS_REL_BOUND}"))
        assert max_rel <= LOGITS_REL_BOUND, (
            f"paged int8 logits drifted {max_rel:.3f} rel from the dense "
            f"int8 path (bound {LOGITS_REL_BOUND}) — tail quantization "
            f"noise should stay an order of magnitude below this")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
