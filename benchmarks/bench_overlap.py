"""Paper Fig. 7: overlapped KV loading + decode vs strictly serialized MatKV.

Ported onto the paged/continuous path (the BatchScheduler original predates
the pool): both arms serve the same requests through
``ContinuousScheduler(paged=True)`` over one throttled *shared-link*
``SimulatedReader``, so the flash budget is identical and only the schedule
differs.

* **serial** — one ``run()`` per request: each request's chunk reads fully
  drain before its decode starts, and the next request starts cold after.
  This is the all-or-nothing MatKV pipeline of the original figure.
* **overlap** — one ``run()`` over all requests: the async loader prefetches
  later requests' pages while earlier requests decode, so flash-read wall
  time hides behind ``decode_step`` spans.

The asserted metric is the trace-derived ``load_overlap_frac`` (> 0: some
flash-read time really ran in decode's shadow — the same join
``bench_streaming_admission`` uses); the wall-clock speedup is reported for
the figure but not asserted, since single-core hosts under-deliver it.
Both arms append schema'd records to results.jsonl (``emit_result``) so
``analysis/report.py --serving`` renders Fig. 7 alongside the other serving
benches.
"""

from __future__ import annotations

import tempfile
import time

from benchmarks.common import QUESTIONS, emit_result, make_engine, row

from repro.core.economics import SsdSpec
from repro.kvstore import SimulatedReader
from repro.obs import Tracer
from repro.serving import ContinuousScheduler, RagEngine

BLOCK = 32
SLOTS = 2
THROTTLED = SsdSpec("throttled", 0.1, 0.002, 7.0)    # 2 MB/s: loads matter


def _clone(base, reader):
    eng = RagEngine(base.model, base.params, base.store, mode="matkv",
                    chunk_tokens=base.chunk_tokens, top_k=base.top_k,
                    codec="bf16", reader=reader)
    eng._chunks, eng.vdb = base._chunks, base.vdb
    return eng


def _sched(eng, tracer=None):
    return ContinuousScheduler(eng, max_slots=SLOTS, paged=True,
                               block_size=BLOCK, tracer=tracer)


def run(n_requests: int = 8, max_new_tokens: int = 6, smoke: bool = False):
    if smoke:
        n_requests, max_new_tokens = 4, 3
    out = []
    qs = [QUESTIONS[i % len(QUESTIONS)] for i in range(n_requests)]
    with tempfile.TemporaryDirectory() as d:
        base = make_engine("matkv", d)
        walls = {}
        for arm in ("serial", "overlap"):
            eng = _clone(base, SimulatedReader(base.store, THROTTLED,
                                               shared_link=True))
            sched = _sched(eng)
            sched.run(qs[:SLOTS], max_new_tokens=max_new_tokens)  # warm jit
            sched.shutdown()
            tr = Tracer(role="bench") if arm == "overlap" else None
            t0 = time.perf_counter()
            if arm == "serial":
                # one run() per request: every pool is fresh and each
                # request's reads drain before its decode starts
                for q in qs:
                    sched = _sched(eng)
                    _, m = sched.run([q], max_new_tokens=max_new_tokens)
                    sched.shutdown()
            else:
                sched = _sched(eng, tracer=tr)
                _, m = sched.run(qs, max_new_tokens=max_new_tokens)
                sched.shutdown()
            walls[arm] = time.perf_counter() - t0
            out.append(row(f"fig7/{arm}", walls[arm] / n_requests * 1e6,
                           f"wall_s={walls[arm]:.3f};"
                           f"tokens_per_s={m.tokens_per_s:.1f}"))
            emit_result("fig7_overlap", arm, metrics=m,
                        wall_s=walls[arm], n_requests=n_requests,
                        load_overlap_frac=m.load_overlap_frac)
        speedup = walls["serial"] / walls["overlap"]
        out.append(row("fig7/speedup_x", 0.0,
                       f"ratio={speedup:.2f};"
                       f"load_overlap_frac={m.load_overlap_frac:.2f}"))
        emit_result("fig7_overlap", "speedup", speedup_x=speedup,
                    load_overlap_frac=m.load_overlap_frac)
        assert m.load_overlap_frac > 0.0, (
            "no flash-read time overlapped decode steps in the overlap arm "
            "— the async loader stopped prefetching behind decode")
    return out


if __name__ == "__main__":
    print("\n".join(run()))
