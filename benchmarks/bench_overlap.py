"""Paper Fig. 7: overlapped KV loading + decode vs strictly serialized MatKV.

A throttled reader makes the load phase substantial; the overlapped scheduler
must hide most of it behind decode."""

from __future__ import annotations

import tempfile
import time

from benchmarks.common import QUESTIONS, make_engine, row
from repro.core.economics import SsdSpec
from repro.kvstore import SimulatedReader
from repro.serving import BatchScheduler, RagEngine


def run(n_requests: int = 8, max_new_tokens: int = 6):
    out = []
    qs = [QUESTIONS[i % len(QUESTIONS)] for i in range(n_requests)]
    with tempfile.TemporaryDirectory() as d:
        base = make_engine("matkv", d)
        slow = SsdSpec("throttled", 0.1, 0.002, 7.0)  # 2 MB/s: loads matter
        walls = {}
        for overlap in (False, True):
            reader = SimulatedReader(base.store, slow)
            eng = RagEngine(base.model, base.params, base.store, mode="matkv",
                            chunk_tokens=base.chunk_tokens, top_k=base.top_k,
                            reader=reader)
            eng._chunks, eng.vdb = base._chunks, base.vdb
            sched = BatchScheduler(eng, batch_size=2, overlap=overlap)
            t0 = time.perf_counter()
            _, t = sched.run(qs, max_new_tokens=max_new_tokens)
            wall = time.perf_counter() - t0
            walls[overlap] = wall
            name = "overlap" if overlap else "serial"
            out.append(row(f"fig7/{name}", wall / n_requests * 1e6,
                           f"load_s={t.load_s:.3f}"))
        out.append(row("fig7/speedup_x", 0.0,
                       f"ratio={walls[False] / walls[True]:.2f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
