"""Paper Fig. 9: MatKV's benefit vs model size — prefill compute grows faster
than KV size, so the benefit amplifies with scale.

Two parts: (a) measured on CPU across 3 reduced model widths; (b) analytic at
paper scale for LLaMA 3B / 8B / 70B (prefill seconds vs KV MB per 1,024-token
chunk, and their ratio = MatKV's advantage)."""

from __future__ import annotations

import tempfile

import jax
from benchmarks.common import CHUNK_TOKENS, DOCS, QUESTIONS, row, timeit

from repro.configs import get_config
from repro.core.economics import H100, RAID0_9100_PRO_X4, load_cost
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.serving import RagEngine


def run():
    out = []
    # (a) measured: reduced widths
    for d_model, layers in ((64, 2), (128, 2), (256, 4)):
        cfg = get_config("smollm-135m").reduced(
            vocab_size=300, d_model=d_model, num_layers=layers,
            d_ff=d_model * 2)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            eng = RagEngine(model, params, FlashKVStore(d), mode="matkv",
                            chunk_tokens=CHUNK_TOKENS, top_k=2)
            for did, text in list(DOCS.items())[:4]:
                eng.ingest(did, text)
            q = QUESTIONS[0]
            t = timeit(lambda: eng.answer(q, max_new_tokens=2), warmup=1,
                       iters=2)
            kv_per_tok = cfg.kv_bytes_per_token()
            out.append(row(f"fig9a/d{d_model}l{layers}", t * 1e6,
                           f"kv_bytes_per_tok={kv_per_tok}"))
    # (b) analytic at paper scale
    for name in ("llama-3.2-3b", "llama-3.1-8b", "llama-3.1-70b"):
        cfg = get_config(name)
        # prefill rate scales inversely with active params (H100 ref = 70B)
        rate = H100.prefill_tokens_per_s * (70.55e9 / cfg.param_count())
        t_pref = 1024 / rate
        kv_mb = cfg.kv_bytes_per_token(2) * 1024 / 1e6
        t_load, _ = load_cost(RAID0_9100_PRO_X4, kv_mb * 1e6)
        out.append(row(f"fig9b/{name}", t_pref * 1e6,
                       f"kv_mb={kv_mb:.0f};load_s={t_load:.4f};"
                       f"benefit_x={t_pref / t_load:.1f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
