"""Fused single-launch paged decode vs the three-phase pipeline (DESIGN.md
§13).

The three-phase paged step moves the whole dense working set through HBM
every token: gather reads each row's pool pages and writes an
activation-width ``(B, S_buf)`` view, the jitted step reads that view and
writes updated buffers back out, and the scatter persists the new token. The
fused kernel replaces all of it with one Pallas launch per layer that reads
each row's occupied pages exactly once at *storage* width (int8 pages + f16
scales dequantize in VMEM next to the attention dot) and appends the new
token into the row's private tail block — nothing ``(B, S_buf)``-sized ever
round-trips through HBM.

Serves one Zipf-free closed-loop workload twice per codec — fused, then
pinned three-phase (``ContinuousScheduler(fused=False)``) — and checks:

* answers are IDENTICAL between the two pipelines (bf16 bit-parity at the
  logits level makes greedy decode deterministic; int8 shares the same
  stored quantized pages so parity holds there too);
* the DESIGN §Roofline-accounting KV-byte model
  (``repro.analysis.roofline.paged_step_kv_bytes``) puts the fused step's
  per-token HBM traffic strictly below three-phase, at worst-case full
  buffers AND at half-full typical occupancy, for both codecs.

CPU wall-times are reported for the relative trend only; interpret-mode
Pallas undersells the fused win (it emulates the VMEM pipeline in pure
Python), so the byte model is the asserted metric.
"""

from __future__ import annotations

import tempfile
import time

from benchmarks.common import DOCS, emit_result, make_engine, row

from repro.analysis.roofline import paged_step_kv_bytes_for_pool
from repro.serving import ContinuousScheduler

BUF, BLOCK = 192, 32


def _serve(eng, qs, max_new, slots, fused):
    sched = ContinuousScheduler(eng, max_slots=slots, buf_size=BUF,
                                paged=True, block_size=BLOCK, fused=fused)
    sched.run(qs[:slots], max_new_tokens=max_new)            # warm jit
    t0 = time.perf_counter()
    answers, m = sched.run(qs, max_new_tokens=max_new)
    wall = time.perf_counter() - t0
    sched.shutdown()
    return answers, m, wall


def _roofline_rows(eng, slots, codec, out):
    """Assert the fused HBM-traffic win against the roofline KV-byte model,
    with widths read off a live pool (storage/scale/view dtypes)."""
    pcache = eng.init_paged_cache(slots, BUF, block_size=BLOCK)
    pool = pcache.pool
    for tag, lengths in (("worst", [BUF] * slots),
                         ("typical", [BUF // 2] * slots)):
        b3 = paged_step_kv_bytes_for_pool(pool, lengths, buf_size=BUF,
                                          fused=False)
        bf = paged_step_kv_bytes_for_pool(pool, lengths, buf_size=BUF,
                                          fused=True)
        assert bf < b3, (
            f"roofline model: fused step moves {bf} KV bytes vs "
            f"three-phase {b3} ({codec}, {tag}) — the fusion lost its "
            f"HBM-traffic win")
        out.append(row(f"fused_decode/{codec}/{tag}/kv_bytes_per_step",
                       float(bf),
                       f"three_phase={b3};ratio={bf / b3:.3f};"
                       f"buf={BUF};block={BLOCK};slots={slots}"))


def run(n_requests: int = 16, slots: int = 4, max_new: int = 6,
        smoke: bool = False):
    codecs = ["bf16", "int8"]
    if smoke:
        n_requests, max_new, codecs = 8, 3, ["bf16"]
    words = sorted(DOCS)
    qs = [f"where is the {words[i % len(words)]} artifact?"
          for i in range(n_requests)]
    out = []
    for codec in codecs:
        with tempfile.TemporaryDirectory() as d:
            eng = make_engine("matkv", d + "/m", codec=codec)
            ans3, m3, w3 = _serve(eng, qs, max_new, slots, fused=False)
            ansf, mf, wf = _serve(eng, qs, max_new, slots, fused=True)
            assert ansf == ans3, (
                f"fused paged decode diverged from the three-phase parity "
                f"oracle under codec={codec}")
            out.append(row(f"fused_decode/{codec}/three_phase_tokens_per_s",
                           m3.tokens_per_s, f"wall_s={w3:.2f}"))
            out.append(row(f"fused_decode/{codec}/fused_tokens_per_s",
                           mf.tokens_per_s,
                           f"wall_s={wf:.2f};answers_exact=True"))
            emit_result("fused_decode", f"three_phase-{codec}", metrics=m3,
                        wall_s=w3)
            emit_result("fused_decode", f"fused-{codec}", metrics=mf,
                        wall_s=wf, answers_exact=True)
            _roofline_rows(eng, slots, codec, out)
    return out


if __name__ == "__main__":
    print("\n".join(run()))
