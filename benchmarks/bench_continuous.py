"""Continuous vs fixed batching under Poisson arrivals (beyond-paper;
KV-offloading bottleneck analysis in PAPERS.md motivates per-request
admission).

An open-loop arrival process with mixed per-request decode lengths is served
two ways:

  fixed       ``BatchScheduler(overlap=True)`` behind an arrival-aware batch
              former: a batch launches once ``batch_size`` requests have
              arrived (or the stream ends), and every row decodes the batch
              max ``max_new_tokens`` (the fixed-geometry constraint).
  continuous  ``ContinuousScheduler``: per-request admission, EOS /
              per-request-length eviction, slot backfill, per-request KV
              prefetch.

Reported per scheduler: useful tokens/sec and p50/p95 request latency
(arrival -> answer). Useful tokens = tokens actually kept per request, so the
fixed scheduler's dead-air decode steps hurt its tokens/sec, exactly the
effect continuous batching removes.
"""

from __future__ import annotations

import tempfile
import time

import numpy as np
from benchmarks.common import QUESTIONS, emit_result, make_engine, row

from repro.serving import BatchScheduler, ContinuousScheduler

MAX_NEW_CHOICES = (2, 4, 8, 16)


def _workload(n_requests: int, seed: int, mean_gap_s: float):
    rng = np.random.default_rng(seed)
    qs = [QUESTIONS[int(rng.integers(len(QUESTIONS)))]
          for _ in range(n_requests)]
    max_new = [int(rng.choice(MAX_NEW_CHOICES)) for _ in range(n_requests)]
    arrivals = np.cumsum(rng.exponential(mean_gap_s, n_requests)).tolist()
    return qs, max_new, arrivals


def _serve_fixed(engine, qs, max_new, arrivals, batch_size: int):
    """Arrival-aware fixed batching: wait for a full batch (requests are
    invisible before their arrival time), then run the overlapped
    BatchScheduler on it at the batch-max decode length."""
    sched = BatchScheduler(engine, batch_size=batch_size, overlap=True)
    t0 = time.perf_counter()
    latencies, n_useful = [], 0
    for i in range(0, len(qs), batch_size):
        j = min(i + batch_size, len(qs))
        # the batch can't form before its last member arrives
        gate = arrivals[j - 1]
        wait = gate - (time.perf_counter() - t0)
        if wait > 0:
            time.sleep(wait)
        sched.run(qs[i:j], max_new_tokens=max(max_new[i:j]))
        done = time.perf_counter() - t0
        for r in range(i, j):
            latencies.append(done - arrivals[r])
            # credit the full per-request budget (generous to fixed: EOS
            # tails count as useful); the dead-air penalty it pays is the
            # extra decode steps up to the batch max
            n_useful += max_new[r]
    wall = time.perf_counter() - t0
    return wall, n_useful, latencies


def run(n_requests: int = 16, batch_size: int = 4, seed: int = 0,
        mean_gap_s: float = 0.05):
    out = []
    qs, max_new, arrivals = _workload(n_requests, seed, mean_gap_s)
    with tempfile.TemporaryDirectory() as d:
        eng = make_engine("matkv", d + "/m")

        cont = ContinuousScheduler(eng, max_slots=batch_size)
        # warm every shape the timed pass will hit (each distinct prompt
        # length retraces the batch=1 sub-prefill; buf is workload-bucketed)
        cont.run(qs, max_new_tokens=max_new)
        _, m = cont.run(qs, max_new_tokens=max_new, arrivals_s=arrivals)
        cont.shutdown()
        out.append(row("continuous/tokens_per_s", m.tokens_per_s,
                       f"n={n_requests};slots={batch_size}"))
        out.append(row("continuous/p50_latency_us", m.p50_latency_s * 1e6))
        out.append(row("continuous/p95_latency_us", m.p95_latency_s * 1e6))
        out.append(row("continuous/p95_ttft_us", m.p95_ttft_s * 1e6))
        emit_result("continuous_batching", "continuous", metrics=m,
                    n_requests=n_requests, slots=batch_size)

        _serve_fixed(eng, qs, max_new, [0.0] * n_requests,
                     batch_size)                               # warm jit
        wall, n_useful, lats = _serve_fixed(eng, qs, max_new, arrivals,
                                            batch_size)
        fixed_tps = n_useful / wall if wall else 0.0
        out.append(row("fixed_overlap/tokens_per_s", fixed_tps,
                       f"n={n_requests};bs={batch_size}"))
        out.append(row("fixed_overlap/p50_latency_us",
                       float(np.quantile(lats, 0.5)) * 1e6))
        out.append(row("fixed_overlap/p95_latency_us",
                       float(np.quantile(lats, 0.95)) * 1e6))
        out.append(row(
            "continuous_vs_fixed/speedup",
            m.tokens_per_s / fixed_tps if fixed_tps else 0.0,
            f"p95_ratio={np.quantile(lats, 0.95) / max(m.p95_latency_s, 1e-9):.2f}"))
        emit_result("continuous_batching", "fixed_overlap",
                    tokens_per_s=fixed_tps,
                    p95_latency_s=float(np.quantile(lats, 0.95)),
                    n_requests=n_requests, batch_size=batch_size)
    return out


if __name__ == "__main__":
    print("\n".join(run()))
