"""Shared fixtures for the benchmark suite.

CPU wall-times here are for *relative* comparisons (MatKV vs Vanilla vs
CacheBlend phase structure); absolute H100/SSD-scale numbers come from the
analytical model in repro.core.economics with the paper's constants. Each
benchmark prints ``name,us_per_call,derived`` CSV rows, and the serving
benches additionally append machine-readable records to
``experiments/serving/results.jsonl`` via :func:`emit_result` — the file
``analysis/report.py`` renders (DESIGN.md §15).
"""

from __future__ import annotations

import functools
import json
import os
import time
from pathlib import Path

import jax

from repro.configs import get_config
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.serving import RagEngine

# schema for results.jsonl records (bump on breaking field changes; the
# report skips records whose schema it doesn't know)
RESULTS_SCHEMA = 1

DOCS = {
    f"doc{i:02d}": (f"the {w} artifact number {i} rests in chamber {i * 7}. "
                    * 6)
    for i, w in enumerate(
        ["amber", "basil", "cedar", "delta", "ember", "fjord", "grove",
         "haven", "iris", "jade", "karst", "lotus"])
}
QUESTIONS = [f"where is the {w} artifact?" for w in
             ["amber", "basil", "cedar", "delta", "ember", "fjord"]]

CHUNK_TOKENS = 64


@functools.lru_cache(maxsize=4)
def small_model(arch: str = "smollm-135m", layers: int = 2, d_model: int = 128):
    cfg = get_config(arch).reduced(vocab_size=300, num_layers=layers,
                                   d_model=min(d_model, 512))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def make_engine(mode: str, store_dir: str, arch: str = "smollm-135m",
                top_k: int = 2, **kw) -> RagEngine:
    cfg, model, params = small_model(arch)
    store = FlashKVStore(store_dir)
    eng = RagEngine(model, params, store, mode=mode,
                    chunk_tokens=CHUNK_TOKENS, top_k=top_k, **kw)
    for d, text in DOCS.items():
        eng.ingest(d, text)
    return eng


def timeit(fn, warmup: int = 1, iters: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        fn()
    return (time.perf_counter() - t0) / iters


def row(name: str, us_per_call: float, derived: str = "") -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def results_path() -> Path:
    """Where ``emit_result`` appends: ``$REPRO_RESULTS`` if set, else
    ``experiments/serving/results.jsonl`` under the repo root. Relative
    overrides resolve against the repo root so subprocess benches (which
    run with ``cwd=root``) and direct invocations agree on one file."""
    root = Path(__file__).resolve().parent.parent
    override = os.environ.get("REPRO_RESULTS")
    if override:
        p = Path(override)
        return p if p.is_absolute() else root / p
    return root / "experiments" / "serving" / "results.jsonl"


def emit_result(suite: str, name: str, metrics=None, **derived) -> dict:
    """Append one machine-readable benchmark record to results.jsonl.

    ``metrics`` may be a ``ServeMetrics`` (serialized via ``as_dict()``,
    schema-tagged) or any plain dict; ``derived`` carries scalar
    suite-specific fields (ratios, tok/s, trace paths). Returns the record
    so callers can assert on what was written."""
    rec = {"schema": RESULTS_SCHEMA, "suite": suite, "name": name,
           "time": time.time()}
    rec.update(derived)
    if metrics is not None:
        rec["metrics"] = (metrics.as_dict() if hasattr(metrics, "as_dict")
                          else dict(metrics))
    path = results_path()
    path.parent.mkdir(parents=True, exist_ok=True)
    with path.open("a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
    return rec
