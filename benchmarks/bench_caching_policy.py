"""Paper §III-E (Discussions): selective materialization + eviction.

The paper's evaluation materializes everything; its discussion argues a
deployment needs admission (the per-object ten-day rule) and eviction
(recency / frequency / TCO-aware). This benchmark quantifies that: a Zipf
RAG workload against a flash budget of 10% of the corpus KV footprint,
comparing eviction policies by hit rate and GPU-recompute seconds saved."""

from __future__ import annotations

import numpy as np
from benchmarks.common import row

from repro.core.economics import H100
from repro.core.tiering import (CostAwarePolicy, LfuPolicy, LruPolicy,
                                TieredStore)

N_CHUNKS = 400
KV_BYTES = 8           # stand-in payload; budget counts objects
N_QUERIES = 20_000
BUDGET_FRAC = 0.10
CHUNK_TOKENS = 1024


class _MemStore:
    def __init__(self):
        self.d = {}

    def put(self, c, p):
        self.d[c] = p

    def get(self, c):
        return self.d[c]

    def delete(self, c):
        self.d.pop(c, None)


def run():
    out = []
    rng = np.random.default_rng(7)
    probs = 1.0 / np.arange(1, N_CHUNKS + 1) ** 1.1
    probs /= probs.sum()
    accesses = rng.choice(N_CHUNKS, size=N_QUERIES, p=probs)
    budget = int(N_CHUNKS * BUDGET_FRAC) * KV_BYTES
    recompute_s = CHUNK_TOKENS / H100.prefill_tokens_per_s

    for name, mk in (("lru", lambda c: LruPolicy()),
                     ("lfu", lambda c: LfuPolicy()),
                     ("cost_aware", lambda c: CostAwarePolicy(now_fn=c))):
        t = [0.0]
        clock = lambda: t[0]
        ts = TieredStore(_MemStore(), budget, eviction=mk(clock),
                         now_fn=clock)
        for step, i in enumerate(accesses):
            t[0] = float(step + 1)
            cid = f"chunk{i:04d}"
            if ts.get(cid) is None:
                ts.offer(cid, b"x" * KV_BYTES)
        saved = ts.stats.hits * recompute_s
        out.append(row(f"tiering/{name}", 0.0,
                       f"hit_rate={ts.stats.hit_rate:.3f};"
                       f"evictions={ts.stats.evictions};"
                       f"gpu_s_saved={saved:.0f}"))
    out.append(row("tiering/budget", 0.0,
                   f"frac={BUDGET_FRAC};chunks={N_CHUNKS};"
                   f"queries={N_QUERIES}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
