"""Paper Table III: impact of storage performance on MatKV load time.

Replays the same KV loads through bandwidth profiles for one 9100 Pro, the
4x RAID-0 array, a PM9A3, and a DRAM tier; reports per-request average load
time (the paper's columns) plus the analytic time at paper scale (LLaMA-70B
250MB/chunk)."""

from __future__ import annotations

import tempfile

from benchmarks.common import QUESTIONS, make_engine, row

from repro.core.economics import load_cost
from repro.kvstore import PROFILES, SimulatedReader
from repro.serving import RagEngine


def run(n_requests: int = 4):
    out = []
    with tempfile.TemporaryDirectory() as d:
        base = make_engine("matkv", d)
        for profile in ("9100pro", "raid0_x4", "pm9a3", "dram"):
            reader = SimulatedReader(base.store, profile)
            eng = RagEngine(base.model, base.params, base.store, mode="matkv",
                            chunk_tokens=base.chunk_tokens, top_k=base.top_k,
                            reader=reader)
            eng._chunks, eng.vdb = base._chunks, base.vdb
            load = 0.0
            for i in range(n_requests):
                _, t = eng.answer(QUESTIONS[i % len(QUESTIONS)],
                                  max_new_tokens=4)
                load += t.load_s
            # paper scale: 250MB KV per chunk, 2 chunks
            spec = PROFILES[profile]
            t70b, _ = load_cost(spec, 2 * 250_000_000)
            out.append(row(f"table3/{profile}/load", load / n_requests * 1e6,
                           f"llama70b_2chunks_s={t70b:.4f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
