"""Paper Table VI: QA accuracy (F1) — Vanilla vs MatKV vs CacheBlend.

No pretrained weights ship with this container, so we TRAIN a small model on
the synthetic key-value QA task (repro.data.KvQaTask: answer = the value of a
named key found in one retrieved document; cross-document attention is
irrelevant by construction, mirroring the paper's central accuracy insight),
then evaluate all three serving modes with the gold + one distractor document.
Expected shape of the result (paper): MatKV within a few points of Vanilla;
CacheBlend between them."""

from __future__ import annotations

import tempfile

import jax
import numpy as np
from benchmarks.common import row

from repro.configs import get_config
from repro.data import KvQaTask, batched, f1_score
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.serving import RagEngine
from repro.training import AdamWConfig, TrainConfig, train

N_TRAIN_STEPS = 220
N_EVAL = 24


def _trained_model(task: KvQaTask):
    cfg = get_config("smollm-135m").reduced(
        vocab_size=300, num_layers=2, d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    # max_len fits 2 chunk-padded docs (2x128) + prompt + answer untruncated;
    # left-truncation used to cut the gold doc half the time (F1 = 0)
    data = iter(batched(task, batch=16, max_len=320, n_context=2, seed=3))
    tcfg = TrainConfig(steps=N_TRAIN_STEPS, log_every=100,
                       adamw=AdamWConfig(lr=3e-3, warmup_steps=20,
                                         total_steps=N_TRAIN_STEPS))
    params, _, hist = train(model, params, data, tcfg)
    return cfg, model, params, hist


def run():
    out = []
    task = KvQaTask(n_docs=6, n_facts=4, seed=0)
    cfg, model, params, hist = _trained_model(task)
    out.append(row("table6/train/final_ce", 0.0, f"ce={hist[-1]['ce']:.3f}"))
    examples = task.examples(N_EVAL)
    with tempfile.TemporaryDirectory() as d:
        store = FlashKVStore(d)
        engines = {}
        for mode in ("vanilla", "matkv", "cacheblend"):
            eng = RagEngine(model, params, store, mode=mode, chunk_tokens=64,
                            top_k=2)
            for doc_id, text in task.docs.items():
                eng.ingest(doc_id, text)
            engines[mode] = eng
        for mode, eng in engines.items():
            f1s = []
            for ex in examples:
                pred, _ = eng.answer(ex.question, max_new_tokens=10)
                f1s.append(f1_score(pred, ex.answer))
            out.append(row(f"table6/{mode}/f1", 0.0,
                           f"f1={float(np.mean(f1s)):.3f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
