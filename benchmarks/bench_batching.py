"""Paper Fig. 6: batched inference, batch sizes 1..8 — prefill scales linearly
with batch while decode grows sublinearly; past ~batch 8 prefill dominates and
MatKV's advantage widens."""

from __future__ import annotations

import tempfile

from benchmarks.common import QUESTIONS, make_engine, row

from repro.serving import BatchScheduler


def run(n_requests: int = 8, max_new_tokens: int = 6):
    out = []
    qs = [QUESTIONS[i % len(QUESTIONS)] for i in range(n_requests)]
    with tempfile.TemporaryDirectory() as d:
        for mode in ("vanilla", "matkv"):
            if mode == "vanilla":
                eng = make_engine("vanilla", d + "/v")
                # vanilla path is per-request; emulate batching cost shape by
                # sequential requests (prefill dominates identically)
                import time
                for q in qs:                 # warm jit for every prompt shape
                    eng.answer(q, max_new_tokens=max_new_tokens)
                for bs in (1, 2, 4):
                    t0 = time.perf_counter()
                    for q in qs:
                        eng.answer(q, max_new_tokens=max_new_tokens)
                    total = time.perf_counter() - t0
                    out.append(row(f"fig6/vanilla/bs{bs}",
                                   total / n_requests * 1e6))
            else:
                eng = make_engine("matkv", d + "/m")
                for bs in (1, 2, 4):
                    sched = BatchScheduler(eng, batch_size=bs, overlap=False)
                    import time
                    sched.run(qs, max_new_tokens=max_new_tokens)   # warm jit
                    t0 = time.perf_counter()
                    _, t = sched.run(qs, max_new_tokens=max_new_tokens)
                    total = time.perf_counter() - t0
                    out.append(row(
                        f"fig6/matkv/bs{bs}", total / n_requests * 1e6,
                        f"prefill={t.prefill_s:.3f};decode={t.decode_s:.3f};"
                        f"load={t.load_s:.3f}"))
    return out


if __name__ == "__main__":
    print("\n".join(run()))
