"""Benchmark driver — one module per paper table/figure (DESIGN.md §7).

Prints ``name,us_per_call,derived`` CSV rows. ``--only fig5`` (etc.) runs a
subset; default runs everything. The roofline table is produced separately by
``python -m repro.launch.dryrun`` (it needs the 512-device host platform).
"""

from __future__ import annotations

import argparse
import pathlib
import sys
import time
import traceback

# make `python benchmarks/run.py` work from anywhere: the suite modules
# import as `benchmarks.bench_*` (needs the repo root importable) and pull in
# `repro` (which lives under src/)
_ROOT = pathlib.Path(__file__).resolve().parent.parent
for p in (str(_ROOT), str(_ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

SUITES = {
    "fig2_access_skew": "benchmarks.bench_access_skew",
    "fig5_single_request": "benchmarks.bench_single_request",
    "table3_storage_tiers": "benchmarks.bench_storage_tiers",
    "fig6_batching": "benchmarks.bench_batching",
    "continuous_batching": "benchmarks.bench_continuous",
    "paged_sharing": "benchmarks.bench_paged_sharing",
    "fused_decode": "benchmarks.bench_fused_decode",
    "quant_residency": "benchmarks.bench_quant_residency",
    "tp_serving": "benchmarks.bench_tp_serving",
    "disagg": "benchmarks.bench_disagg",
    "fig7_overlap": "benchmarks.bench_overlap",
    "streaming_admission": "benchmarks.bench_streaming_admission",
    "table45_power": "benchmarks.bench_power",
    "fig8_lengths": "benchmarks.bench_lengths",
    "fig9_model_scaling": "benchmarks.bench_model_scaling",
    "fig10_hetero": "benchmarks.bench_hetero",
    "table6_accuracy": "benchmarks.bench_accuracy",
    "eq1_economics": "benchmarks.bench_economics",
    "sec3e_caching_policy": "benchmarks.bench_caching_policy",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over suite names (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print available suite names and exit")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast path: import + validate every registered "
                         "suite (catching registration rot), and execute the "
                         "ones that support run(smoke=True) at reduced size")
    args = ap.parse_args()
    if args.list:
        print("\n".join(SUITES))
        return
    selected = {n: m for n, m in SUITES.items()
                if not args.only or args.only in n}
    if not selected:
        sys.exit(f"error: no benchmark suite matches --only {args.only!r}; "
                 f"available: {', '.join(SUITES)}")
    print("name,us_per_call,derived")
    failures = 0
    for name, modpath in selected.items():
        t0 = time.perf_counter()
        try:
            import importlib
            import inspect
            mod = importlib.import_module(modpath)
            if not callable(getattr(mod, "run", None)):
                raise TypeError(f"suite {name}: module {modpath} has no "
                                f"callable run()")
            if args.smoke:
                if "smoke" in inspect.signature(mod.run).parameters:
                    for line in mod.run(smoke=True):
                        print(line, flush=True)
                status = "smoke-ok"
            else:
                for line in mod.run():
                    print(line, flush=True)
                status = "done"
            print(f"suite/{name},{(time.perf_counter() - t0) * 1e6:.0f},"
                  f"{status}", flush=True)
        except Exception:
            failures += 1
            print(f"suite/{name},0,FAILED", flush=True)
            traceback.print_exc(file=sys.stderr)
    if args.smoke:
        failures += _validate_traces()
    if failures:
        sys.exit(1)


def _validate_traces() -> int:
    """Smoke-mode trace check (DESIGN.md §15): round-trip a threaded tracer
    through the Chrome exporter + validator, then validate every trace the
    benches dropped under experiments/traces/. Returns failure count."""
    import tempfile
    import threading

    t0 = time.perf_counter()
    try:
        from repro.obs import Tracer, load_chrome, validate_chrome

        tr = Tracer(role="smoke")
        def worker(i):
            with tr.span("outer", req=i):
                with tr.span("inner", req=i):
                    tr.instant("tick", req=i)
        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        with tempfile.TemporaryDirectory() as d:
            path = pathlib.Path(d) / "smoke.trace.json"
            tr.to_chrome(path)
            stats = validate_chrome(load_chrome(path))
        checked, bad = 1, 0
        for p in sorted((_ROOT / "experiments" / "traces").glob("*.json")):
            try:
                s = validate_chrome(load_chrome(p))
                stats["events"] += s["events"]
                stats["spans"] += s["spans"]
                checked += 1
            except ValueError as e:
                bad += 1
                print(f"trace/validate,0,INVALID:{p.name}", flush=True)
                traceback.print_exc(file=sys.stderr)
        status = (f"{checked}-traces-{stats['events']}ev-{stats['spans']}sp"
                  if not bad else f"{bad}-invalid")
        print(f"trace/validate,{(time.perf_counter() - t0) * 1e6:.0f},"
              f"{status}", flush=True)
        return bad
    except Exception:
        print("trace/validate,0,FAILED", flush=True)
        traceback.print_exc(file=sys.stderr)
        return 1


if __name__ == '__main__':
    main()
