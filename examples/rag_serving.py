"""End-to-end MatKV RAG serving driver (paper §V-B, Figs. 6-7).

Builds a corpus, materializes every chunk's KV on a flash store, then serves
a stream of batched requests three ways and prints a throughput table:

  vanilla           full KV recomputation each request
  matkv (serial)    load materialized KVs, strictly serialized phases
  matkv (overlap)   KV loads for batch i+1 prefetched while batch i decodes
                    (paper Fig. 4 / §III-C — the double-buffered pipeline)
  matkv (cont.)     continuous batching: per-request admission into decode
                    slots, EOS/length eviction + backfill, per-request KV
                    prefetch (beyond-paper serving core)

Storage is a bandwidth-accurate SimulatedReader so the load phase reflects a
real SSD tier instead of the page cache; pick the tier with --ssd. The decode
side runs for real on CPU JAX with a batched composed cache.

Run:  PYTHONPATH=src python examples/rag_serving.py [--ssd 9100pro|raid0|pm9a3|dram]
"""

import argparse
import tempfile
import time

import jax

from repro.configs import get_config
from repro.kvstore import FlashKVStore, SimulatedReader
from repro.models import build_model
from repro.serving import BatchScheduler, ContinuousScheduler, RagEngine

WORDS = ["amber", "basil", "cedar", "delta", "ember", "fjord", "grove",
         "haven", "iris", "jade", "karst", "lotus", "mason", "north",
         "onyx", "pearl"]


def build_corpus():
    docs = {f"doc{i:02d}":
            (f"the {w} artifact number {i} rests in chamber {i * 7} of the "
             f"deep vault. its custodian is warden number {i * 3}. ") * 5
            for i, w in enumerate(WORDS)}
    questions = [f"where is the {w} artifact?" for w in WORDS]
    return docs, questions


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ssd", default="9100pro",
                    choices=["9100pro", "raid0", "pm9a3", "dram"])
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config("smollm-135m").reduced(vocab_size=300, num_layers=2,
                                            d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    docs, questions = build_corpus()
    qs = [questions[i % len(questions)] for i in range(args.requests)]

    with tempfile.TemporaryDirectory() as root:
        store = FlashKVStore(root)
        base = RagEngine(model, params, store, mode="matkv",
                         chunk_tokens=64, top_k=2)
        t0 = time.perf_counter()
        n_chunks = sum(len(base.ingest(d, text)) for d, text in docs.items())
        print(f"ingest: {n_chunks} chunks materialized "
              f"({store.total_bytes() / 2**20:.1f} MiB KV) "
              f"in {time.perf_counter() - t0:.1f}s")

        results = {}
        # -- vanilla: one engine, per-request full prefill ---------------------
        veng = RagEngine(model, params, store, mode="vanilla",
                         chunk_tokens=64, top_k=2)
        veng._chunks, veng.vdb = base._chunks, base.vdb
        veng.answer(qs[0], max_new_tokens=args.new_tokens)      # warm jit
        t0 = time.perf_counter()
        for q in qs:
            veng.answer(q, max_new_tokens=args.new_tokens)
        results["vanilla"] = time.perf_counter() - t0

        # -- matkv serial / overlapped, bandwidth-simulated flash reads -------
        for overlap in (False, True):
            reader = SimulatedReader(store, args.ssd)
            eng = RagEngine(model, params, store, mode="matkv",
                            chunk_tokens=64, top_k=2, reader=reader)
            eng._chunks, eng.vdb = base._chunks, base.vdb
            sched = BatchScheduler(eng, batch_size=args.batch_size,
                                   overlap=overlap)
            sched.run(qs[:args.batch_size],
                      max_new_tokens=args.new_tokens)           # warm jit
            t0 = time.perf_counter()
            _, t = sched.run(qs, max_new_tokens=args.new_tokens)
            wall = time.perf_counter() - t0
            name = "matkv+overlap" if overlap else "matkv serial"
            results[name] = wall
            print(f"[{name:14s}] wall={wall:6.2f}s "
                  f"load={t.load_s:6.2f}s prefill={t.prefill_s:6.2f}s "
                  f"decode={t.decode_s:6.2f}s "
                  f"(simulated {args.ssd} read: "
                  f"{t.kv_bytes_loaded / 2**20:.1f} MiB)")

        # -- continuous batching over the same simulated flash tier -----------
        reader = SimulatedReader(store, args.ssd)
        eng = RagEngine(model, params, store, mode="matkv",
                        chunk_tokens=64, top_k=2, reader=reader)
        eng._chunks, eng.vdb = base._chunks, base.vdb
        # n_load_workers=1: SimulatedReader enforces bandwidth per call, so
        # concurrent reads would over-credit the simulated drive vs the
        # serial/overlap modes above
        cont = ContinuousScheduler(eng, max_slots=args.batch_size,
                                   n_load_workers=1)
        cont.run(qs, max_new_tokens=args.new_tokens)           # warm jit
        t0 = time.perf_counter()
        _, m = cont.run(qs, max_new_tokens=args.new_tokens)
        cont.shutdown()
        wall = time.perf_counter() - t0
        results["matkv+cont"] = wall
        print(f"[{'matkv+cont':14s}] wall={wall:6.2f}s "
              f"prefill={m.prefill_s:6.2f}s decode={m.decode_s:6.2f}s "
              f"p95={m.p95_latency_s:5.2f}s "
              f"(simulated {args.ssd} read: "
              f"{m.kv_bytes_loaded / 2**20:.1f} MiB)")

        print(f"[{'vanilla':14s}] wall={results['vanilla']:6.2f}s "
              f"(full recompute)")
        print(f"\nrequests/s: " + "  ".join(
            f"{k}={args.requests / v:.2f}" for k, v in results.items()))
        print(f"overlap speedup vs serial: "
              f"{results['matkv serial'] / results['matkv+overlap']:.2f}x")


if __name__ == "__main__":
    main()
