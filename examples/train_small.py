"""Train a small LM on the synthetic KV-QA task, then serve it with MatKV.

Exercises the full training substrate — data pipeline (host prefetch),
AdamW + cosine schedule, gradient accumulation, checkpointing — and then the
point of it all: the trained model answers retrieval questions through the
MatKV read path, so the run ends with a measurable exact-match score that the
accuracy benchmark (paper Table VI) builds on.

Defaults train a ~1M-param model for 300 steps in a few minutes on CPU;
--arch/--steps scale it up (any assigned arch id works).

Run:  PYTHONPATH=src python examples/train_small.py [--steps 300] [--arch smollm-135m]
"""

import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.data.pipeline import PrefetchIterator, batched
from repro.data.synthetic import KvQaTask, f1_score
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.serving import RagEngine
from repro.training import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=320)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced(vocab_size=300, num_layers=2,
                                        d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"training {cfg.name}: {n_params / 1e6:.2f}M params, "
          f"{args.steps} steps, batch {args.batch}")

    task = KvQaTask(n_docs=24, n_facts=6, seed=0)
    batches = PrefetchIterator(
        batched(task, args.batch, args.seq_len, n_context=2), depth=2)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainConfig(steps=args.steps, log_every=25,
                           grad_accum=args.grad_accum, ckpt_dir=ckpt_dir)
        params, _, history = train(
            model, params, batches, tcfg,
            callback=lambda m: print(
                f"  step {m['step']:4d} loss={m['loss']:.3f} "
                f"lr={m.get('lr', 0):.2e} {m['wall_s']:.0f}s"))

        # -- serve what we trained through the MatKV read path ----------------
        with tempfile.TemporaryDirectory() as root:
            eng = RagEngine(model, params, FlashKVStore(root), mode="matkv",
                            chunk_tokens=64, top_k=2)
            for doc_id, text in task.docs.items():
                eng.ingest(doc_id, text)
            examples = task.examples(12)
            f1 = 0.0
            for ex in examples:
                pred, _ = eng.answer(ex.question, max_new_tokens=12)
                f1 += f1_score(pred, ex.answer)
            print(f"\nMatKV-served F1 over {len(examples)} held-out "
                  f"questions: {f1 / len(examples):.3f} "
                  f"(final train loss {history[-1]['loss']:.3f})")


if __name__ == "__main__":
    main()
