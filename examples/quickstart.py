"""MatKV quickstart: materialize chunk KVs on flash, answer a RAG query.

Walks the paper's Fig. 3 end-to-end with a tiny model on CPU:

  1. ingest documents  -> chunk, embed into the vector DB, precompute each
     chunk's KV on "GPU" (here: CPU JAX) and persist it to the flash store
     (paper Fig. 3a: the MatKV *write path*).
  2. answer a question -> top-k retrieve, load the materialized KVs instead
     of recomputing prefill, sub-prefill only the query, decode
     (paper Fig. 3b: the *read path*).
  3. compare against Vanilla (full recompute) and CacheBlend (18% selective
     recompute) on the same request, printing the paper's §V-A phase
     breakdown (load / prefill / decode).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.configs import get_config
from repro.core.economics import (H100, SAMSUNG_9100_PRO,
                                  break_even_interval_days)
from repro.kvstore import FlashKVStore
from repro.models import build_model
from repro.serving import RagEngine

DOCS = {
    "volcanoes": "the obsidian archive is kept under mount helka in iceland. "
                 "it holds the oldest lava-glass records known. " * 4,
    "lighthouse": "the keeper of the gray lighthouse is named tobias finch. "
                  "he has tended the lamp for forty-one years. " * 4,
    "orchards":  "the red orchard of dunmore grows nothing but quince. "
                 "its cider is pressed once every september. " * 4,
}
QUESTION = "where is the obsidian archive kept?"


def main():
    # a tiny llama-family config so the whole demo runs in seconds on CPU
    cfg = get_config("smollm-135m").reduced(vocab_size=300, num_layers=2,
                                            d_model=128)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    print(f"model: {cfg.name} (reduced) — {cfg.num_layers}L d={cfg.d_model}")
    results = {}
    for mode in ("matkv", "vanilla", "cacheblend"):
        with tempfile.TemporaryDirectory() as root:
            store = FlashKVStore(root)
            eng = RagEngine(model, params, store, mode=mode,
                            chunk_tokens=64, top_k=2)
            for doc_id, text in DOCS.items():
                chunk_ids = eng.ingest(doc_id, text)
                if mode == "matkv":
                    sz = sum(store.size_bytes(c) for c in chunk_ids)
                    print(f"  ingested {doc_id}: {len(chunk_ids)} chunks, "
                          f"{sz / 1024:.1f} KiB of KV materialized")
            eng.answer(QUESTION, max_new_tokens=12)   # warm up jit caches
            answer, t = eng.answer(QUESTION, max_new_tokens=12)
            results[mode] = t
            print(f"[{mode:10s}] load={t.load_s * 1e3:7.1f}ms "
                  f"prefill={t.prefill_s * 1e3:7.1f}ms "
                  f"decode={t.decode_s * 1e3:7.1f}ms "
                  f"kv_loaded={t.kv_bytes_loaded / 1024:.0f}KiB")

    v, m = results["vanilla"], results["matkv"]
    print(f"\nprefill-phase speedup (matkv vs vanilla): "
          f"{v.prefill_s / max(m.load_s + m.prefill_s, 1e-9):.2f}x")

    # the ten-day rule (paper Eq. 1) with the paper's H100 + 9100 Pro
    # constants and LLaMA-70B's per-token KV footprint (~250 MB / 1k tokens)
    days = break_even_interval_days(H100, SAMSUNG_9100_PRO,
                                    kv_bytes_per_token=250_000)
    print(f"ten-day rule: storing a chunk's KV on flash beats GPU recompute "
          f"if it is re-retrieved at least once every {days:.1f} days")


if __name__ == "__main__":
    main()
