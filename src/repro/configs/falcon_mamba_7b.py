"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free (Mamba-1 blocks),
vocab=65024, ssm_state=16, expand=2 (d_inner=8192), conv width 4.
[arXiv:2410.05355]

MatKV applicability (DESIGN.md §4): attention-free, so there is no KV to
materialize. The analogue is the chunk's *final recurrent state* (conv state +
SSM state), which is exact only for single-chunk prefix reuse — multi-document
concatenation of states is not defined for a recurrence. We materialize per-chunk
states and reuse them with prefix-caching semantics.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    source="arXiv:2410.05355 (Falcon-Mamba-7B)",
    num_layers=64,
    d_model=4096,
    d_ff=0,                 # attention-free; Mamba block has no separate FFN
    vocab_size=65_024,
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    norm_eps=1e-5,
    tie_embeddings=False,
)
