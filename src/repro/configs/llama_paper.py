"""The paper's own evaluation models: LLaMA 3.2 3B, LLaMA 3.1 8B, LLaMA 3.1 70B.
[arXiv:2407.21783 (The Llama 3 Herd of Models)] — MatKV §V-A.
"""

from repro.configs.base import ModelConfig

LLAMA_3B = ModelConfig(
    name="llama-3.2-3b",
    family="dense",
    source="arXiv:2407.21783 (LLaMA 3.2 3B)",
    num_layers=28,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128_256,
    rope_theta=500_000.0,
    act="swiglu",
    tie_embeddings=True,
)

LLAMA_8B = ModelConfig(
    name="llama-3.1-8b",
    family="dense",
    source="arXiv:2407.21783 (LLaMA 3.1 8B)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=128_256,
    rope_theta=500_000.0,
    act="swiglu",
)

LLAMA_70B = ModelConfig(
    name="llama-3.1-70b",
    family="dense",
    source="arXiv:2407.21783 (LLaMA 3.1 70B)",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    rope_theta=500_000.0,
    act="swiglu",
)
