"""Input-shape registry: the 4 assigned global input shapes.

Each shape dictates which step function is lowered in the dry-run:
  * train_4k      -> train_step   (tokens + labels)
  * prefill_32k   -> prefill_step (MatKV chunk-materialization write path)
  * decode_32k    -> serve_step   (ONE new token against a seq_len KV cache)
  * long_500k     -> serve_step   (sub-quadratic archs only; see DESIGN.md §5)
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(f"unknown input shape {name!r}; have {sorted(SHAPES)}") from None


def shape_applicable(cfg, shape: InputShape) -> tuple[bool, str]:
    """Whether (arch, shape) is runnable, with a reason when skipped.

    Policy from DESIGN.md §5: long_500k needs sub-quadratic attention. It runs for
    SSM/hybrid archs and for dense-family archs via the sliding-window variant we
    implement. whisper (enc-dec, 448-token decoder ctx, full cross-attn) skips it.
    """
    if shape.kind == "decode" and shape.seq_len > 100_000:
        if cfg.family in ("encdec", "audio"):
            return False, ("enc-dec with full cross-attention and a 448-token "
                           "decoder context; no sub-quadratic path at 524k tokens")
        if cfg.family in ("ssm", "hybrid"):
            return True, "O(1) recurrent state / local-window attention"
        if cfg.sliding_window is None:
            return False, "pure full-attention config without sliding-window variant"
        return True, f"sliding-window variant (window={cfg.sliding_window})"
    return True, ""
