"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408 vocab=151936,
qk_norm, head_dim=128. [hf:Qwen/Qwen3-8B family card, 14B dims per assignment]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (Qwen3 family); assigned 14B dims",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17_408,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="swiglu",
)
