"""whisper-tiny [audio]: 4L enc + 4L dec, d_model=384, 6H MHA, d_ff=1536,
vocab=51865. Encoder-decoder with a conv audio frontend (STUB per assignment:
``input_specs`` supplies precomputed mel-frame embeddings). [arXiv:2212.04356]

MatKV fit: the decoder's *cross-attention* K/V over the encoded audio are
query-independent by construction — the cleanest possible materialization target.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    source="arXiv:2212.04356 (Whisper); tiny variant",
    num_layers=4,          # per-stack depth (enc_layers/dec_layers below)
    enc_layers=4,
    dec_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,        # MHA (GQA kv=6 == heads)
    d_ff=1536,
    vocab_size=51_865,
    act="gelu",
    use_rope=False,        # whisper uses learned absolute positions
    enc_positions=1500,    # 30 s of audio at 50 frames/s after conv frontend
    frontend="audio_stub",
    frontend_tokens=1500,
    max_position=448,      # decoder context
    norm_eps=1e-5,
)
