"""Model configuration schema for the repro framework.

Every assigned architecture (plus the paper's own LLaMA family) is expressed as a
single ``ModelConfig``. The config is deliberately a *superset* over all supported
families (dense / MoE / SSM / hybrid / enc-dec / VLM / audio); family-specific
fields are ignored by the other families. ``validate()`` enforces internal
consistency so a bad config fails at construction, not deep inside a jit trace.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# Layer-kind tags used by hybrid block patterns.
RECURRENT = "recurrent"
ATTENTION = "attention"


@dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------------
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm | audio
    source: str = ""  # citation: arXiv id / hf model card

    # --- core transformer dims ----------------------------------------------
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 -> derived as d_model // num_heads
    d_ff: int = 0
    vocab_size: int = 0

    # --- attention flavour ---------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None  # per-layer local attention window
    use_rope: bool = True  # whisper uses learned absolute positions
    max_position: int = 1 << 20

    # --- misc architecture ---------------------------------------------------
    norm_eps: float = 1e-6
    act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # --- MoE ------------------------------------------------------------------
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0  # routed (and shared) expert hidden dim
    first_dense_layers: int = 0  # leading layers that use a dense FFN instead
    dense_d_ff: int = 0  # FFN dim for those leading dense layers
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # --- SSM (Mamba-1) ---------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0  # 0 -> ceil(d_model / 16)

    # --- hybrid (Griffin / RecurrentGemma) -------------------------------------
    block_pattern: Tuple[str, ...] = ()  # e.g. (RECURRENT, RECURRENT, ATTENTION)
    rglru_width: int = 0  # 0 -> d_model

    # --- encoder-decoder (whisper) ---------------------------------------------
    enc_layers: int = 0
    dec_layers: int = 0
    enc_positions: int = 1500  # whisper audio frames after conv frontend

    # --- modality frontend stubs ------------------------------------------------
    frontend: Optional[str] = None  # audio_stub | vision_stub | None
    frontend_tokens: int = 0  # patches / frames consumed per example

    # --- numerics ---------------------------------------------------------------
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.ssm_dt_rank == 0 and self.family == "ssm":
            object.__setattr__(self, "ssm_dt_rank", -(-self.d_model // 16))
        if self.rglru_width == 0 and self.family == "hybrid":
            object.__setattr__(self, "rglru_width", self.d_model)
        self.validate()

    # ------------------------------------------------------------------------
    def validate(self) -> None:
        fams = {"dense", "moe", "ssm", "hybrid", "encdec", "vlm", "audio"}
        if self.family not in fams:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "ssm":
            if self.num_heads <= 0:
                raise ValueError(f"{self.name}: num_heads must be positive")
            if self.num_kv_heads <= 0 or self.num_heads % self.num_kv_heads:
                raise ValueError(
                    f"{self.name}: num_heads={self.num_heads} must be a multiple "
                    f"of num_kv_heads={self.num_kv_heads}")
        if self.family == "moe":
            if not (self.num_experts and self.moe_top_k and self.moe_d_ff):
                raise ValueError(f"{self.name}: incomplete MoE config")
            if self.moe_top_k > self.num_experts:
                raise ValueError(f"{self.name}: top_k > num_experts")
        if self.family == "ssm" and not self.ssm_state:
            raise ValueError(f"{self.name}: ssm_state required for ssm family")
        if self.family == "hybrid" and not self.block_pattern:
            raise ValueError(f"{self.name}: block_pattern required for hybrid")
        if self.family in ("encdec", "audio") and not (self.enc_layers and self.dec_layers):
            raise ValueError(f"{self.name}: enc/dec layers required")
        if self.vocab_size <= 0:
            raise ValueError(f"{self.name}: vocab_size must be positive")

    # ------------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixing kind for hybrid models (cycled pattern)."""
        if self.family != "hybrid":
            return tuple(ATTENTION for _ in range(self.num_layers))
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    def param_count(self) -> int:
        """Analytical parameter count (embedding + per-layer), used for 6ND."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # lm head
        per_attn = (self.d_model * self.q_dim  # wq
                    + 2 * self.d_model * self.kv_dim  # wk, wv
                    + self.q_dim * self.d_model)  # wo
        if self.family == "ssm":
            d_in = self.d_inner
            per_layer = (self.d_model * 2 * d_in  # in_proj
                         + d_in * self.ssm_conv  # conv
                         + d_in * (self.ssm_dt_rank + 2 * self.ssm_state)  # x_proj
                         + self.ssm_dt_rank * d_in + d_in  # dt_proj
                         + d_in * self.ssm_state + d_in  # A_log, D
                         + d_in * self.d_model)  # out_proj
            return n + self.num_layers * per_layer
        def ffn(dff):
            mult = 3 if self.act == "swiglu" else 2
            return mult * self.d_model * dff
        if self.family == "moe":
            per_moe = (self.num_experts + self.num_shared_experts) * ffn(self.moe_d_ff) \
                + self.d_model * self.num_experts
            n_moe_layers = self.num_layers - self.first_dense_layers
            n += self.first_dense_layers * (per_attn + ffn(self.dense_d_ff or self.d_ff))
            n += n_moe_layers * (per_attn + per_moe)
            return n
        if self.family == "hybrid":
            per_rec = (2 * self.d_model * self.rglru_width  # gates in_proj x2
                       + 2 * self.rglru_width  # lru params a, gate
                       + self.rglru_width * self.d_model  # out proj
                       + self.rglru_width * 4)  # conv1d width-4
            total = 0
            for kind in self.layer_kinds:
                total += (per_attn if kind == ATTENTION else per_rec) + ffn(self.d_ff)
            return n + total
        if self.family in ("encdec", "audio"):
            enc = self.enc_layers * (per_attn + ffn(self.d_ff))
            dec = self.dec_layers * (2 * per_attn + ffn(self.d_ff))  # self+cross
            return n + enc + dec
        return n + self.num_layers * (per_attn + ffn(self.d_ff))

    def active_param_count(self) -> int:
        """Params active per token (MoE: shared + top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        mult = 3 if self.act == "swiglu" else 2

        def ffn(dff):
            return mult * self.d_model * dff

        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        per_attn = (self.d_model * self.q_dim + 2 * self.d_model * self.kv_dim
                    + self.q_dim * self.d_model)
        active_moe = (self.num_shared_experts + self.moe_top_k) * ffn(self.moe_d_ff) \
            + self.d_model * self.num_experts
        n += self.first_dense_layers * (per_attn + ffn(self.dense_d_ff or self.d_ff))
        n += (self.num_layers - self.first_dense_layers) * (per_attn + active_moe)
        return n

    # KV bytes per token (the quantity MatKV materializes) -------------------
    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        if self.family == "ssm":
            return 0  # state is O(1), not per-token
        n_attn = sum(1 for k in self.layer_kinds if k == ATTENTION)
        if self.family in ("encdec", "audio"):
            n_attn = self.dec_layers  # cross-attention KV per encoder frame
        return 2 * n_attn * self.kv_dim * dtype_bytes

    def reduced(self, **overrides) -> "ModelConfig":
        """A small same-family variant for CPU smoke tests."""
        small = dict(
            num_layers=2, d_model=min(self.d_model, 128),
            vocab_size=min(self.vocab_size, 512),
            max_position=4096,
        )
        if self.num_heads:
            heads = min(self.num_heads, 4)
            kv = max(1, min(self.num_kv_heads, heads))
            while heads % kv:
                kv -= 1
            small.update(num_heads=heads, num_kv_heads=kv, head_dim=32,
                         d_ff=min(self.d_ff, 256) or 0)
        if self.family == "moe":
            small.update(num_experts=4, moe_top_k=min(self.moe_top_k, 2),
                         num_shared_experts=min(self.num_shared_experts, 1),
                         moe_d_ff=64, first_dense_layers=min(self.first_dense_layers, 1),
                         dense_d_ff=128 if self.first_dense_layers else 0)
        if self.family == "ssm":
            small.update(ssm_state=8, ssm_dt_rank=8)
        if self.family == "hybrid":
            small.update(num_layers=3, rglru_width=128, sliding_window=64)
        if self.family in ("encdec", "audio"):
            small.update(enc_layers=2, dec_layers=2, enc_positions=64)
        if self.frontend:
            small.update(frontend_tokens=min(self.frontend_tokens, 16))
        if self.sliding_window:
            small.update(sliding_window=min(self.sliding_window, 64))
        small.update(overrides)
        return dataclasses.replace(self, name=self.name + "-reduced", **small)
