"""llava-next-mistral-7b [vlm]: Mistral-7B language backbone — 32L d_model=4096
32H (GQA kv=8) d_ff=14336 vocab=32000, native sliding window 4096. Vision tower
(SigLIP/CLIP + projector) is a STUB per assignment: ``input_specs`` provides
anyres patch embeddings of the right shape. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

MatKV fit: each anyres image tile's patch-embedding chunk is a natural MatKV
chunk — tiles are prefilled independently and composed before the text query.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf (Mistral-7B backbone)",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=32_000,
    sliding_window=4096,    # native Mistral sliding-window attention
    rope_theta=1_000_000.0,
    act="swiglu",
    frontend="vision_stub",
    frontend_tokens=2880,   # anyres: up to 5 tiles x 576 patches
)
