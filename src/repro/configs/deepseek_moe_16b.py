"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (MHA) routed-expert d_ff=1408,
vocab=102400. Fine-grained MoE: 2 shared experts + 64 routed experts, top-6;
first layer uses a dense FFN (d_ff=10944). [arXiv:2401.06066]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066 (DeepSeekMoE 16B)",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,              # headline per-expert dim from the assignment
    moe_d_ff=1408,
    num_experts=64,
    num_shared_experts=2,
    moe_top_k=6,
    first_dense_layers=1,
    dense_d_ff=10_944,
    vocab_size=102_400,
    rope_theta=10_000.0,
    act="swiglu",
)
