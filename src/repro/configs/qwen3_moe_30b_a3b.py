"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4, head_dim=128)
per-expert d_ff=768, vocab=151936, 128 routed experts top-8 (no shared experts),
qk_norm. [hf:Qwen/Qwen3-30B-A3B]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,
    moe_d_ff=768,
    num_experts=128,
    num_shared_experts=0,
    moe_top_k=8,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="swiglu",
)
