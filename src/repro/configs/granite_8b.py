"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152,
llama-style code model. [arXiv:2405.04324 (Granite Code Models)]
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    source="arXiv:2405.04324 (Granite-8B-Code)",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=49_152,
    rope_theta=10_000_000.0,
    act="swiglu",
    tie_embeddings=True,
)
