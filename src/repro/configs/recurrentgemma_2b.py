"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000. Griffin block pattern — two RG-LRU (recurrent) blocks followed by
one local (sliding-window 2048) attention block. [arXiv:2402.19427]
"""

from repro.configs.base import ATTENTION, ModelConfig, RECURRENT

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427 (Griffin / RecurrentGemma-2B)",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,          # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=(RECURRENT, RECURRENT, ATTENTION),
    rglru_width=2560,
    sliding_window=2048,     # local attention window (native to the arch)
    rope_theta=10_000.0,
    act="gelu",              # gemma-style geglu
    tie_embeddings=True,
)
