"""Config registry: ``get_config(arch_id)`` plus shape plumbing.

``--arch <id>`` anywhere in the framework resolves through REGISTRY below.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ATTENTION, ModelConfig, RECURRENT
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek_moe
from repro.configs.falcon_mamba_7b import CONFIG as _falcon_mamba
from repro.configs.granite_8b import CONFIG as _granite
from repro.configs.llama_paper import LLAMA_3B, LLAMA_70B, LLAMA_8B
from repro.configs.llava_next_mistral_7b import CONFIG as _llava_next
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4_mini
from repro.configs.qwen3_14b import CONFIG as _qwen3_14b
from repro.configs.qwen3_moe_30b_a3b import CONFIG as _qwen3_moe
from repro.configs.recurrentgemma_2b import CONFIG as _recurrentgemma
from repro.configs.shapes import (InputShape, SHAPES, get_shape,
                                  shape_applicable)
from repro.configs.smollm_135m import CONFIG as _smollm
from repro.configs.whisper_tiny import CONFIG as _whisper_tiny

# The 10 assigned architectures.
ASSIGNED: Dict[str, ModelConfig] = {
    c.name: c for c in (
        _whisper_tiny, _deepseek_moe, _qwen3_14b, _phi4_mini, _recurrentgemma,
        _falcon_mamba, _qwen3_moe, _llava_next, _smollm, _granite,
    )
}

# The paper's own models (used by the paper-table benchmarks).
PAPER_MODELS: Dict[str, ModelConfig] = {
    c.name: c for c in (LLAMA_3B, LLAMA_8B, LLAMA_70B)
}

REGISTRY: Dict[str, ModelConfig] = {**ASSIGNED, **PAPER_MODELS}

# Window used for the beyond-paper sliding-window variant that unlocks
# long_500k on otherwise full-attention dense/MoE/VLM archs (DESIGN.md §5).
LONG_CONTEXT_WINDOW = 4096


def get_config(arch: str) -> ModelConfig:
    try:
        return REGISTRY[arch]
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; have {sorted(REGISTRY)}") from None


def config_for_shape(arch: str, shape_name: str):
    """Resolve (config, applicable, reason) for an (arch, input-shape) pair.

    For long_500k on full-attention archs, applies the sliding-window variant so
    the per-step attention is O(window) instead of O(seq).
    """
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    if (shape.kind == "decode" and shape.seq_len > 100_000
            and cfg.family in ("dense", "moe", "vlm") and cfg.sliding_window is None):
        cfg = dataclasses.replace(cfg, sliding_window=LONG_CONTEXT_WINDOW)
    ok, reason = shape_applicable(cfg, shape)
    return cfg, ok, reason


__all__ = [
    "ATTENTION", "RECURRENT", "ModelConfig", "InputShape", "SHAPES",
    "ASSIGNED", "PAPER_MODELS", "REGISTRY", "get_config", "get_shape",
    "config_for_shape", "shape_applicable", "LONG_CONTEXT_WINDOW",
]
