"""Runtime lock-order detector (DESIGN.md §17).

``TrackedLock`` wraps ``threading.Lock`` and records, per thread, which
locks are held when a new one is acquired. Every held->acquired pair is an
edge in a global acquisition-order graph keyed by the lock's *creation
site* (``file:line``, the lockdep convention: all instances of a class's
lock share one node, so an ordering observed between two ``ChunkStream``
locks and two pool locks generalizes). A cycle in that graph means two
code paths acquire the same locks in opposite orders — a deadlock waiting
for the right interleaving, even if this run never hit it.

Usage in tests (see ``tests/conftest.py``)::

    reg = LockOrderRegistry()
    with instrumented(reg, async_loader, queue, cache_tier):
        ... exercise loader/pool/scheduler ...
    reg.assert_clean()          # raises LockOrderError on any cycle

``instrumented`` swaps each module's ``threading`` reference for a shim
whose ``Lock()``/``RLock()`` return tracked locks; everything else
delegates to the real module. Locks created while instrumented keep
working after uninstall (they hold their own registry reference).
"""

from __future__ import annotations

import os
import sys
import threading
from contextlib import contextmanager
from types import ModuleType
from typing import Dict, Iterator, List, Optional, Sequence, Tuple


class LockOrderError(AssertionError):
    """A lock-acquisition-order cycle (potential deadlock) was observed."""


def _caller_site(skip_file: str) -> str:
    """``file.py:line`` of the nearest stack frame outside this module —
    the lock's creation site, which names its node in the order graph."""
    frame = sys._getframe(1)
    while frame is not None:
        if frame.f_code.co_filename != skip_file:
            return (f"{os.path.basename(frame.f_code.co_filename)}"
                    f":{frame.f_lineno}")
        frame = frame.f_back
    return "<unknown>"


class LockOrderRegistry:
    """Acquisition-order graph + violation log shared by tracked locks."""

    def __init__(self) -> None:
        self._graph_lock = threading.Lock()   # plain: guards the graph only
        # a -> b: an edge "a was held while b was acquired", annotated with
        # the first thread/site that observed it
        self._edges: Dict[str, Dict[str, str]] = {}
        self._tls = threading.local()
        self._reported: set = set()        # (held, acquired) pairs reported
        self.violations: List[str] = []

    # -- per-thread held stack ------------------------------------------------
    def _held(self) -> List[str]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    # -- graph ---------------------------------------------------------------
    def _path(self, src: str, dst: str) -> Optional[List[str]]:
        """A directed path src -> ... -> dst in the edge graph, or None."""
        stack: List[Tuple[str, List[str]]] = [(src, [src])]
        seen = {src}
        while stack:
            node, path = stack.pop()
            if node == dst:
                return path
            for nxt in self._edges.get(node, ()):
                if nxt not in seen:
                    seen.add(nxt)
                    stack.append((nxt, path + [nxt]))
        return None

    def note_acquire(self, name: str, reentrant: bool = False) -> None:
        held = self._held()
        if name in held and not reentrant:
            self.violations.append(
                f"self-deadlock: {name} acquired while already held by "
                f"this thread (held: {' -> '.join(held)})")
        with self._graph_lock:
            for h in held:
                if h == name:
                    continue
                back = self._path(name, h)
                if back is not None and (h, name) not in self._reported:
                    self._reported.add((h, name))
                    self.violations.append(
                        f"lock-order cycle: acquiring {name} while holding "
                        f"{h}, but the reverse order "
                        f"{' -> '.join(back)} was already observed "
                        f"(first at {self._edges[back[0]][back[1]]})")
                self._edges.setdefault(h, {}).setdefault(
                    name, f"thread={threading.current_thread().name}")
        held.append(name)

    def note_release(self, name: str) -> None:
        held = self._held()
        # release may be out of LIFO order (rare but legal) — remove the
        # most recent matching entry
        for i in range(len(held) - 1, -1, -1):
            if held[i] == name:
                del held[i]
                return

    def edges(self) -> Dict[str, Dict[str, str]]:
        with self._graph_lock:
            return {a: dict(bs) for a, bs in self._edges.items()}

    def assert_clean(self) -> None:
        if self.violations:
            raise LockOrderError(
                "lock-order violations observed:\n  "
                + "\n  ".join(self.violations))


class TrackedLock:
    """Drop-in ``threading.Lock`` recording acquisition order."""

    def __init__(self, registry: LockOrderRegistry,
                 name: Optional[str] = None, reentrant: bool = False):
        self._registry = registry
        self.name = name or _caller_site(__file__)
        self._reentrant = reentrant
        self._lock = threading.RLock() if reentrant else threading.Lock()

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._lock.acquire(blocking, timeout)
        if got:
            self._registry.note_acquire(self.name,
                                        reentrant=self._reentrant)
        return got

    def release(self) -> None:
        self._lock.release()
        self._registry.note_release(self.name)

    def locked(self) -> bool:
        locked = getattr(self._lock, "locked", None)
        if locked is not None:
            return locked()
        if self._lock.acquire(blocking=False):   # RLock pre-3.12 fallback
            self._lock.release()
            return False
        return True

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc: object) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<TrackedLock {self.name}>"


class _ThreadingShim:
    """Stands in for a module's ``threading`` reference: ``Lock``/``RLock``
    become tracked, everything else delegates to the real module."""

    def __init__(self, registry: LockOrderRegistry):
        self._registry = registry

    def Lock(self) -> TrackedLock:
        return TrackedLock(self._registry, name=_caller_site(__file__))

    def RLock(self) -> TrackedLock:
        return TrackedLock(self._registry, name=_caller_site(__file__),
                           reentrant=True)

    def __getattr__(self, item: str) -> object:
        return getattr(threading, item)


def install(registry: LockOrderRegistry,
            modules: Sequence[ModuleType]) -> Dict[ModuleType, object]:
    """Point each module's ``threading`` attribute at a tracking shim;
    returns the originals for :func:`uninstall`."""
    shim = _ThreadingShim(registry)
    saved: Dict[ModuleType, object] = {}
    for m in modules:
        if not hasattr(m, "threading"):
            raise ValueError(f"{m.__name__} does not import threading — "
                             f"nothing to instrument")
        saved[m] = m.threading
        m.threading = shim
    return saved


def uninstall(saved: Dict[ModuleType, object]) -> None:
    for m, original in saved.items():
        m.threading = original


@contextmanager
def instrumented(registry: LockOrderRegistry,
                 *modules: ModuleType) -> Iterator[LockOrderRegistry]:
    saved = install(registry, modules)
    try:
        yield registry
    finally:
        uninstall(saved)
