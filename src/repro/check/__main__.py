"""``python -m repro.check [paths...]`` — the reprolint CLI (CI lint gate).

Exit codes: 0 = clean, 1 = findings, 2 = usage error (unknown rule code,
missing path). ``--format json`` emits a machine-readable report.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.check.core import RULES, check_paths, check_source, iter_py_files


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.check",
        description="reprolint: repo-invariant static analysis "
                    "(DESIGN.md §17)")
    ap.add_argument("paths", nargs="*", default=["src/repro"],
                    help="files or directories to check "
                         "(default: src/repro)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", default=None, metavar="RP101,RP104",
                    help="comma-separated rule codes to run (default: all)")
    ap.add_argument("--no-noqa", action="store_true",
                    help="report findings even where a "
                         "`# repro: noqa[...]` suppresses them")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    import repro.check.rules  # noqa: F401  (registers RULES)
    if args.list_rules:
        for code, r in sorted(RULES.items()):
            print(f"{code}  {r.summary}")
        return 0

    select = None
    if args.select:
        select = [c.strip().upper() for c in args.select.split(",")
                  if c.strip()]
        unknown = [c for c in select if c not in RULES]
        if unknown:
            print(f"unknown rule code(s): {', '.join(unknown)} "
                  f"(see --list-rules)", file=sys.stderr)
            return 2

    try:
        files = list(iter_py_files(args.paths))
        findings = check_paths(args.paths, select=select,
                               respect_noqa=not args.no_noqa)
    except FileNotFoundError as e:
        print(str(e), file=sys.stderr)
        return 2

    if args.format == "json":
        print(json.dumps({
            "schema": 1,
            "checked_files": len(files),
            "findings": [f.as_dict() for f in findings],
        }, indent=1))
    else:
        for f in findings:
            print(f.render())
        print(f"repro.check: {len(findings)} finding(s) in "
              f"{len(files)} file(s)")
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())


# re-exported for tests that drive the CLI in-process
__all__ = ["main", "check_source"]
