"""repro.check — "reprolint": repo-invariant static analysis (DESIGN.md §17).

An AST-based analyzer (stdlib ``ast`` only, zero dependencies) whose rules
are distilled from this repo's own bug history: every rule encodes a
concurrency/ownership contract that a past PR shipped a
failing-before-verified fix for, so the serving tier can't silently
reintroduce the bug class. Run it as::

    python -m repro.check [paths...]

Rules (each maps to the PR/bug that motivated it — DESIGN.md §17):

========  =============================================================
RP101     pool ref/stream pairing: ``acquire``/``begin_stream``/
          ``alloc_private`` need a matching release reachable on all
          paths (try/finally or single-exit), or an ownership-transfer
          suppression.
RP102     donated-buffer reuse: a buffer passed at a ``donate_argnums``
          position of a jitted callable is dead after the call unless
          the call statement rebinds it.
RP103     bare ``Future.exception()``/``result()`` inside
          ``add_done_callback`` callbacks without a cancellation guard
          (the PR 7 ``CancelledError``-out-of-callbacks hang).
RP104     mutation of underscore-prefixed shared state of a
          lock-carrying class outside ``with self._lock``.
RP105     Pallas kernel-body purity: no host/numpy access, ``float64``,
          side-effecting builtins, or closure mutation inside a
          ``pl.pallas_call`` kernel fn.
RP106     wall-clock reads (``time.time``/``perf_counter``/
          ``monotonic``) in modules that declare an injectable clock
          (``now_fn``/``clock``) instead of using it.
========  =============================================================

Suppress a finding with an inline ``# repro: noqa[RP1xx]`` comment on any
line of the flagged statement — by convention followed by a justification.
"""

from repro.check.core import (Finding, RULES, check_paths, check_source,
                              iter_py_files)
from repro.check.lockorder import (LockOrderError, LockOrderRegistry,
                                   TrackedLock, instrumented)

__all__ = [
    "Finding", "RULES", "check_paths", "check_source", "iter_py_files",
    "LockOrderError", "LockOrderRegistry", "TrackedLock", "instrumented",
]
