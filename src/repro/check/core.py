"""Analyzer core: rule registry, noqa suppression, file walking, reporting.

The rules themselves live in :mod:`repro.check.rules`; this module owns
everything rule-independent — parsing, the parent-link pass every rule
relies on, the ``# repro: noqa[RPxxx]`` protocol, and ordering/rendering of
findings. Zero dependencies beyond the stdlib by design: the analyzer gates
CI, so it must run before (and without) the jax toolchain.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)

#: matches ``# repro: noqa`` (blanket) or ``# repro: noqa[RP101,RP104]``
_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_,\s]*)\])?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at a source location.

    ``span`` is the (first, last) physical line of the enclosing statement:
    a ``# repro: noqa[code]`` comment on *any* of those lines suppresses the
    finding, so multi-line call chains can carry the justification where it
    reads best.
    """
    code: str
    path: str
    line: int
    col: int
    message: str
    span: Tuple[int, int] = field(default=(0, 0), compare=False)

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.code} {self.message}"

    def as_dict(self) -> Dict[str, object]:
        return {"code": self.code, "path": self.path, "line": self.line,
                "col": self.col, "message": self.message}


RuleFn = Callable[[ast.Module, List[str], str], Iterable[Finding]]


@dataclass(frozen=True)
class Rule:
    code: str
    summary: str
    fn: RuleFn


#: code -> Rule; populated by the ``@rule`` decorator in rules.py
RULES: Dict[str, Rule] = {}


def rule(code: str, summary: str) -> Callable[[RuleFn], RuleFn]:
    def deco(fn: RuleFn) -> RuleFn:
        if code in RULES:
            raise ValueError(f"duplicate rule code {code}")
        RULES[code] = Rule(code, summary, fn)
        return fn
    return deco


# -- AST plumbing shared by every rule ---------------------------------------

def attach_parents(tree: ast.AST) -> None:
    """Link every node to its parent (``_repro_parent``) — the rules walk
    ancestor chains for with/try/function containment."""
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            child._repro_parent = parent  # type: ignore[attr-defined]


def ancestors(node: ast.AST) -> Iterator[ast.AST]:
    cur = getattr(node, "_repro_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_repro_parent", None)


def stmt_span(node: ast.AST) -> Tuple[int, int]:
    stmt = node
    if not isinstance(node, ast.stmt):
        for anc in ancestors(node):
            if isinstance(anc, ast.stmt):
                stmt = anc
                break
    return (getattr(stmt, "lineno", getattr(node, "lineno", 0)),
            getattr(stmt, "end_lineno", getattr(node, "end_lineno", 0)) or 0)


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` source path of a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def node_pos(node: ast.AST) -> Tuple[int, int]:
    return (getattr(node, "lineno", 0), getattr(node, "col_offset", 0))


_SCOPES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda, ast.ClassDef)


def own_nodes(fn: ast.AST) -> Iterator[ast.AST]:
    """Walk a function's body without descending into nested scopes
    (nested defs/lambdas/classes own their resources independently)."""
    stack: List[ast.AST] = list(ast.iter_child_nodes(fn))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, _SCOPES):
            stack.extend(ast.iter_child_nodes(node))


def func_defs(tree: ast.AST) -> Iterator[ast.AST]:
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


# -- suppression -------------------------------------------------------------

def _suppressed_codes(line: str) -> Optional[set]:
    """Codes a source line's noqa comment suppresses; empty set = blanket
    (all codes); None = no noqa comment on the line."""
    m = _NOQA_RE.search(line)
    if m is None:
        return None
    if m.group(1) is None:
        return set()
    return {c.strip().upper() for c in m.group(1).split(",") if c.strip()}


def is_suppressed(finding: Finding, lines: Sequence[str]) -> bool:
    lo, hi = finding.span
    if lo <= 0:
        lo = hi = finding.line
    for ln in range(lo, min(hi, len(lines)) + 1):
        codes = _suppressed_codes(lines[ln - 1])
        if codes is not None and (not codes or finding.code in codes):
            return True
    return False


# -- entry points ------------------------------------------------------------

def check_source(src: str, path: str = "<string>",
                 select: Optional[Sequence[str]] = None,
                 respect_noqa: bool = True) -> List[Finding]:
    """Run the (selected) rules over one source text."""
    import repro.check.rules  # noqa: F401  (registers RULES on first use)
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding("RP000", path, e.lineno or 1, (e.offset or 1) - 1,
                        f"syntax error: {e.msg}")]
    attach_parents(tree)
    lines = src.splitlines()
    findings: List[Finding] = []
    for code, r in sorted(RULES.items()):
        if select is not None and code not in select:
            continue
        findings.extend(r.fn(tree, lines, path))
    if respect_noqa:
        findings = [f for f in findings if not is_suppressed(f, lines)]
    return sorted(findings, key=lambda f: (f.path, f.line, f.col, f.code))


def iter_py_files(paths: Sequence[str]) -> Iterator[Path]:
    for p in paths:
        path = Path(p)
        if path.is_dir():
            yield from sorted(path.rglob("*.py"))
        elif path.suffix == ".py":
            yield path
        else:
            raise FileNotFoundError(f"not a .py file or directory: {p}")


def check_paths(paths: Sequence[str],
                select: Optional[Sequence[str]] = None,
                respect_noqa: bool = True) -> List[Finding]:
    findings: List[Finding] = []
    for f in iter_py_files(paths):
        findings.extend(check_source(f.read_text(), str(f), select=select,
                                     respect_noqa=respect_noqa))
    return findings
