"""The six reprolint rules (RP101–RP106), one per historical bug class.

Every rule here is an approximation with a deliberate bias: flag the shape
of a bug this repo actually shipped (see DESIGN.md §17 for the rule ->
PR/bug map) and accept that legitimate cross-function ownership transfers
need an inline ``# repro: noqa[RPxxx]`` with a justifying comment — the
suppression then *documents the contract* at the hand-off site.
"""

from __future__ import annotations

import ast
from typing import Dict, Iterable, Iterator, List, Optional, Set

from repro.check.core import (Finding, ancestors, dotted, func_defs,
                              node_pos, own_nodes, rule, stmt_span)

# mutating container/collection methods — calling one of these on shared
# state is a write even though the attribute itself is only loaded
_MUTATORS = {"append", "extend", "add", "update", "pop", "popleft",
             "appendleft", "popitem", "clear", "remove", "discard",
             "insert", "setdefault", "move_to_end", "difference_update"}


def _finding(code: str, node: ast.AST, path: str, msg: str) -> Finding:
    line, col = node_pos(node)
    return Finding(code, path, line, col, msg, span=stmt_span(node))


def _attr_calls(nodes: Iterable[ast.AST]) -> Iterator[ast.Call]:
    for n in nodes:
        if isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute):
            yield n


# ---------------------------------------------------------------------------
# RP101 — pool ref / stream pairing (PR 3/5 double frees, PR 9 stream leaks)
# ---------------------------------------------------------------------------

_ACQ_PAIRS = {
    "acquire": ("release", "release_row_paged"),
    "begin_stream": ("commit_stream", "abort_stream"),
    "alloc_private": ("free_private",),
}


def _is_pool_recv(recv: Optional[str]) -> bool:
    """``acquire`` is also a ``threading.Lock`` method — only pool-ish
    receivers (``pool``, ``pcache.pool``, ``self.pool``, ...) are in scope."""
    if not recv:
        return False
    return "pool" in recv.split(".")[-1].lower()


def _in_finally_of(rel: ast.AST) -> Optional[ast.Try]:
    for anc in ancestors(rel):
        if isinstance(anc, ast.Try):
            for stmt in anc.finalbody:
                if rel is stmt or any(rel is n for n in ast.walk(stmt)):
                    return anc
    return None


def _branch_depth(fn: ast.AST, node: ast.AST) -> int:
    """How many conditional/looping constructs sit between ``node`` and the
    function body — a release nested deeper than its acquire is a release
    some paths skip."""
    depth = 0
    for anc in ancestors(node):
        if anc is fn:
            break
        if isinstance(anc, (ast.If, ast.For, ast.While, ast.AsyncFor,
                            ast.ExceptHandler, ast.IfExp)):
            depth += 1
    return depth


@rule("RP101", "pool acquire/stream/private-alloc must release on all paths")
def rp101(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Finding]:
    for fn in func_defs(tree):
        nodes = list(own_nodes(fn))
        calls = list(_attr_calls(nodes))
        exits = [n for n in nodes if isinstance(n, (ast.Return, ast.Raise))]
        for acq in calls:
            kind = acq.func.attr
            if kind not in _ACQ_PAIRS:
                continue
            if kind == "acquire" and not _is_pool_recv(dotted(acq.func.value)):
                continue
            rel_names = _ACQ_PAIRS[kind]
            rels = [c for c in calls if c.func.attr in rel_names]
            if not rels:
                yield _finding(
                    "RP101", acq, path,
                    f"{kind}() with no {' / '.join(rel_names)} in this "
                    f"function — pair it, or suppress with a comment naming "
                    f"where ownership transfers to")
                continue
            protected = False
            for rel in rels:
                t = _in_finally_of(rel)
                if t is not None and node_pos(rel) > node_pos(acq):
                    # release in a finally: reachable on every path out,
                    # provided no return/raise can skip past the try after
                    # the acquire (acquire inside the try, or acquire-then-
                    # try with nothing risky between)
                    t_start = node_pos(t)
                    if node_pos(acq) >= t_start or not any(
                            node_pos(acq) < node_pos(e) < t_start
                            for e in exits):
                        protected = True
                        break
                # single-exit: no return/raise between acquire and release,
                # and the release no more conditional than the acquire
                if (node_pos(rel) > node_pos(acq)
                        and _branch_depth(fn, rel) <= _branch_depth(fn, acq)
                        and not any(node_pos(acq) < node_pos(e)
                                    < node_pos(rel) for e in exits)):
                    protected = True
                    break
            if not protected:
                yield _finding(
                    "RP101", acq, path,
                    f"{kind}() release is conditional or jumped over by an "
                    f"early return/raise — move it to a try/finally")


# ---------------------------------------------------------------------------
# RP102 — donated-buffer reuse (PR 3: scatter jits donate the pool buffers)
# ---------------------------------------------------------------------------

def _donate_positions(node: ast.AST) -> Optional[Set[int]]:
    """Literal ``donate_argnums`` positions; None when unresolvable."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[int] = set()
        for e in node.elts:
            if not (isinstance(e, ast.Constant) and isinstance(e.value, int)):
                return None
            out.add(e.value)
        return out
    if isinstance(node, ast.IfExp):
        a, b = _donate_positions(node.body), _donate_positions(node.orelse)
        if a is not None and b is not None:
            return a | b                 # either branch may donate: union
    return None


def _donating_call(call: ast.AST) -> Optional[Set[int]]:
    """Donated positions if ``call`` is ``jax.jit(..., donate_argnums=...)``
    or ``functools.partial(jax.jit, donate_argnums=...)``."""
    if not isinstance(call, ast.Call):
        return None
    fname = dotted(call.func) or ""
    is_jit = fname == "jit" or fname.endswith(".jit")
    is_partial_jit = (fname.endswith("partial") and call.args
                      and (dotted(call.args[0]) or "").endswith("jit"))
    if not (is_jit or is_partial_jit):
        return None
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            return _donate_positions(kw.value)
    return None


def _donating_names(tree: ast.Module) -> Dict[str, Set[int]]:
    """name -> donated positions, for jit-wrapped defs and assignments."""
    out: Dict[str, Set[int]] = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                pos = _donating_call(dec)
                if pos:
                    out[node.name] = pos
        elif isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = dotted(node.targets[0])
            pos = _donating_call(node.value)
            if tgt and pos:
                out[tgt] = pos
    return out


def _assign_targets(stmt: ast.AST) -> Set[str]:
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    for t in targets:
        stack = [t]
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.Tuple, ast.List, ast.Starred)):
                stack.extend(getattr(n, "elts", [])
                             or [getattr(n, "value", None)])
            else:
                d = dotted(n)
                if d:
                    out.add(d)
    return out


@rule("RP102", "buffer read after being donated to a jitted call")
def rp102(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Finding]:
    donating = _donating_names(tree)
    if not donating:
        return
    for fn in func_defs(tree):
        nodes = sorted(own_nodes(fn), key=node_pos)
        # rebind events: (pos, dotted-target) — a rebind of `x` (or of a
        # prefix like `pool` for `pool.k`) makes the name live again
        rebinds = []
        for n in nodes:
            if isinstance(n, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                for tgt in _assign_targets(n):
                    rebinds.append((node_pos(n), tgt))
        for call in nodes:
            if not isinstance(call, ast.Call):
                continue
            fname = dotted(call.func)
            pos = donating.get(fname or "")
            if not pos:
                continue
            stmt_lo, stmt_hi = stmt_span(call)
            stmt_targets: Set[str] = set()
            for anc in ancestors(call):
                if isinstance(anc, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
                    stmt_targets = _assign_targets(anc)
                    break
            for i in sorted(pos):
                if i >= len(call.args):
                    continue
                donated = dotted(call.args[i])
                if donated is None or donated in stmt_targets:
                    continue           # rebound by the call statement itself
                prefixes = {donated}
                parts = donated.split(".")
                for k in range(1, len(parts)):
                    prefixes.add(".".join(parts[:k]))
                cutoff = min((p for p, t in rebinds
                              if t in prefixes and p[0] > stmt_hi),
                             default=(1 << 30, 0))
                for use in nodes:
                    upos = node_pos(use)
                    if not (stmt_hi < upos[0] and upos < cutoff):
                        continue
                    if isinstance(use, (ast.Attribute, ast.Name)) and \
                            isinstance(use.ctx, ast.Load) and \
                            dotted(use) == donated:
                        yield _finding(
                            "RP102", use, path,
                            f"{donated!r} read after being donated to "
                            f"{fname}() (donate_argnums={i}) — the buffer "
                            f"is invalidated by the call")
                        break


# ---------------------------------------------------------------------------
# RP103 — bare Future.exception()/result() in done callbacks (PR 7 hang)
# ---------------------------------------------------------------------------

def _callback_bodies(tree: ast.Module) -> Iterator[ast.AST]:
    """Functions/lambdas registered via ``*.add_done_callback(cb)``."""
    defs: Dict[str, List[ast.AST]] = {}
    for fd in func_defs(tree):
        defs.setdefault(fd.name, []).append(fd)
    seen: Set[int] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_done_callback" and node.args):
            continue
        cb = node.args[0]
        targets: List[ast.AST] = []
        if isinstance(cb, ast.Lambda):
            targets = [cb]
        elif isinstance(cb, ast.Name):
            targets = defs.get(cb.id, [])
        for t in targets:
            if id(t) not in seen:
                seen.add(id(t))
                yield t


def _catches_cancelled(handler: ast.ExceptHandler) -> bool:
    if handler.type is None:
        return True
    types = (handler.type.elts if isinstance(handler.type, ast.Tuple)
             else [handler.type])
    for t in types:
        name = (dotted(t) or "").split(".")[-1]
        if name in ("CancelledError", "BaseException", "Exception"):
            return True
    return False


@rule("RP103", "done-callback calls Future.exception()/result() unguarded")
def rp103(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Finding]:
    for cb in _callback_bodies(tree):
        nodes = sorted(ast.walk(cb), key=node_pos)
        # a `fut.cancelled()` probe or an `_outcome(fut)`-style helper call
        # guards every later exception()/result() on the same name
        guarded_names: Dict[str, tuple] = {}
        for n in nodes:
            if not isinstance(n, ast.Call):
                continue
            if isinstance(n.func, ast.Attribute):
                recv = dotted(n.func.value)
                if n.func.attr == "cancelled" and recv:
                    guarded_names.setdefault(recv, node_pos(n))
                if n.func.attr in ("_outcome", "outcome"):
                    for a in n.args:
                        d = dotted(a)
                        if d:
                            guarded_names.setdefault(d, node_pos(n))
            elif isinstance(n.func, ast.Name) and \
                    n.func.id in ("_outcome", "outcome"):
                for a in n.args:
                    d = dotted(a)
                    if d:
                        guarded_names.setdefault(d, node_pos(n))
        for n in nodes:
            if not (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and n.func.attr in ("exception", "result")):
                continue
            recv = dotted(n.func.value)
            if recv is None:
                continue
            guard = guarded_names.get(recv)
            if guard is not None and guard <= node_pos(n):
                continue
            if any(isinstance(anc, ast.Try)
                   and any(_catches_cancelled(h) for h in anc.handlers)
                   and any(n is w for s in anc.body for w in ast.walk(s))
                   for anc in ancestors(n)):
                continue
            yield _finding(
                "RP103", n, path,
                f"bare {recv}.{n.func.attr}() in an add_done_callback "
                f"callback: on a cancelled future it raises CancelledError "
                f"(a BaseException) out of Future._invoke_callbacks, "
                f"silently aborting later callbacks — check "
                f"{recv}.cancelled() first or catch CancelledError")


# ---------------------------------------------------------------------------
# RP104 — lock-guarded shared state mutated outside the lock
# ---------------------------------------------------------------------------

def _is_self_attr(node: ast.AST, name: Optional[str] = None) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        if name is None or node.attr == name:
            return node.attr
    return None


def _lock_attrs(cls: ast.ClassDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            fname = (dotted(node.value.func) or "").split(".")[-1]
            if fname in ("Lock", "RLock"):
                for t in node.targets:
                    attr = _is_self_attr(t)
                    if attr:
                        out.add(attr)
    return out


def _with_lock_node(node: ast.AST, locks: Set[str]) -> bool:
    """Is ``node`` inside a ``with self.<lock>:`` block?"""
    for anc in ancestors(node):
        if isinstance(anc, (ast.With, ast.AsyncWith)):
            for item in anc.items:
                ctx = item.context_expr
                if isinstance(ctx, ast.Call):
                    ctx = ctx.func       # with self._lock.acquire_timeout()…
                attr = _is_self_attr(ctx)
                if attr in locks:
                    return True
    return False


def _mutations(method: ast.AST) -> Iterator[tuple]:
    """(attr_name, node, verb) for each mutation of a ``self._x`` in
    ``method`` — direct (re)binds, augmented assigns, subscript stores, and
    mutating container-method calls."""
    for n in own_nodes(method):
        if isinstance(n, ast.Attribute) and \
                isinstance(n.ctx, (ast.Store, ast.Del)):
            attr = _is_self_attr(n)
            if attr:
                yield attr, n, "assigned"
        elif isinstance(n, ast.Subscript) and \
                isinstance(n.ctx, (ast.Store, ast.Del)):
            attr = _is_self_attr(n.value)
            if attr:
                yield attr, n, "item-assigned"
        elif isinstance(n, ast.AugAssign):
            tgt = n.target
            attr = _is_self_attr(tgt) or (
                isinstance(tgt, ast.Subscript) and _is_self_attr(tgt.value))
            if attr:
                yield attr, n, "aug-assigned"
        elif isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                and n.func.attr in _MUTATORS:
            attr = _is_self_attr(n.func.value)
            if attr:
                yield attr, n, f".{n.func.attr}()-mutated"


@rule("RP104", "lock-guarded underscore state mutated outside the lock")
def rp104(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Finding]:
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        locks = _lock_attrs(cls)
        if not locks:
            continue
        # the guarded set: underscore attrs this class itself accesses under
        # one of its locks anywhere — those are the documented thread-facing
        # shared state
        guarded: Set[str] = set()
        for node in ast.walk(cls):
            attr = None
            if isinstance(node, ast.Attribute):
                attr = _is_self_attr(node)
            elif isinstance(node, ast.Subscript):
                attr = _is_self_attr(node.value)
            if (attr and attr.startswith("_") and attr not in locks
                    and _with_lock_node(node, locks)):
                guarded.add(attr)
        if not guarded:
            continue
        # nested defs are scanned as functions of their own: closures are
        # exactly the code that ends up on worker threads (done callbacks,
        # pool submissions), so they don't inherit __init__'s exemption
        for method in func_defs(cls):
            if method.name in ("__init__", "__new__", "__del__"):
                continue               # construction/teardown is unshared
            for attr, node, verb in _mutations(method):
                if attr in guarded and not _with_lock_node(node, locks):
                    yield _finding(
                        "RP104", node, path,
                        f"self.{attr} is {verb} outside `with self."
                        f"{'/'.join(sorted(locks))}` but is elsewhere "
                        f"accessed under it — racing threads can interleave")


# ---------------------------------------------------------------------------
# RP105 — Pallas kernel-body purity
# ---------------------------------------------------------------------------

_HOST_MODULES = {"np", "numpy", "time", "os", "sys", "random", "io"}
_HOST_BUILTINS = {"print", "open", "input", "breakpoint", "exec", "eval"}


def _kernel_fns(tree: ast.Module) -> Iterator[ast.AST]:
    """Functions passed (directly or via functools.partial) as the kernel
    argument of a ``pl.pallas_call``."""
    defs = {fd.name: fd for fd in func_defs(tree)}
    partials: Dict[str, str] = {}      # local name -> wrapped fn name
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.value, ast.Call) and node.value.args:
            fname = dotted(node.value.func) or ""
            if fname.endswith("partial"):
                tgt, inner = dotted(node.targets[0]), dotted(node.value.args[0])
                if tgt and inner:
                    partials[tgt] = inner
    seen: Set[int] = set()
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and (dotted(node.func) or "").endswith("pallas_call")
                and node.args):
            continue
        arg = node.args[0]
        name = dotted(arg)
        if isinstance(arg, ast.Call) and \
                (dotted(arg.func) or "").endswith("partial") and arg.args:
            name = dotted(arg.args[0])
        if name in partials:
            name = partials[name]
        fd = defs.get(name or "")
        if fd is not None and id(fd) not in seen:
            seen.add(id(fd))
            yield fd


def _local_bindings(fn: ast.AST) -> Set[str]:
    out: Set[str] = set()
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs
              + ([args.vararg] if args.vararg else [])
              + ([args.kwarg] if args.kwarg else [])):
        out.add(a.arg)
    for n in ast.walk(fn):
        if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store):
            out.add(n.id)
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.add(n.name)
    return out


@rule("RP105", "impure Pallas kernel body")
def rp105(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Finding]:
    for fn in _kernel_fns(tree):
        local = _local_bindings(fn)
        for n in ast.walk(fn):
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load) \
                    and n.id in _HOST_MODULES and n.id not in local:
                yield _finding(
                    "RP105", n, path,
                    f"host module {n.id!r} used inside Pallas kernel "
                    f"{fn.name!r} — kernel bodies trace to device code and "
                    f"must not touch the host")
            elif isinstance(n, ast.Call) and isinstance(n.func, ast.Name) \
                    and n.func.id in _HOST_BUILTINS and n.func.id not in local:
                yield _finding(
                    "RP105", n, path,
                    f"side-effecting builtin {n.func.id}() inside Pallas "
                    f"kernel {fn.name!r}")
            elif (isinstance(n, ast.Attribute) and n.attr == "float64") or \
                    (isinstance(n, ast.Constant) and n.value == "float64"):
                yield _finding(
                    "RP105", n, path,
                    f"float64 inside Pallas kernel {fn.name!r} — TPU lanes "
                    f"are 32-bit; f64 silently falls back or errors")
            elif isinstance(n, (ast.Global, ast.Nonlocal)):
                yield _finding(
                    "RP105", n, path,
                    f"{'global' if isinstance(n, ast.Global) else 'nonlocal'}"
                    f" inside Pallas kernel {fn.name!r} — the kernel traces "
                    f"once; closure mutation is a silent no-op per launch")
            elif isinstance(n, ast.Call) and isinstance(n.func, ast.Attribute) \
                    and n.func.attr in _MUTATORS \
                    and isinstance(n.func.value, ast.Name) \
                    and n.func.value.id not in local:
                yield _finding(
                    "RP105", n, path,
                    f"mutation of closure variable "
                    f"{n.func.value.id!r} inside Pallas kernel {fn.name!r} "
                    f"— runs at trace time, not per launch")


# ---------------------------------------------------------------------------
# RP106 — wall-clock reads where an injectable clock is declared
# ---------------------------------------------------------------------------

_CLOCK_PARAMS = {"now_fn", "clock"}
_WALL_CLOCKS = {"time.time", "time.perf_counter", "time.monotonic"}


def _declares_clock(tree: ast.Module) -> Optional[str]:
    for fn in func_defs(tree):
        args = fn.args
        for a in args.posonlyargs + args.args + args.kwonlyargs:
            if a.arg in _CLOCK_PARAMS:
                return a.arg
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.ctx, ast.Store) and \
                node.attr.lstrip("_") in _CLOCK_PARAMS:
            return node.attr
    return None


@rule("RP106", "wall-clock read in a module with an injectable clock")
def rp106(tree: ast.Module, lines: List[str], path: str
          ) -> Iterator[Finding]:
    declared = _declares_clock(tree)
    if declared is None:
        return
    for n in ast.walk(tree):
        if isinstance(n, ast.Call) and dotted(n.func) in _WALL_CLOCKS:
            yield _finding(
                "RP106", n, path,
                f"direct {dotted(n.func)}() call in a module that declares "
                f"an injectable clock ({declared!r}) — route it through the "
                f"injected clock so tests stay deterministic")
