"""AdamW with cosine schedule + global-norm clipping (no optax dependency —
pure JAX, pytree-structured states, sharding-friendly: m/v inherit the param
PartitionSpecs, optionally further sharded over the data axis, ZeRO-1 style)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init_state(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState
                  ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = schedule(cfg, state.step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    params = jax.tree.unflatten(treedef, [n[0] for n in new])
    m = jax.tree.unflatten(treedef, [n[1] for n in new])
    v = jax.tree.unflatten(treedef, [n[2] for n in new])
    return params, AdamWState(step=step, m=m, v=v), {
        "grad_norm": gnorm, "lr": lr}
