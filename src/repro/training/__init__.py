from repro.training.checkpoint import (latest_checkpoint, restore_checkpoint,
                                       save_checkpoint)
from repro.training.optimizer import (AdamWConfig, AdamWState, apply_updates,
                                      init_state)
from repro.training.train_loop import TrainConfig, make_train_step, train

__all__ = ["latest_checkpoint", "restore_checkpoint", "save_checkpoint",
           "AdamWConfig", "AdamWState", "apply_updates", "init_state",
           "TrainConfig", "make_train_step", "train"]
