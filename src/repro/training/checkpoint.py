"""Checkpointing: msgpack header + raw tensor payload (same container format as
the KV artifacts), atomic rename, with step bookkeeping and pytree-structure
round-tripping for arbitrarily nested param/optimizer states."""

from __future__ import annotations

import os
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.kvstore.serialization import deserialize, serialize


def _flatten_with_paths(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(directory: str, step: int, params, opt_state=None) -> str:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    tensors = {f"params/{k}": v for k, v in _flatten_with_paths(params).items()}
    if opt_state is not None:
        tensors.update({f"opt/{k}": v
                        for k, v in _flatten_with_paths(opt_state).items()})
    payload = serialize(tensors, {"step": step})
    path = d / f"ckpt_{step:08d}.mkv"
    tmp = path.with_suffix(".tmp")
    with open(tmp, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)
    return str(path)


def latest_checkpoint(directory: str) -> Optional[str]:
    d = Path(directory)
    if not d.exists():
        return None
    ckpts = sorted(d.glob("ckpt_*.mkv"))
    return str(ckpts[-1]) if ckpts else None


def restore_checkpoint(path: str, params_template, opt_template=None
                       ) -> Tuple[int, Any, Any]:
    """Restore into the shapes/structure of the provided templates."""
    with open(path, "rb") as f:
        tensors, meta = deserialize(f.read())

    def rebuild(template, prefix):
        flat_keys = list(_flatten_with_paths(template).keys())
        leaves, treedef = jax.tree.flatten(template)
        new_leaves = []
        for key, leaf in zip(flat_keys, leaves):
            arr = tensors[f"{prefix}/{key}"]
            if tuple(arr.shape) != tuple(np.shape(leaf)):
                raise ValueError(f"shape mismatch for {key}: "
                                 f"{arr.shape} vs {np.shape(leaf)}")
            new_leaves.append(arr.astype(np.asarray(leaf).dtype))
        return jax.tree.unflatten(treedef, new_leaves)

    params = rebuild(params_template, "params")
    opt = rebuild(opt_template, "opt") if opt_template is not None else None
    return int(meta["step"]), params, opt
