"""Training loop: jitted train_step (loss + AdamW update), optional gradient
accumulation, periodic checkpointing. Mesh-aware: under a mesh context the
caller passes in/out shardings resolved by ``repro.dist``; on one device it
runs as-is (smoke tests, the accuracy-benchmark training run)."""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import AdamWConfig, apply_updates, init_state


@dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0          # 0 = only at the end
    ckpt_dir: Optional[str] = None
    grad_accum: int = 1
    remat: bool = False
    adamw: AdamWConfig = field(default_factory=AdamWConfig)


def make_train_step(model, tcfg: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch, remat=tcfg.remat)

    def train_step(params, opt_state, batch):
        if tcfg.grad_accum > 1:
            def micro(carry, mb):
                loss_sum, grad_sum = carry
                (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                return (loss_sum + loss,
                        jax.tree.map(jnp.add, grad_sum, g)), None
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            mbs = jax.tree.map(
                lambda x: x.reshape((tcfg.grad_accum,
                                     x.shape[0] // tcfg.grad_accum)
                                    + x.shape[1:]), batch)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.zeros(()), zeros), mbs)
            loss = loss / tcfg.grad_accum
            grads = jax.tree.map(lambda g: g / tcfg.grad_accum, grads)
            metrics = {"loss": loss}
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        params, opt_state, opt_metrics = apply_updates(
            tcfg.adamw, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def train(model, params, batches: Iterator[Dict[str, Any]],
          tcfg: TrainConfig, jit: bool = True,
          callback: Optional[Callable] = None):
    """Run the loop; returns (params, opt_state, history)."""
    opt_state = init_state(params)
    step_fn = make_train_step(model, tcfg)
    if jit:
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
    history = []
    t0 = time.perf_counter()
    for step in range(tcfg.steps):
        batch = next(batches)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["wall_s"] = time.perf_counter() - t0
            history.append(m)
            if callback:
                callback(m)
        if (tcfg.ckpt_dir and tcfg.ckpt_every
                and step and step % tcfg.ckpt_every == 0):
            save_checkpoint(tcfg.ckpt_dir, step, params, opt_state)
    if tcfg.ckpt_dir:
        save_checkpoint(tcfg.ckpt_dir, tcfg.steps, params, opt_state)
    return params, opt_state, history
