"""Pallas TPU kernel: single-token decode attention through a page table.

The paged twin of ``chunked_decode``: instead of a dense per-row cache
``(B, KV, S, hd)``, K/V live once in a shared block pool ``(N, KV, block,
hd)`` and each row names its blocks via a block table — the device-side
counterpart of ``repro.paged``'s chunk-shared pool, where N concurrent rows
retrieving the same hot chunk attend to one HBM copy of its pages.

The block table and per-block valid-token counts ride in as scalar-prefetch
operands (``pltpu.PrefetchScalarGridSpec``): grid (batch, kv_head, block)
with the block dim innermost, and the K/V BlockSpec index maps read
``tbl[b, i]`` to DMA the right pool block — data-dependent paging with zero
gather traffic. Per-block valid counts (``block_lens``) mask ragged chunk
tails anywhere in the row, not just at the end. Flash-decoding running
stats (m, l, acc) sit in VMEM scratch exactly as in ``chunked_decode``; on
a block-aligned layout the two kernels execute the same op sequence and
agree bit-for-bit (asserted in tests).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, blen_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (group, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (block, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = blen_ref[bi, ki]                       # tokens valid in this block
    off = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(off < valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.maximum(m_new, -1e29)
    p = jnp.exp(s - m_safe)
    alpha = jnp.exp(jnp.maximum(m_prev, -1e29) - m_safe)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode(q, k_pool, v_pool, block_tables, block_lens, *,
                 interpret: bool = True):
    """q (B,H,hd); k/v pool (N,KV,block,hd); block_tables (B,n_max) int32
    pool-block ids per row (padding rows: any valid id, masked by a 0 len);
    block_lens (B,n_max) int32 valid tokens per block -> (B,H,hd).

    Each row attends over the first ``block_lens[b, i]`` tokens of block
    ``block_tables[b, i]``, in table order — the logical concatenation of
    its (possibly shared, possibly ragged) chunk pages plus private tail.
    """
    b, h, hd = q.shape
    n, kvh, block = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    if block_tables.shape != block_lens.shape or block_tables.shape[0] != b:
        raise ValueError(f"paged_decode: tables {block_tables.shape} / lens "
                         f"{block_lens.shape} must be (B={b}, n_max)")
    group = h // kvh
    n_max = block_tables.shape[1]
    qg = q.reshape(b, kvh, group, hd)
    tbl = jnp.clip(block_tables, 0, n - 1).astype(jnp.int32)
    blens = jnp.clip(block_lens, 0, block).astype(jnp.int32)

    kernel = functools.partial(_kernel, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kvh, n_max),
            in_specs=[
                pl.BlockSpec((1, 1, group, hd),
                             lambda bi, ci, ki, tbl, bl: (bi, ci, 0, 0)),
                pl.BlockSpec((1, 1, block, hd),
                             lambda bi, ci, ki, tbl, bl: (tbl[bi, ki], ci, 0, 0)),
                pl.BlockSpec((1, 1, block, hd),
                             lambda bi, ci, ki, tbl, bl: (tbl[bi, ki], ci, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda bi, ci, ki, tbl, bl: (bi, ci, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, hd), q.dtype),
        interpret=interpret,
    )(tbl, blens, qg, k_pool, v_pool)
    return out.reshape(b, h, hd)


def paged_decode_tp(q, k_pool, v_pool, block_tables, block_lens, *, mesh,
                    axis: str = "model", interpret: bool = True):
    """Tensor-parallel paged decode: ``shard_map`` over the KV-head axis.

    Each device runs the single-device kernel on its own KV-head slice of
    the pool and of q (the head axis is kv-major — ``head = kv * group + g``
    — so a contiguous H/n slice of q is exactly the query heads of a
    contiguous KV/n slice of the pool). Block tables and valid counts
    replicate: paging is head-agnostic, every shard walks the same pages.
    GQA softmax normalization is per query head, entirely inside one KV
    head, so the sharded kernel needs NO collectives and is bit-identical
    to the single-device kernel per head (asserted in
    tests/test_dist_serving.py).
    """
    from jax.sharding import PartitionSpec as P

    from repro.dist import _compat  # noqa: F401  (installs jax.shard_map)

    n = mesh.shape[axis]
    kvh = k_pool.shape[1]
    if kvh % n:
        raise ValueError(f"paged_decode_tp: num_kv_heads={kvh} must divide "
                         f"the {axis!r} mesh axis ({n}) — indivisible head "
                         f"counts serve via the replicated kernel instead")
    fn = jax.shard_map(
        functools.partial(paged_decode, interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, axis, None), P(None, axis, None, None),
                  P(None, axis, None, None), P(None, None), P(None, None)),
        out_specs=P(None, axis, None),
        check_vma=False,
    )
    return fn(q, k_pool, v_pool, block_tables, block_lens)


def tp_parity_probe(mesh, *, seed: int = 0, interpret: bool = True) -> bool:
    """Shared TP-kernel acceptance probe (bench and tests measure one
    protocol, the serving/parity.py precedent): a grouped paged layout with
    ragged / zero-length tail blocks, sized so the KV-head axis divides the
    mesh. True iff ``paged_decode_tp`` matches the single-device kernel
    bit-for-bit."""
    import numpy as np

    n = mesh.shape["model"]
    rng = np.random.default_rng(seed)
    b, kvh, group, hd, block, nblk = 2, n, 2, 16, 16, 6
    h = kvh * group
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nblk, kvh, block, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nblk, kvh, block, hd)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, nblk, size=(b, 3)), jnp.int32)
    lens = jnp.asarray([[block, block, 7], [block, 4, 0]], jnp.int32)
    ref = paged_decode(q, kp, vp, tbl, lens, interpret=interpret)
    tp = paged_decode_tp(q, kp, vp, tbl, lens, mesh=mesh,
                         interpret=interpret)
    return bool(jnp.array_equal(ref, jnp.asarray(tp)))
