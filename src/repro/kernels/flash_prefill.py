"""Pallas TPU kernel: blockwise causal flash attention (the MatKV chunk
materialization / vanilla-baseline prefill hot spot).

TPU-native adaptation of FlashAttention: the score matrix never leaves VMEM;
the grid is (batch, q_head, q_blocks, kv_blocks) with the kv dimension
innermost (sequential on TPU), carrying running max / sum / output accumulator
in VMEM scratch. GQA is expressed through the k/v BlockSpec index maps
(q head h reads kv head h // group) — no host-side K/V repetition, so HBM
traffic stays at the GQA level. Block shapes are MXU-aligned (multiples of
128 on the lane dim; head_dim is the minor dim).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, window, causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # clamp: rows with nothing visible yet keep exp() finite
    m_safe = jnp.maximum(m_new, -1e29)
    p = jnp.exp(s - m_safe)
    alpha = jnp.exp(jnp.maximum(m_prev, -1e29) - m_safe)
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_prefill(q, k, v, *, window=None, causal: bool = True,
                  block_q: int = 128, block_k: int = 128,
                  interpret: bool = True):
    """q (B,H,Sq,hd), k/v (B,KV,Sk,hd) -> (B,H,Sq,hd)."""
    b, h, sq, hd = q.shape
    kvh, sk = k.shape[1], k.shape[2]
    group = h // kvh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    if sq % block_q or sk % block_k:
        raise ValueError(f"seq lens ({sq},{sk}) must divide blocks "
                         f"({block_q},{block_k})")
    grid = (b, h, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _kernel, scale=hd ** -0.5, block_q=block_q, block_k=block_k,
        window=window, causal=causal)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bi, hi, qi, ki: (bi, hi // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
