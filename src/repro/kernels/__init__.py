# Pallas TPU kernels for the paper's compute hot spots, validated in
# interpret mode against the pure-jnp oracles in ref.py.
from repro.kernels.ops import (chunked_decode_op, flash_prefill_op,
                               kv_dequant_op, mamba_scan_op, paged_decode_op,
                               paged_decode_quant_op)
from repro.kernels.paged_decode import paged_decode_tp
from repro.kernels.paged_decode_fused import (fused_tp_parity_probe,
                                              paged_decode_fused,
                                              paged_decode_fused_quant,
                                              paged_decode_fused_tp)

__all__ = ["chunked_decode_op", "flash_prefill_op", "kv_dequant_op",
           "mamba_scan_op", "paged_decode_op", "paged_decode_quant_op",
           "paged_decode_tp", "paged_decode_fused",
           "paged_decode_fused_quant", "paged_decode_fused_tp",
           "fused_tp_parity_probe"]
