"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_prefill_ref(q, k, v, window=None):
    """Causal (optionally windowed) attention.

    q (B,H,Sq,hd), k/v (B,KV,Sk,hd), Sq == Sk (prefill). f32 softmax.
    """
    b, h, sq, hd = q.shape
    kv = k.shape[1]
    g = h // kv
    qr = q.reshape(b, kv, g, sq, hd)
    s = jnp.einsum("bcgqd,bckd->bcgqk", qr, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(k.shape[2])[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bcgqk,bckd->bcgqd", p, v)
    return out.reshape(b, h, sq, hd).astype(q.dtype)


def chunked_decode_ref(q, k, v, cache_len, window=None):
    """One-token decode attention over a composed KV cache.

    q (B,H,hd); k/v (B,KV,S,hd); cache_len scalar int (valid prefix length).
    The query sits at position cache_len (it may attend to all valid slots).
    """
    b, h, hd = q.shape
    kv, s = k.shape[1], k.shape[2]
    g = h // kv
    qr = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bcgd,bckd->bcgk", qr, k,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    kpos = jnp.arange(s)
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos > cache_len - window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bcgk,bckd->bcgd", p, v)
    return out.reshape(b, h, hd).astype(q.dtype)


def paged_decode_ref(q, k_pool, v_pool, block_tables, block_lens):
    """One-token decode attention through a page table.

    q (B,H,hd); k/v pool (N,KV,block,hd); block_tables (B,n_max) int32 pool
    block ids; block_lens (B,n_max) valid tokens per block. Each row attends
    over the first block_lens[b,i] tokens of each of its blocks, in table
    order (the logical concat of its shared chunk pages + private tail).
    """
    b, h, hd = q.shape
    n, kv, block = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = h // kv
    n_max = block_tables.shape[1]
    tbl = jnp.clip(block_tables, 0, n - 1)
    # (B, n_max, KV, block, hd) -> (B, KV, n_max*block, hd)
    kr = jnp.take(k_pool, tbl.reshape(-1), axis=0).reshape(
        b, n_max, kv, block, hd).transpose(0, 2, 1, 3, 4).reshape(
        b, kv, n_max * block, hd)
    vr = jnp.take(v_pool, tbl.reshape(-1), axis=0).reshape(
        b, n_max, kv, block, hd).transpose(0, 2, 1, 3, 4).reshape(
        b, kv, n_max * block, hd)
    qr = q.reshape(b, kv, g, hd)
    scores = jnp.einsum("bcgd,bckd->bcgk", qr, kr,
                        preferred_element_type=jnp.float32) * hd ** -0.5
    off = jnp.arange(block)[None, None]
    mask = (off < block_lens[:, :, None]).reshape(b, 1, 1, n_max * block)
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bcgk,bckd->bcgd", p, vr)
    # a fully-masked row (all block_lens 0 — a padding row) attends to
    # nothing and outputs zeros, matching the kernel's l=0 guard (plain
    # softmax would return the mean of the gathered garbage V instead)
    any_valid = (block_lens.sum(axis=1) > 0)[:, None, None, None]
    out = jnp.where(any_valid, out, 0.0)
    return out.reshape(b, h, hd).astype(q.dtype)


def paged_decode_quant_ref(q, k_pool, v_pool, k_scale, v_scale,
                           block_tables, block_lens):
    """One-token decode attention over int8 pages, replaying the kernel's
    per-block op sequence exactly.

    q (B,H,hd); int8 k/v pool (N,KV,block,hd); f16 scales (N,KV,block);
    block_tables / block_lens (B,n_max). Unlike the dense-softmax oracles,
    this one walks blocks with the same flash-decoding running stats
    (m, l, acc) and the same dequant-then-dot order as the kernel, so in
    interpret mode the two agree *bit-for-bit* — the oracle pins the fused
    dequant math, not just the attention semantics.

    Compare against the **jitted** oracle (``jax.jit(paged_decode_quant_ref)``)
    for bit-equality: under jit XLA contracts ``acc * alpha + dot(...)`` to
    an FMA exactly as it does inside the kernel, while eager op-by-op
    evaluation rounds the multiply separately (a 1-ulp difference). Bitwise
    equality holds for grouped-query shapes (group > 1 — every serving
    config here); the degenerate group == 1 GEMV lowers through a different
    XLA path and agrees to fp tolerance instead."""
    b, h, hd = q.shape
    n, kv, block = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = h // kv
    n_max = block_tables.shape[1]
    tbl = jnp.clip(block_tables, 0, n - 1).astype(jnp.int32)
    blens = jnp.clip(block_lens, 0, block).astype(jnp.int32)
    qg = q.reshape(b, kv, g, hd)
    scale = hd ** -0.5
    out = []
    for bi in range(b):
        per_head = []
        for ci in range(kv):
            qf = qg[bi, ci].astype(jnp.float32)                # (g, hd)
            m = jnp.full((g, 1), -1e30, jnp.float32)
            l = jnp.zeros((g, 1), jnp.float32)
            acc = jnp.zeros((g, hd), jnp.float32)
            for ki in range(n_max):
                blk = tbl[bi, ki]
                k = (k_pool[blk, ci].astype(jnp.float32)
                     * k_scale[blk, ci].astype(jnp.float32)[:, None])
                v = (v_pool[blk, ci].astype(jnp.float32)
                     * v_scale[blk, ci].astype(jnp.float32)[:, None])
                s = jax.lax.dot_general(
                    qf, k, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale
                off = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
                s = jnp.where(off < blens[bi, ki], s, -1e30)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
                m_safe = jnp.maximum(m_new, -1e29)
                p = jnp.exp(s - m_safe)
                alpha = jnp.exp(jnp.maximum(m, -1e29) - m_safe)
                l = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
                acc = acc * alpha + jax.lax.dot_general(
                    p, v, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                m = m_new
            per_head.append((acc / jnp.maximum(l, 1e-30)).astype(q.dtype))
        out.append(jnp.stack(per_head))
    return jnp.stack(out).reshape(b, h, hd)


def paged_decode_fused_ref(q, k_pool, v_pool, k_new, v_new, tables, lens,
                           totals, *, buf_size, k_scale=None, v_scale=None):
    """Dense-softmax oracle for the fused paged-decode kernel.

    q (B,H,hd); k/v pool (n_blocks, block, KV, hd) — the serving pool
    layout; k/v_new (B,KV,hd) the step's new token; tables/lens (B,n_max)
    block ids and valid counts in dense order; totals (B,) the row length
    including the new token. Pass ``k_scale``/``v_scale``
    (n_blocks, block, KV) for an int8 pool. Builds the compacted dense view
    exactly as ``gather_rows(_quant)`` would — each table entry's first
    ``lens[b,i]`` tokens concatenated in table order, the new token at
    dense slot ``totals-1`` — and runs the same masked dense softmax as
    ``models.attention.attention_rows`` over it.
    """
    b, h, hd = q.shape
    nblk, block, kv = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    g = h // kv
    n_max = tables.shape[1]
    tbl = jnp.clip(tables, 0, nblk - 1).astype(jnp.int32)
    blens = jnp.clip(lens, 0, block).astype(jnp.int32)
    tot = jnp.clip(totals, 1, buf_size).astype(jnp.int32)
    view_dtype = q.dtype

    def widen(pool, scale):
        blocks = jnp.take(pool, tbl.reshape(-1), axis=0)   # (B*n_max, blk, KV, hd)
        if scale is None:
            return blocks.astype(view_dtype)
        sc = jnp.take(scale, tbl.reshape(-1), axis=0)
        return (blocks.astype(jnp.float32)
                * sc.astype(jnp.float32)[..., None]).astype(view_dtype)

    kb = widen(k_pool, k_scale).reshape(b, n_max, block, kv, hd)
    vb = widen(v_pool, v_scale).reshape(b, n_max, block, kv, hd)
    # compact each row's ragged entries in dense order via a scatter of each
    # valid token to its dense slot offs[b,i] + j
    offs = jnp.cumsum(blens, axis=1) - blens                     # (B, n_max)
    tok_off = jnp.arange(block)[None, None]                      # (1,1,block)
    dense_idx = offs[:, :, None] + tok_off                       # (B,n_max,blk)
    valid = tok_off < blens[:, :, None]
    s_buf = buf_size
    dense_idx = jnp.where(valid, dense_idx, s_buf)               # park invalid
    kd = jnp.zeros((b, s_buf + 1, kv, hd), view_dtype)
    vd = jnp.zeros((b, s_buf + 1, kv, hd), view_dtype)
    bi = jnp.arange(b)[:, None, None] * jnp.ones_like(dense_idx)
    kd = kd.at[bi.reshape(b, -1), dense_idx.reshape(b, -1)].set(
        kb.reshape(b, -1, kv, hd))
    vd = vd.at[bi.reshape(b, -1), dense_idx.reshape(b, -1)].set(
        vb.reshape(b, -1, kv, hd))
    row = jnp.arange(b)
    kd = kd.at[row, tot - 1].set(k_new.astype(view_dtype))
    vd = vd.at[row, tot - 1].set(v_new.astype(view_dtype))
    kd, vd = kd[:, :s_buf], vd[:, :s_buf]

    qr = q.reshape(b, 1, kv, g, hd)
    s = jnp.einsum("bqcgd,bscd->bcgqs", qr, kd,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    mask = jnp.arange(s_buf)[None, :] < tot[:, None]             # (B, S_buf)
    s = jnp.where(mask[:, None, None, None], s, -1e30)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bcgqs,bscd->bqcgd", p / jnp.maximum(l, 1e-30), vd,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype).reshape(b, h, hd)


def kv_dequant_ref(q8, scale, dtype=jnp.bfloat16):
    """int8 (..., hd) x f16 scale (..., 1) -> dtype."""
    return (q8.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def mamba_scan_ref(x, dt, bmat, cmat, a_log, d_skip, h0):
    """Selective scan oracle. x/dt (B,S,din) f32, bmat/cmat (B,S,st),
    a_log (din,st), d_skip (din,), h0 (B,din,st). Returns (y, h_final)."""
    a = -jnp.exp(a_log)

    def step(h, inp):
        xt, dtt, bt, ct = inp
        da = jnp.exp(dtt[..., None] * a)
        h = da * h + dtt[..., None] * bt[:, None, :] * xt[..., None]
        return h, jnp.einsum("bds,bs->bd", h, ct)

    xs = (x.transpose(1, 0, 2), dt.transpose(1, 0, 2),
          bmat.transpose(1, 0, 2), cmat.transpose(1, 0, 2))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2) + d_skip[None, None, :] * x, h
