"""Pallas TPU kernel: single-token decode attention over a composed MatKV cache.

This is MatKV's serving hot spot: the new token's q attends to the
concatenated, flash-loaded chunk KVs. The cache stays in HBM and is streamed
through VMEM in ``block_k`` tiles; grid (batch, kv_head, kv_blocks) with the
kv-block dim innermost carrying flash-decoding running stats in VMEM scratch.
The valid prefix length arrives as a scalar in SMEM (slots >= cache_len are
masked — composed caches are padded to the buffer size). GQA: all ``group`` q
heads of one kv head are processed together as the (sublane) rows of one MXU
matmul — q tile is (group, hd), scores tile is (group, block_k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_k: int, window):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)
    cache_len = len_ref[0]

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (group, hd)
    k = k_ref[0, 0].astype(jnp.float32)            # (block_k, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < cache_len
    if window is not None:
        mask &= kpos > cache_len - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.maximum(m_new, -1e29)
    p = jnp.exp(s - m_safe)
    alpha = jnp.exp(jnp.maximum(m_prev, -1e29) - m_safe)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def chunked_decode(q, k, v, cache_len, *, window=None, block_k: int = 512,
                   interpret: bool = True):
    """q (B,H,hd), k/v (B,KV,S,hd), cache_len scalar int32 -> (B,H,hd)."""
    b, h, hd = q.shape
    kvh, s = k.shape[1], k.shape[2]
    group = h // kvh
    block_k = min(block_k, s)
    if s % block_k:
        raise ValueError(f"cache size {s} must divide block_k {block_k}")
    grid = (b, kvh, s // block_k)
    qg = q.reshape(b, kvh, group, hd)
    clen = jnp.asarray(cache_len, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, scale=hd ** -0.5, block_k=block_k,
                               window=window)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, group, hd), lambda bi, ci, ki: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, ci, ki: (bi, ci, ki, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda bi, ci, ki: (bi, ci, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda bi, ci, ki: (bi, ci, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, hd), jnp.float32),
        ],
        interpret=interpret,
    )(clen, qg, k, v)
    return out.reshape(b, h, hd)
