"""jit'd wrappers around the Pallas kernels, in model-layout terms.

``interpret`` defaults to True because this container is CPU-only; on a real
TPU deployment set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False) and the
same kernels compile to Mosaic.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.chunked_decode import chunked_decode
from repro.kernels.flash_prefill import flash_prefill
from repro.kernels.kv_dequant import kv_dequant
from repro.kernels.mamba_scan import mamba_scan
from repro.kernels.paged_decode import paged_decode
from repro.kernels.paged_decode_quant import paged_decode_quant


def _interpret_default() -> bool:
    return os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def flash_prefill_op(q, k, v, window=None, interpret=None):
    """Model layout: q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd)."""
    interpret = _interpret_default() if interpret is None else interpret
    out = flash_prefill(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), window=window,
                        interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def chunked_decode_op(q, k, v, cache_len, window=None, interpret=None):
    """Model layout: q (B,1,H,hd), cache k/v (B,S,KV,hd) -> (B,1,H,hd)."""
    interpret = _interpret_default() if interpret is None else interpret
    out = chunked_decode(q[:, 0], k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), cache_len,
                         window=window, interpret=interpret)
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_op(q, k_pool, v_pool, block_tables, block_lens,
                    interpret=None):
    """Model layout: q (B,1,H,hd) over a paged pool (N,KV,block,hd) with
    per-row block tables/lens (B,n_max) -> (B,1,H,hd)."""
    interpret = _interpret_default() if interpret is None else interpret
    out = paged_decode(q[:, 0], k_pool, v_pool, block_tables, block_lens,
                       interpret=interpret)
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_quant_op(q, k_pool, v_pool, k_scale, v_scale, block_tables,
                          block_lens, interpret=None):
    """Model layout: q (B,1,H,hd) over an int8 paged pool (N,KV,block,hd)
    with f16 per-vector scales (N,KV,block) and per-row block tables/lens
    (B,n_max) -> (B,1,H,hd). The storage stream stays int8; the widening
    happens in VMEM inside the kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    out = paged_decode_quant(q[:, 0], k_pool, v_pool, k_scale, v_scale,
                             block_tables, block_lens, interpret=interpret)
    return out[:, None]


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def kv_dequant_op(q8, scale, out_dtype=jnp.bfloat16, interpret=None):
    """Artifact layout: q8 (L,S,KV,hd) int8, scale (L,S,KV,1) f16."""
    interpret = _interpret_default() if interpret is None else interpret
    l, s, kvh, hd = q8.shape
    flat = kv_dequant(q8.reshape(-1, hd), scale.reshape(-1, 1),
                      out_dtype=out_dtype, interpret=interpret)
    return flat.reshape(l, s, kvh, hd)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mamba_scan_op(x, dt, bmat, cmat, a_log, d_skip, h0, interpret=None):
    """Model layout (matches models.mamba.selective_scan): adds the D-skip."""
    interpret = _interpret_default() if interpret is None else interpret
    y, h = mamba_scan(x, dt, bmat, cmat, a_log, h0, interpret=interpret)
    return y + d_skip * x, h
