"""Online-softmax carry over an *arriving* KV prefix (streaming admission,
DESIGN.md §16).

A cold request's document KV lands block-by-block off flash. Its layer-0
prompt queries depend only on the prompt tokens (embed -> ln1 -> Wq -> RoPE),
so layer-0 prompt-over-document attention can run *incrementally*: one
flash-attention-style (m, l, acc) carry update per arriving block, in arrival
order, while the loader races the tail pages. These ops restate the exact
online body of ``models.attention._flash_fwd`` — same score einsum and scale,
same ``m0 = -1e29`` init, same ``NEG_INF`` masking, same f32 accumulators —
so folding the blocks one at a time computes the same softmax the all-at-once
path computes, up to f32 summation order. That is what makes the first
sampled token of a streamed admission match the all-or-nothing path (bf16
greedy-identical; int8 inside the shared parity bound).

Document blocks need no position mask: every document token is causally
visible to every prompt query (order positions 0..n_doc-1 < n_doc..), and
block *padding* is handled by a validity mask whose ``exp`` contributes an
exact 0.0. Callers pad arriving blocks to bucketed widths (multiples of the
pool block size) so ``carry_update`` retraces once per bucket, not per
arrival width.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30     # masked score (matches models.attention.NEG_INF)
M_INIT = -1e29      # running-max init (matches _flash_fwd's m0)


def carry_init(b: int, sq: int, n_heads: int, n_kv_heads: int, hd: int):
    """Fresh (m, l, acc) for ``sq`` prompt queries — _flash_fwd's carry init."""
    g = n_heads // n_kv_heads
    m0 = jnp.full((b, n_kv_heads, g, sq, 1), M_INIT, jnp.float32)
    l0 = jnp.zeros((b, n_kv_heads, g, sq, 1), jnp.float32)
    acc0 = jnp.zeros((b, sq, n_kv_heads, g, hd), jnp.float32)
    return m0, l0, acc0


def carry_block(m, l, acc, qr, k_blk, v_blk, mask=None):
    """One online-softmax block fold (the ``_flash_fwd`` scan body).

    qr (B,Sq,KV,G,hd) pre-grouped queries, k/v_blk (B,W,KV,hd),
    mask (B,Sq,W) bool or None (None = every slot valid and visible).
    Pure jnp so larger jitted functions (the streamed decode step) can
    inline it; ``carry_update`` below is the jitted eager-path wrapper.
    """
    scale = qr.shape[-1] ** -0.5
    s = jnp.einsum("bqcgd,bscd->bcgqs", qr, k_blk,
                   preferred_element_type=jnp.float32) * scale
    if mask is not None:
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m - m_new)               # rescale of old accumulators
    p = jnp.exp(s - m_new)
    l_new = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jnp.einsum("bcgqs,bscd->bqcgd", p, v_blk,
                    preferred_element_type=jnp.float32)
    return m_new, l_new, acc * alpha.transpose(0, 3, 1, 2, 4) + pv


@jax.jit
def carry_update(m, l, acc, q, k_blk, v_blk, n_valid):
    """Fold one arriving document block into the carry.

    q (B,Sq,H,hd) roped layer-0 prompt queries; k/v_blk (B,W,KV,hd) padded
    to a bucketed width W with the first ``n_valid`` (traced scalar) tokens
    real. Document tokens take no position mask — only padding validity.
    """
    b, sq, h, hd = q.shape
    kvh = k_blk.shape[2]
    qr = q.reshape(b, sq, kvh, h // kvh, hd)
    w = k_blk.shape[1]
    valid = jnp.broadcast_to(
        (jnp.arange(w, dtype=jnp.int32) < n_valid)[None, None, :],
        (b, sq, w))
    return carry_block(m, l, acc, qr, k_blk, v_blk, valid)


def carry_finalize(m, l, acc, dtype):
    """(m, l, acc) -> attention output (B,Sq,H,hd) — _flash_fwd's epilogue."""
    del m
    b, sq, kvh, g, hd = acc.shape
    out = acc / jnp.maximum(l.transpose(0, 3, 1, 2, 4), 1e-30)
    return out.astype(dtype).reshape(b, sq, kvh * g, hd)
