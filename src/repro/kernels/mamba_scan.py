"""Pallas TPU kernel: chunked Mamba selective scan.

The recurrence h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t is sequential in t, but
TPU-native chunking keeps it fast: the grid is (batch, d_inner blocks, time
chunks) with time innermost (sequential); the (block_d, state) hidden state
lives in VMEM scratch and is carried across time chunks, while each chunk's
x/dt/B/C tiles stream HBM->VMEM. Within a chunk a fori_loop steps the
recurrence entirely in registers/VMEM. This is the materialization hot spot
for SSM archs (falcon-mamba): MatKV's per-chunk state artifact is h after the
final time chunk (also written out).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, h0_ref, y_ref, hout_ref,
            h_scr, *, block_t: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    a = -jnp.exp(alog_ref[...].astype(jnp.float32))      # (bd, st)
    x = x_ref[0].astype(jnp.float32)                     # (bt, bd)
    dt = dt_ref[0].astype(jnp.float32)                   # (bt, bd)
    bm = b_ref[0].astype(jnp.float32)                    # (bt, st)
    cm = c_ref[0].astype(jnp.float32)                    # (bt, st)

    def step(t, carry):
        h = carry
        da = jnp.exp(dt[t][:, None] * a)                 # (bd, st)
        h = da * h + (dt[t] * x[t])[:, None] * bm[t][None, :]
        y_ref[0, t, :] = jnp.sum(h * cm[t][None, :], axis=1).astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[...])
    h_scr[...] = h

    @pl.when(ti == nt - 1)
    def _write_state():
        hout_ref[0] = h.astype(hout_ref.dtype)


def mamba_scan(x, dt, bmat, cmat, a_log, h0, *, block_d: int = 256,
               block_t: int = 128, interpret: bool = True):
    """Chunked selective scan (no D-skip; ops.py adds it).

    x/dt (B,S,din) f32, bmat/cmat (B,S,st), a_log (din,st), h0 (B,din,st).
    Returns (y (B,S,din), h_final (B,din,st)).
    """
    b, s, din = x.shape
    st = bmat.shape[-1]
    block_d = min(block_d, din)
    block_t = min(block_t, s)
    if din % block_d or s % block_t:
        raise ValueError(f"(din={din}, S={s}) must divide blocks "
                         f"({block_d},{block_t})")
    grid = (b, din // block_d, s // block_t)

    kernel = functools.partial(_kernel, block_t=block_t)
    y, h = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_t, st), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, st), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((block_d, st), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((1, block_d, st), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_d, st), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, din), x.dtype),
            jax.ShapeDtypeStruct((b, din, st), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, st), jnp.float32)],
        interpret=interpret,
    )(x, dt, bmat, cmat, a_log, h0)
    return y, h
