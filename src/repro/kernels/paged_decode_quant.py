"""Pallas TPU kernel: single-token decode attention over *int8* pages.

The quantized twin of ``paged_decode`` (DESIGN.md §11): K/V live in the
shared block pool at storage width — int8 values ``(N, KV, block, hd)`` plus
per-vector f16 scales ``(N, KV, block)`` — and the widening happens *inside*
the kernel, in VMEM, one block at a time, immediately before the attention
dot. The HBM→VMEM stream for a KV block is ``block × (hd + 2)`` bytes
instead of ``block × 2·hd``: the DMA traffic halves along with the flash
bytes, which is the whole point of making the codec end-to-end.

Paging machinery is identical to ``paged_decode``: block tables and
per-block valid-token counts ride in as scalar-prefetch operands
(``pltpu.PrefetchScalarGridSpec``), grid (batch, kv_head, block) with the
block dim innermost, and the K/V/scale BlockSpec index maps read
``tbl[b, i]`` to DMA the right pool block. Flash-decoding running stats
(m, l, acc) sit in VMEM scratch. The dequantized block is bit-identical to
host ``dequantize_kv`` of the same page (same f32 multiply), so on shared
pages the kernel sees exactly the values the dense int8 path composes —
and ``paged_decode_quant_ref`` (kernels.ref) replays the same op sequence
block-by-block, so kernel and oracle agree bit-for-bit (asserted in tests
and in the quantized-residency benchmark).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(tbl_ref, blen_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # (group, hd)
    # fused dequant in VMEM, right next to the compute: int8 values widen by
    # their per-vector scales only here — HBM never holds wide KV
    k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0].astype(jnp.float32)[:, None]
    v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0].astype(jnp.float32)[:, None]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    valid = blen_ref[bi, ki]                       # tokens valid in this block
    off = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(off < valid, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    m_safe = jnp.maximum(m_new, -1e29)
    p = jnp.exp(s - m_safe)
    alpha = jnp.exp(jnp.maximum(m_prev, -1e29) - m_safe)
    l_scr[...] = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _write():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def paged_decode_quant(q, k_pool, v_pool, k_scale, v_scale, block_tables,
                       block_lens, *, interpret: bool = True):
    """q (B,H,hd); int8 k/v pool (N,KV,block,hd); f16 scales (N,KV,block);
    block_tables (B,n_max) int32 pool-block ids per row (padding rows: any
    valid id, masked by a 0 len); block_lens (B,n_max) int32 valid tokens
    per block -> (B,H,hd).

    Each row attends over the first ``block_lens[b, i]`` tokens of block
    ``block_tables[b, i]``, in table order — the logical concatenation of
    its (possibly shared, possibly ragged) chunk pages plus private tail.
    """
    b, h, hd = q.shape
    n, kvh, block = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    if block_tables.shape != block_lens.shape or block_tables.shape[0] != b:
        raise ValueError(f"paged_decode_quant: tables {block_tables.shape} / "
                         f"lens {block_lens.shape} must be (B={b}, n_max)")
    if k_scale.shape != (n, kvh, block) or v_scale.shape != (n, kvh, block):
        raise ValueError(f"paged_decode_quant: scales must be "
                         f"(N={n}, KV={kvh}, block={block}), got "
                         f"{k_scale.shape} / {v_scale.shape}")
    group = h // kvh
    n_max = block_tables.shape[1]
    qg = q.reshape(b, kvh, group, hd)
    tbl = jnp.clip(block_tables, 0, n - 1).astype(jnp.int32)
    blens = jnp.clip(block_lens, 0, block).astype(jnp.int32)

    kernel = functools.partial(_kernel, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, kvh, n_max),
            in_specs=[
                pl.BlockSpec((1, 1, group, hd),
                             lambda bi, ci, ki, tbl, bl: (bi, ci, 0, 0)),
                pl.BlockSpec((1, 1, block, hd),
                             lambda bi, ci, ki, tbl, bl: (tbl[bi, ki], ci, 0, 0)),
                pl.BlockSpec((1, 1, block, hd),
                             lambda bi, ci, ki, tbl, bl: (tbl[bi, ki], ci, 0, 0)),
                pl.BlockSpec((1, 1, block),
                             lambda bi, ci, ki, tbl, bl: (tbl[bi, ki], ci, 0)),
                pl.BlockSpec((1, 1, block),
                             lambda bi, ci, ki, tbl, bl: (tbl[bi, ki], ci, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda bi, ci, ki, tbl, bl: (bi, ci, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, 1), jnp.float32),
                pltpu.VMEM((group, hd), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, hd), q.dtype),
        interpret=interpret,
    )(tbl, blens, qg, k_pool, v_pool, k_scale, v_scale)
    return out.reshape(b, h, hd)
