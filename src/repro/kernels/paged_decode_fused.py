"""Pallas TPU kernel: fused paged decode — gather, dequant, attend and the
new token's attention in ONE launch per layer.

The serving paged step before this kernel was three jitted phases per token:
``gather_rows(_quant)`` materializes a dense ``(B, S_buf)`` view of the page
table, ``decode_step_rows`` attends over it, ``scatter_decode_token(_quant)``
writes the new token back — three full-working-set HBM round trips per step
(the binding cost the KV-offloading bottleneck analysis in PAPERS.md
identifies once KVs are resident). This kernel reads each row's KV pages
exactly once, directly through the scalar-prefetched block table of the
serving pool layout ``(n_blocks, block, KV, hd)``, dequantizes int8 pages
next to the dot in VMEM, stages the row's ragged pages *compacted in dense
order* into a VMEM buffer, appends the step's new-token K/V at the row's
ragged length, and computes the full softmax in the SAME op order as the
dense ``attention_rows`` path — which is what makes the fused step
bit-identical to gather → decode → scatter at the logits level (asserted in
tests/test_paged_fused.py and fuzzed against the oracle in
tests/test_kernel_fuzz.py).

Layout notes:

* grid ``(B, KV, n_max)`` with the table dim innermost; per (row, kv-head)
  the n_max iterations DMA one pool block each and copy its first
  ``lens[b, i]`` valid tokens to scratch offset ``offs[b, i]`` (the
  exclusive cumsum of lens). Ascending-i writes clobber the previous
  block's ragged garbage tail, so after the last iteration scratch holds
  the row's tokens exactly as the dense gather would lay them out.
* the new token is staged at offset ``totals - 1`` (totals = row length
  including the new token) AFTER the last block copy, then one dense-order
  softmax runs over the whole buffer with an ``iota < totals`` mask —
  masked lanes contribute an exact 0.0 after the exp, so the padded buffer
  is value-identical to the dense path's masked ``S_buf`` axis.
* the pool APPEND of the new token is NOT done in-kernel: the caller
  persists the returned per-layer K/V through the page table (one
  token-granularity ``.at[slots].set`` per step, `engine._fused_step`),
  keeping the kernel free of input/output aliasing and keeping the
  shared-page mutation guard (DESIGN.md §13) a host-side invariant.

``paged_decode_fused_tp`` is the shard_map twin over the KV-head axis,
mirroring ``paged_decode_tp``: paging is head-agnostic so the tables
replicate, GQA softmax normalization lives entirely inside one KV head, and
the sharded kernel is bit-identical per head (``fused_tp_parity_probe``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attend(q_ref, kn_ref, vn_ref, o_ref, k_buf, v_buf, totals, bi,
            *, scale: float):
    """Stage the new token and run the dense-order softmax over the staged
    buffer. Op sequence mirrors ``models.attention.attention_rows`` exactly
    (scores -> mask -> clamped max -> exp -> sum -> p/l @ v) so the fused
    step matches the three-phase pipeline bit-for-bit at the logits level."""
    t = totals[bi]
    k_buf[pl.ds(t - 1, 1), :] = kn_ref[0, 0][None].astype(k_buf.dtype)
    v_buf[pl.ds(t - 1, 1), :] = vn_ref[0, 0][None].astype(v_buf.dtype)

    q = q_ref[0, 0].astype(jnp.float32)                  # (group, hd)
    k = k_buf[...].astype(jnp.float32)                   # (S_max, hd)
    v = v_buf[...].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    idx = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(idx < t, s, NEG_INF)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jax.lax.dot_general(p / jnp.maximum(l, 1e-30), v,
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[0, 0] = o.astype(o_ref.dtype)


def _zero_scratch(ki, k_buf, v_buf):
    # Fresh (row, kv-head) cell: zero the scratch so lanes past the staged
    # region hold exact 0.0 — masked softmax weights underflow to 0.0 and
    # 0.0 * 0.0 contributes exactly nothing to the p @ v dot, matching the
    # dense path's masked buffer tail. (Uninitialized VMEM could hold NaN,
    # and 0.0 * NaN would poison the output.)
    @pl.when(ki == 0)
    def _():
        k_buf[...] = jnp.zeros_like(k_buf)
        v_buf[...] = jnp.zeros_like(v_buf)


def _kernel(tbl_ref, lens_ref, offs_ref, totals_ref, q_ref, k_ref, v_ref,
            kn_ref, vn_ref, o_ref, k_buf, v_buf, *, scale: float):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    block = k_ref.shape[1]
    off = offs_ref[bi, ki]
    _zero_scratch(ki, k_buf, v_buf)
    # stage the whole DMA'd block; the next iteration's write (at off + lens)
    # clobbers the garbage beyond this block's valid count, and the final
    # iota < totals mask covers the buffer tail
    k_buf[pl.ds(off, block), :] = k_ref[0, :, 0, :]
    v_buf[pl.ds(off, block), :] = v_ref[0, :, 0, :]

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        _attend(q_ref, kn_ref, vn_ref, o_ref, k_buf, v_buf, totals_ref, bi,
                scale=scale)


def _kernel_quant(tbl_ref, lens_ref, offs_ref, totals_ref, q_ref, k_ref,
                  v_ref, ks_ref, vs_ref, kn_ref, vn_ref, o_ref, k_buf, v_buf,
                  *, scale: float):
    bi = pl.program_id(0)
    ki = pl.program_id(2)
    block = k_ref.shape[1]
    off = offs_ref[bi, ki]
    _zero_scratch(ki, k_buf, v_buf)
    # widen int8 pages by their f16 per-vector scales in VMEM, next to the
    # dot — the exact per-element math of gather_rows_quant / dequantize_kv
    # (f32 multiply, then cast), so staged values are bit-identical to the
    # dense view the three-phase pipeline attends over
    k_sc = ks_ref[0, :, 0].astype(jnp.float32)[:, None]
    v_sc = vs_ref[0, :, 0].astype(jnp.float32)[:, None]
    k_buf[pl.ds(off, block), :] = (k_ref[0, :, 0, :].astype(jnp.float32)
                                   * k_sc).astype(k_buf.dtype)
    v_buf[pl.ds(off, block), :] = (v_ref[0, :, 0, :].astype(jnp.float32)
                                   * v_sc).astype(v_buf.dtype)

    @pl.when(ki == pl.num_programs(2) - 1)
    def _():
        _attend(q_ref, kn_ref, vn_ref, o_ref, k_buf, v_buf, totals_ref, bi,
                scale=scale)


def _prep(q, k_pool, tables, lens, totals, buf_size):
    b, h, hd = q.shape
    nblk, block, kvh = k_pool.shape[0], k_pool.shape[1], k_pool.shape[2]
    if tables.shape != lens.shape or tables.shape[0] != b:
        raise ValueError(f"paged_decode_fused: tables {tables.shape} / lens "
                         f"{lens.shape} must be (B={b}, n_max)")
    group = h // kvh
    qg = q.reshape(b, kvh, group, hd)
    tbl = jnp.clip(tables, 0, nblk - 1).astype(jnp.int32)
    blens = jnp.clip(lens, 0, block).astype(jnp.int32)
    offs = (jnp.cumsum(blens, axis=1) - blens).astype(jnp.int32)
    tot = jnp.clip(totals, 1, buf_size).astype(jnp.int32)
    # staging room for one whole block past the last valid offset (partial
    # blocks are staged whole and clobbered/masked)
    s_max = buf_size + block
    return qg, tbl, blens, offs, tot, group, block, s_max


def paged_decode_fused(q, k_pool, v_pool, k_new, v_new, tables, lens, totals,
                       *, buf_size: int, interpret: bool = True):
    """q (B,H,hd); k/v pool (n_blocks, block, KV, hd) — the serving pool's
    per-layer slice; k/v_new (B,KV,hd) the step's new-token K/V (already in
    the pool view dtype); tables/lens (B,n_max) int32 pool-block ids and
    valid token counts per table entry, in dense order; totals (B,) int32
    row length INCLUDING the new token. Returns attention out (B,H,hd).

    Every row attends over the logical concatenation of its table entries'
    valid tokens plus the new token at position ``totals - 1`` — exactly the
    dense view the three-phase gather builds, without materializing it.
    """
    b, h, hd = q.shape
    qg, tbl, blens, offs, tot, group, block, s_max = _prep(
        q, k_pool, tables, lens, totals, buf_size)
    n_max = tbl.shape[1]
    kvh = k_pool.shape[2]

    kernel = functools.partial(_kernel, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(b, kvh, n_max),
            in_specs=[
                pl.BlockSpec((1, 1, group, hd),
                             lambda bi, ci, ki, *s: (bi, ci, 0, 0)),
                pl.BlockSpec((1, block, 1, hd),
                             lambda bi, ci, ki, tbl, *s: (tbl[bi, ki], 0, ci, 0)),
                pl.BlockSpec((1, block, 1, hd),
                             lambda bi, ci, ki, tbl, *s: (tbl[bi, ki], 0, ci, 0)),
                pl.BlockSpec((1, 1, hd),
                             lambda bi, ci, ki, *s: (bi, ci, 0)),
                pl.BlockSpec((1, 1, hd),
                             lambda bi, ci, ki, *s: (bi, ci, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda bi, ci, ki, *s: (bi, ci, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((s_max, hd), k_pool.dtype),
                pltpu.VMEM((s_max, hd), v_pool.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, hd), q.dtype),
        interpret=interpret,
    )(tbl, blens, offs, tot, qg, k_pool, v_pool, k_new, v_new)
    return out.reshape(b, h, hd)


def paged_decode_fused_quant(q, k_pool, v_pool, k_scale, v_scale, k_new,
                             v_new, tables, lens, totals, *, buf_size: int,
                             interpret: bool = True):
    """Quantized twin: int8 pools (n_blocks, block, KV, hd) + f16 per-vector
    scales (n_blocks, block, KV). The storage stream stays int8 from HBM to
    VMEM; widening happens next to the dot. The new token attends at the
    view dtype this step (exactly like the dense path, which writes it into
    the activation-width view) — quantization applies only to the stored
    pool copy the caller appends."""
    b, h, hd = q.shape
    qg, tbl, blens, offs, tot, group, block, s_max = _prep(
        q, k_pool, tables, lens, totals, buf_size)
    n_max = tbl.shape[1]
    kvh = k_pool.shape[2]

    kernel = functools.partial(_kernel_quant, scale=hd ** -0.5)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=(b, kvh, n_max),
            in_specs=[
                pl.BlockSpec((1, 1, group, hd),
                             lambda bi, ci, ki, *s: (bi, ci, 0, 0)),
                pl.BlockSpec((1, block, 1, hd),
                             lambda bi, ci, ki, tbl, *s: (tbl[bi, ki], 0, ci, 0)),
                pl.BlockSpec((1, block, 1, hd),
                             lambda bi, ci, ki, tbl, *s: (tbl[bi, ki], 0, ci, 0)),
                pl.BlockSpec((1, block, 1),
                             lambda bi, ci, ki, tbl, *s: (tbl[bi, ki], 0, ci)),
                pl.BlockSpec((1, block, 1),
                             lambda bi, ci, ki, tbl, *s: (tbl[bi, ki], 0, ci)),
                pl.BlockSpec((1, 1, hd),
                             lambda bi, ci, ki, *s: (bi, ci, 0)),
                pl.BlockSpec((1, 1, hd),
                             lambda bi, ci, ki, *s: (bi, ci, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, group, hd),
                                   lambda bi, ci, ki, *s: (bi, ci, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((s_max, hd), q.dtype),
                pltpu.VMEM((s_max, hd), q.dtype),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, hd), q.dtype),
        interpret=interpret,
    )(tbl, blens, offs, tot, qg, k_pool, v_pool, k_scale, v_scale,
      k_new, v_new)
    return out.reshape(b, h, hd)


def paged_decode_fused_tp(q, k_pool, v_pool, k_new, v_new, tables, lens,
                          totals, *, buf_size: int, mesh, axis: str = "model",
                          k_scale=None, v_scale=None, interpret: bool = True):
    """Tensor-parallel fused paged decode: ``shard_map`` over the KV-head
    axis, mirroring ``paged_decode_tp``. q's head axis is kv-major
    (``head = kv * group + g``) so a contiguous H/n slice of q is exactly
    the query heads of a contiguous KV/n slice of the pool; block tables,
    valid counts and totals replicate (paging is head-agnostic). GQA softmax
    normalization is per query head, entirely inside one KV head, so the
    sharded kernel needs NO collectives and is bit-identical to the
    single-device kernel per head (``fused_tp_parity_probe``). Pass
    ``k_scale``/``v_scale`` for an int8 pool."""
    from jax.sharding import PartitionSpec as P

    from repro.dist import _compat  # noqa: F401  (installs jax.shard_map)

    n = mesh.shape[axis]
    kvh = k_pool.shape[2]
    if kvh % n:
        raise ValueError(f"paged_decode_fused_tp: num_kv_heads={kvh} must "
                         f"divide the {axis!r} mesh axis ({n}) — indivisible "
                         f"head counts serve via the three-phase path "
                         f"instead")
    rep2, rep1 = P(None, None), P(None)
    if k_scale is None:
        fn = jax.shard_map(
            functools.partial(paged_decode_fused, buf_size=buf_size,
                              interpret=interpret),
            mesh=mesh,
            in_specs=(P(None, axis, None), P(None, None, axis, None),
                      P(None, None, axis, None), P(None, axis, None),
                      P(None, axis, None), rep2, rep2, rep1),
            out_specs=P(None, axis, None),
            check_vma=False,
        )
        return fn(q, k_pool, v_pool, k_new, v_new, tables, lens, totals)
    fn = jax.shard_map(
        functools.partial(paged_decode_fused_quant, buf_size=buf_size,
                          interpret=interpret),
        mesh=mesh,
        in_specs=(P(None, axis, None), P(None, None, axis, None),
                  P(None, None, axis, None), P(None, None, axis),
                  P(None, None, axis), P(None, axis, None),
                  P(None, axis, None), rep2, rep2, rep1),
        out_specs=P(None, axis, None),
        check_vma=False,
    )
    return fn(q, k_pool, v_pool, k_scale, v_scale, k_new, v_new,
              tables, lens, totals)


def fused_tp_parity_probe(mesh, *, seed: int = 0,
                          interpret: bool = True) -> bool:
    """Shared TP-kernel acceptance probe (tests and bench measure one
    protocol, like ``paged_decode.tp_parity_probe``): a grouped paged layout
    with ragged / partial table entries sized so the KV-head axis divides
    the mesh. True iff ``paged_decode_fused_tp`` matches the single-device
    fused kernel bit-for-bit."""
    import numpy as np

    n = mesh.shape["model"]
    rng = np.random.default_rng(seed)
    b, kvh, group, hd, block, nblk, buf = 2, n, 2, 16, 16, 6, 64
    h = kvh * group
    q = jnp.asarray(rng.normal(size=(b, h, hd)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(nblk, block, kvh, hd)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(nblk, block, kvh, hd)), jnp.float32)
    kn = jnp.asarray(rng.normal(size=(b, kvh, hd)), jnp.float32)
    vn = jnp.asarray(rng.normal(size=(b, kvh, hd)), jnp.float32)
    tbl = jnp.asarray(rng.integers(0, nblk, size=(b, 3)), jnp.int32)
    lens = jnp.asarray([[block, block, 7], [block, 4, 0]], jnp.int32)
    totals = jnp.sum(lens, axis=1) + 1
    ref = paged_decode_fused(q, kp, vp, kn, vn, tbl, lens, totals,
                             buf_size=buf, interpret=interpret)
    tp = paged_decode_fused_tp(q, kp, vp, kn, vn, tbl, lens, totals,
                               buf_size=buf, mesh=mesh, interpret=interpret)
    return bool(jnp.array_equal(ref, jnp.asarray(tp)))
