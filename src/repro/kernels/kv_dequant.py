"""Pallas TPU kernel: on-load int8 -> bf16 KV dequantization.

MatKV's int8-on-flash extension (DESIGN.md §9) halves flash bytes; this kernel
turns the loaded int8 payload + per-vector f16 scales back into bf16 KV tiles
on-chip, so the HBM->VMEM stream stays at int8 width and the widening happens
next to the compute. Elementwise, tiled over (rows, hd) VMEM blocks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s).astype(o_ref.dtype)


def kv_dequant(q8, scale, *, out_dtype=jnp.bfloat16, block_rows: int = 256,
               interpret: bool = True):
    """q8 (N, hd) int8, scale (N, 1) f16 -> (N, hd) out_dtype.

    Callers flatten (L,S,KV) into N; ops.py handles the reshape. Row counts
    that don't divide ``block_rows`` (any trimmed ragged chunk, e.g. 300
    rows) are padded up to the block multiple and the result sliced back —
    padded rows dequantize zeros, never touching real output rows.
    """
    n, hd = q8.shape
    block_rows = min(block_rows, max(n, 1))
    pad = -n % block_rows
    if pad:
        q8 = jnp.pad(jnp.asarray(q8), ((0, pad), (0, 0)))
        scale = jnp.pad(jnp.asarray(scale), ((0, pad), (0, 0)))
    n_padded = n + pad
    grid = (n_padded // block_rows,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, hd), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_padded, hd), out_dtype),
        interpret=interpret,
    )(q8, scale)
    return out[:n] if pad else out
