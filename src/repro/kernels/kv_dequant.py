"""Pallas TPU kernel: on-load int8 -> bf16 KV dequantization.

MatKV's int8-on-flash extension (DESIGN.md §9) halves flash bytes; this kernel
turns the loaded int8 payload + per-vector f16 scales back into bf16 KV tiles
on-chip, so the HBM->VMEM stream stays at int8 width and the widening happens
next to the compute. Elementwise, tiled over (rows, hd) VMEM blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, s_ref, o_ref):
    q = q_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    o_ref[...] = (q * s).astype(o_ref.dtype)


def kv_dequant(q8, scale, *, out_dtype=jnp.bfloat16, block_rows: int = 256,
               interpret: bool = True):
    """q8 (N, hd) int8, scale (N, 1) f16 -> (N, hd) out_dtype.

    Callers flatten (L,S,KV) into N; ops.py handles the reshape.
    """
    n, hd = q8.shape
    block_rows = min(block_rows, n)
    if n % block_rows:
        raise ValueError(f"rows {n} must divide block_rows {block_rows}")
    grid = (n // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, hd), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, hd), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, hd), out_dtype),
        interpret=interpret,
    )(q8, scale)
