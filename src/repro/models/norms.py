"""Normalization layers (functional)."""

from __future__ import annotations

import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, weight: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * (var + eps) ** -0.5
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jnp.ndarray, weight: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * (var + eps) ** -0.5
    return (out * weight.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)
