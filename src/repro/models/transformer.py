"""Decoder-only model assembly for the dense / moe / ssm / hybrid / vlm families.

Parameters are plain nested dicts. Homogeneous layer stacks keep their params
stacked with a leading L dim and run under ``lax.scan`` (small HLO, fast
compiles even at 64 layers); heterogeneous stacks (hybrid block patterns,
DeepSeek's leading dense layer) unroll in Python.

Three entry points per model (the MatKV lifecycle):
  forward      — full causal forward (training / vanilla-baseline prefill)
  prefill      — forward that also returns the per-layer KV stack / final
                 recurrent states: the artifact MatKV materializes to flash
  decode_step  — Sq new tokens against a cache (Sq=1: decode; Sq>1: the
                 composed "sub-prefill" of a user query over loaded doc KVs)
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ATTENTION
from repro.dist.sharding import shard
from repro.kernels.streaming_prefix import carry_block, carry_finalize
from repro.models.attention import (attn_into_cache, attn_into_cache_rows,
                                    attn_paged_fused, attn_self,
                                    attn_with_prefix, init_attention,
                                    project_kv, project_q)
from repro.models.cache import (AttnCache, HybridCache, RowAttnCache, SSMCache,
                                write_kv)
from repro.models.mamba import init_mamba, mamba_fwd
from repro.models.mlp import init_mlp, mlp
from repro.models.moe import init_moe, moe_ffn
from repro.models.norms import rms_norm
from repro.models.rglru import init_rglru, rglru_fwd
from repro.models.scan_utils import scan_layers


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_dense_layer(cfg, key, d_ff: int = 0):
    k1, k2 = jax.random.split(key)
    return {
        "attn": init_attention(cfg, k1),
        "mlp": init_mlp(cfg, k2, d_ff=d_ff),
        "ln1": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "ln2": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
    }


def _init_moe_layer(cfg, key):
    k1, k2 = jax.random.split(key)
    return {
        "attn": init_attention(cfg, k1),
        "moe": init_moe(cfg, k2),
        "ln1": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "ln2": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
    }


def _init_mamba_layer(cfg, key):
    return {
        "mamba": init_mamba(cfg, key),
        "ln1": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
    }


def _init_hybrid_layer(cfg, key, kind: str):
    k1, k2 = jax.random.split(key)
    mix = (init_attention(cfg, k1) if kind == ATTENTION else init_rglru(cfg, k1))
    return {
        ("attn" if kind == ATTENTION else "rec"): mix,
        "mlp": init_mlp(cfg, k2),
        "ln1": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "ln2": jnp.ones((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
    }


def init_params(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    p = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * cfg.d_model ** -0.5).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(
            keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32)
            * cfg.d_model ** -0.5).astype(dt)
    if cfg.frontend:
        p["projector"] = (jax.random.normal(
            keys[2], (cfg.d_model, cfg.d_model), jnp.float32)
            * cfg.d_model ** -0.5).astype(dt)

    fam = cfg.family
    if fam in ("dense", "vlm"):
        lkeys = jax.random.split(keys[3], cfg.num_layers)
        p["layers"] = jax.vmap(lambda k: _init_dense_layer(cfg, k))(lkeys)
    elif fam == "moe":
        n_pre = cfg.first_dense_layers
        p["prefix_layers"] = [
            _init_dense_layer(cfg, jax.random.fold_in(keys[4], i),
                              d_ff=cfg.dense_d_ff or cfg.d_ff)
            for i in range(n_pre)]
        lkeys = jax.random.split(keys[3], cfg.num_layers - n_pre)
        p["layers"] = jax.vmap(lambda k: _init_moe_layer(cfg, k))(lkeys)
    elif fam == "ssm":
        lkeys = jax.random.split(keys[3], cfg.num_layers)
        p["layers"] = jax.vmap(lambda k: _init_mamba_layer(cfg, k))(lkeys)
    elif fam == "hybrid":
        p["layers"] = [
            _init_hybrid_layer(cfg, jax.random.fold_in(keys[3], i), kind)
            for i, kind in enumerate(cfg.layer_kinds)]
    else:
        raise ValueError(f"transformer.init_params: unsupported family {fam}")
    return p


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_inputs(cfg, params, tokens, frontend: Optional[jnp.ndarray] = None):
    """tokens (B,S_text) [+ frontend (B,T,D)] -> x (B,S,D)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    if cfg.family == "hybrid":  # gemma-style embedding scale
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if frontend is not None:
        fe = (frontend.astype(cfg.activation_dtype) @ params["projector"])
        x = jnp.concatenate([fe, x], axis=1)
    # act_seq resolves to () outside seq-parallel rules (single device, decode)
    return shard(x, "batch", "act_seq", None)


def unembed(cfg, params, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    if getattr(cfg, "logit_softcap", None):
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    # NOT act_seq here: vocab already occupies the model axis and a
    # PartitionSpec may use a mesh axis once (vocab-sharded logits are the
    # natural matmul output layout)
    return shard(logits, "batch", None, "vocab")


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _dense_block(cfg, lp, x, positions, remat: bool):
    def body(lp, x):
        a, kv = attn_self(cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                          positions)
        x = x + a
        x = x + mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        # layer-boundary residual: sequence-sharded under training rules
        # (Megatron sequence parallelism; "act_seq" -> () outside training)
        return shard(x, "batch", "act_seq", None), kv
    if remat:
        body = jax.checkpoint(body)
    return body(lp, x)


def _moe_block(cfg, lp, x, positions, remat: bool):
    def body(lp, x):
        a, kv = attn_self(cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                          positions)
        x = x + a
        m, aux = moe_ffn(cfg, lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return shard(x + m, "batch", "act_seq", None), (kv, aux)
    if remat:
        body = jax.checkpoint(body)
    return body(lp, x)


def _mamba_block(cfg, lp, x, state, remat: bool):
    def body(lp, x, state):
        out, new_state = mamba_fwd(cfg, lp["mamba"],
                                   rms_norm(x, lp["ln1"], cfg.norm_eps), state)
        return shard(x + out, "batch", "act_seq", None), new_state
    if remat:
        body = jax.checkpoint(body)
    return body(lp, x, state)


def _hybrid_block(cfg, lp, x, positions, state, remat: bool):
    """state: (conv, h) for recurrent layers, (k, v, slot_pos) prefix for attn
    decode, or None for full forward."""
    def body(lp, x, state):
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if "attn" in lp:
            if state is None:
                a, kv = attn_self(cfg, lp["attn"], h, positions,
                                  window=cfg.sliding_window)
            else:
                pk, pv, spos = state
                a, kv = attn_with_prefix(cfg, lp["attn"], h, positions, pk, pv,
                                         spos, window=cfg.sliding_window)
            x, new_state = x + a, kv
        else:
            out, new_state = rglru_fwd(cfg, lp["rec"], h, state)
            x = x + out
        x = x + mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return shard(x, "batch", "act_seq", None), new_state
    if remat:
        body = jax.checkpoint(body)
    return body(lp, x, state)


# ---------------------------------------------------------------------------
# full forward (train / vanilla prefill) — also the KV materialization path
# ---------------------------------------------------------------------------

def _shard_artifact_kv(kv):
    """Constrain the *collected* per-layer KV artifact (B,S,KV,hd) to
    sequence sharding. Without this the materialization output replicates on
    the model axis and the artifact alone (L x B x S x KV x hd x 2) blows the
    per-device peak (41 GiB for qwen3-14b prefill_32k — EXPERIMENTS.md §Perf).
    Only the returned copy is constrained; the attention operands are not."""
    k, v = kv
    return (shard(k, "batch", "cache_seq", None, None),
            shard(v, "batch", "cache_seq", None, None))


def forward(cfg, params, tokens, frontend=None, positions=None,
            remat: bool = False, collect_kv: bool = False,
            return_hidden: bool = False):
    """Returns (logits (B,S,V), aux_loss, artifact).

    artifact (when collect_kv): per-family materialization product —
      dense/moe/vlm: (k, v) stacked (L,B,S,KV,hd)
      ssm:           (conv_state, h) final states
      hybrid:        ((k, v) for attn layers, (conv, h) for recurrent layers)
    """
    x = embed_inputs(cfg, params, tokens, frontend)
    s = x.shape[1]
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)
    artifact = None

    if fam in ("dense", "vlm"):
        def scan_body(x, lp):
            x, kv = _dense_block(cfg, lp, x, positions, remat)
            return x, _shard_artifact_kv(kv) if collect_kv else None
        x, kvs = scan_layers(scan_body, x, params["layers"])
        artifact = kvs
    elif fam == "moe":
        pre_kvs = []
        for lp in params["prefix_layers"]:
            x, kv = _dense_block(cfg, lp, x, positions, remat)
            pre_kvs.append(_shard_artifact_kv(kv) if collect_kv else kv)
        def scan_body(carry, lp):
            x, aux = carry
            x, (kv, a) = _moe_block(cfg, lp, x, positions, remat)
            return (x, aux + a), _shard_artifact_kv(kv) if collect_kv else None
        (x, aux_total), kvs = scan_layers(scan_body, (x, aux_total),
                                           params["layers"])
        if collect_kv:
            if pre_kvs:
                pk = jnp.stack([kv[0] for kv in pre_kvs])
                pv = jnp.stack([kv[1] for kv in pre_kvs])
                artifact = (jnp.concatenate([pk, kvs[0]], axis=0),
                            jnp.concatenate([pv, kvs[1]], axis=0))
            else:
                artifact = kvs
    elif fam == "ssm":
        def scan_body(x, lp):
            x, st = _mamba_block(cfg, lp, x, None, remat)
            return x, st if collect_kv else None
        x, states = scan_layers(scan_body, x, params["layers"])
        artifact = states
    elif fam == "hybrid":
        attn_kvs, rec_states = [], []
        for lp in params["layers"]:
            x, st = _hybrid_block(cfg, lp, x, positions, None, remat)
            if collect_kv:
                if "attn" in lp:
                    attn_kvs.append(_shard_artifact_kv(st))
                else:
                    rec_states.append(st)
        if collect_kv:
            kv = (jnp.stack([a[0] for a in attn_kvs]),
                  jnp.stack([a[1] for a in attn_kvs]))
            rec = (jnp.stack([r[0] for r in rec_states]),
                   jnp.stack([r[1] for r in rec_states]))
            artifact = (kv, rec)
    else:
        raise ValueError(f"forward: unsupported family {fam}")

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_hidden:
        return x, aux_total, artifact
    return unembed(cfg, params, x), aux_total, artifact


def prefill(cfg, params, tokens, frontend=None, positions=None):
    """MatKV write path: forward + the materialization artifact."""
    logits, aux, artifact = forward(cfg, params, tokens, frontend,
                                    positions, collect_kv=True)
    return logits, artifact


# ---------------------------------------------------------------------------
# decode (Sq tokens against a cache)
# ---------------------------------------------------------------------------

def _decode_concat() -> bool:
    """REPRO_DECODE_CONCAT=1 restores the concat-then-attend decode lowering
    (the pre-hillclimb baseline, kept for A/B: concatenating the new token
    onto a sequence-sharded cache forces GSPMD to all-gather the whole KV
    cache every step — see EXPERIMENTS.md §Perf)."""
    import os
    return os.environ.get("REPRO_DECODE_CONCAT") == "1"


def decode_step(cfg, params, cache, tokens, positions=None):
    """tokens (B,Sq) against cache; returns (logits (B,Sq,V), new cache).

    ``positions`` overrides RoPE positions (MatKV restart-mode sub-prefill);
    attention-order masking always uses cache slot positions + global order.
    """
    x = embed_inputs(cfg, params, tokens)
    sq = x.shape[1]
    order_pos = cache.length + jnp.arange(sq, dtype=jnp.int32)
    if positions is None:
        positions = order_pos
    fam = cfg.family
    concat = _decode_concat()
    if fam in ("dense", "vlm", "moe") and not concat:
        # write-then-attend: update slot_pos once (same slots for all
        # layers), then each layer writes its new KV into its buffer slice
        # and attends over the buffer only. No concat => the cache keeps its
        # sequence sharding and decode emits no cache-sized collectives.
        start = (cache.length % cache.buf_size).astype(jnp.int32)
        spos = jax.lax.dynamic_update_slice(cache.slot_pos,
                                            order_pos.astype(jnp.int32),
                                            (start,))

    if fam in ("dense", "vlm"):
        if concat:
            def scan_body(x, xs):
                lp, pk, pv = xs
                a, kv = attn_with_prefix(cfg, lp["attn"],
                                         rms_norm(x, lp["ln1"], cfg.norm_eps),
                                         positions, pk, pv, cache.slot_pos)
                x = x + a
                x = x + mlp(cfg, lp["mlp"],
                            rms_norm(x, lp["ln2"], cfg.norm_eps))
                return x, kv
            x, kvs = scan_layers(scan_body, x,
                                 (params["layers"], cache.k, cache.v))
            k, v, spos, length = write_kv(cache.k, cache.v, cache.slot_pos,
                                          cache.length, kvs[0], kvs[1],
                                          positions=order_pos)
            new_cache = AttnCache(k=k, v=v, slot_pos=spos, length=length)
        else:
            def scan_body(x, xs):
                lp, pk, pv = xs
                a, pk, pv = attn_into_cache(
                    cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                    positions, order_pos, pk, pv, spos, start)
                x = x + a
                x = x + mlp(cfg, lp["mlp"],
                            rms_norm(x, lp["ln2"], cfg.norm_eps))
                return x, (pk, pv)
            x, (k, v) = scan_layers(scan_body, x,
                                    (params["layers"], cache.k, cache.v))
            new_cache = AttnCache(k=k, v=v, slot_pos=spos,
                                  length=cache.length + sq)
    elif fam == "moe":
        n_pre = cfg.first_dense_layers
        if concat:
            new_ks, new_vs = [], []
            for i, lp in enumerate(params["prefix_layers"]):
                a, kv = attn_with_prefix(cfg, lp["attn"],
                                         rms_norm(x, lp["ln1"], cfg.norm_eps),
                                         positions, cache.k[i], cache.v[i],
                                         cache.slot_pos)
                x = x + a
                x = x + mlp(cfg, lp["mlp"],
                            rms_norm(x, lp["ln2"], cfg.norm_eps))
                new_ks.append(kv[0]); new_vs.append(kv[1])
            def scan_body(x, xs):
                lp, pk, pv = xs
                a, kv = attn_with_prefix(cfg, lp["attn"],
                                         rms_norm(x, lp["ln1"], cfg.norm_eps),
                                         positions, pk, pv, cache.slot_pos)
                x = x + a
                m, _ = moe_ffn(cfg, lp["moe"],
                               rms_norm(x, lp["ln2"], cfg.norm_eps))
                return x + m, kv
            x, kvs = scan_layers(
                scan_body, x,
                (params["layers"], cache.k[n_pre:], cache.v[n_pre:]))
            k_new = kvs[0] if not new_ks else jnp.concatenate(
                [jnp.stack(new_ks), kvs[0]], axis=0)
            v_new = kvs[1] if not new_vs else jnp.concatenate(
                [jnp.stack(new_vs), kvs[1]], axis=0)
            k, v, spos, length = write_kv(cache.k, cache.v, cache.slot_pos,
                                          cache.length, k_new, v_new,
                                          positions=order_pos)
            new_cache = AttnCache(k=k, v=v, slot_pos=spos, length=length)
        else:
            new_ks, new_vs = [], []
            for i, lp in enumerate(params["prefix_layers"]):
                a, pk_i, pv_i = attn_into_cache(
                    cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                    positions, order_pos, cache.k[i], cache.v[i], spos, start)
                x = x + a
                x = x + mlp(cfg, lp["mlp"],
                            rms_norm(x, lp["ln2"], cfg.norm_eps))
                new_ks.append(pk_i); new_vs.append(pv_i)
            def scan_body(x, xs):
                lp, pk, pv = xs
                a, pk, pv = attn_into_cache(
                    cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                    positions, order_pos, pk, pv, spos, start)
                x = x + a
                m, _ = moe_ffn(cfg, lp["moe"],
                               rms_norm(x, lp["ln2"], cfg.norm_eps))
                return x + m, (pk, pv)
            x, (ks, vs) = scan_layers(
                scan_body, x,
                (params["layers"], cache.k[n_pre:], cache.v[n_pre:]))
            k = ks if not new_ks else jnp.concatenate(
                [jnp.stack(new_ks), ks], axis=0)
            v = vs if not new_vs else jnp.concatenate(
                [jnp.stack(new_vs), vs], axis=0)
            new_cache = AttnCache(k=k, v=v, slot_pos=spos,
                                  length=cache.length + sq)
    elif fam == "ssm":
        def scan_body(x, xs):
            lp, conv, h = xs
            x, (conv, h) = _mamba_block(cfg, lp, x, (conv, h), remat=False)
            return x, (conv, h)
        x, (convs, hs) = scan_layers(scan_body, x,
                                      (params["layers"], cache.conv, cache.h))
        new_cache = SSMCache(conv=convs, h=hs, length=cache.length + sq)
    elif fam == "hybrid":
        i_attn = i_rec = 0
        new_k, new_v, new_conv, new_h = [], [], [], []
        for lp in params["layers"]:
            if "attn" in lp:
                st = (cache.k[i_attn], cache.v[i_attn], cache.slot_pos)
                x, kv = _hybrid_block(cfg, lp, x, positions, st, remat=False)
                new_k.append(kv[0]); new_v.append(kv[1]); i_attn += 1
            else:
                st = (cache.conv[i_rec], cache.h[i_rec])
                x, st = _hybrid_block(cfg, lp, x, positions, st, remat=False)
                new_conv.append(st[0]); new_h.append(st[1]); i_rec += 1
        k, v, spos, length = write_kv(cache.k, cache.v, cache.slot_pos,
                                      cache.length,
                                      jnp.stack(new_k), jnp.stack(new_v),
                                      positions=order_pos)
        new_cache = HybridCache(k=k, v=v, slot_pos=spos,
                                conv=jnp.stack(new_conv), h=jnp.stack(new_h),
                                length=length)
    else:
        raise ValueError(f"decode_step: unsupported family {fam}")

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), new_cache


def decode_step_rows(cfg, params, cache: RowAttnCache, tokens, positions=None):
    """Row-slotted decode: tokens (B,Sq) against a ``RowAttnCache`` whose rows
    sit at independent lengths/slot maps (continuous batching). Attention-KV
    families only — recurrent state composition has no slot structure to
    stagger (DESIGN.md §4).

    ``positions`` (B,Sq) overrides RoPE positions (MatKV restart-mode
    sub-prefill); order masking always runs against each row's slot positions.
    Returns (logits (B,Sq,V), new cache).
    """
    fam = cfg.family
    if fam not in ("dense", "vlm", "moe"):
        raise ValueError(f"decode_step_rows: attention-KV families only, "
                         f"got {fam}")
    x = embed_inputs(cfg, params, tokens)
    sq = x.shape[1]
    order_pos = cache.length[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]
    if positions is None:
        positions = order_pos
    start = (cache.length % cache.buf_size).astype(jnp.int32)      # (B,)
    spos = jax.vmap(
        lambda sp, op, st: jax.lax.dynamic_update_slice(
            sp, op.astype(jnp.int32), (st,)))(
        cache.slot_pos, order_pos, start)

    def attend(lp, x, pk, pv):
        a, pk, pv = attn_into_cache_rows(
            cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
            positions, order_pos, pk, pv, spos, start)
        return x + a, pk, pv

    if fam in ("dense", "vlm"):
        def scan_body(x, xs):
            lp, pk, pv = xs
            x, pk, pv = attend(lp, x, pk, pv)
            x = x + mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x, (pk, pv)
        x, (k, v) = scan_layers(scan_body, x,
                                (params["layers"], cache.k, cache.v))
    else:  # moe
        n_pre = cfg.first_dense_layers
        new_ks, new_vs = [], []
        for i, lp in enumerate(params["prefix_layers"]):
            x, pk_i, pv_i = attend(lp, x, cache.k[i], cache.v[i])
            x = x + mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            new_ks.append(pk_i); new_vs.append(pv_i)
        def scan_body(x, xs):
            lp, pk, pv = xs
            x, pk, pv = attend(lp, x, pk, pv)
            m, _ = moe_ffn(cfg, lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x + m, (pk, pv)
        x, (ks, vs) = scan_layers(
            scan_body, x, (params["layers"], cache.k[n_pre:], cache.v[n_pre:]))
        k = ks if not new_ks else jnp.concatenate([jnp.stack(new_ks), ks],
                                                  axis=0)
        v = vs if not new_vs else jnp.concatenate([jnp.stack(new_vs), vs],
                                                  axis=0)

    new_cache = RowAttnCache(k=k, v=v, slot_pos=spos,
                             length=cache.length + sq)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), new_cache


def streaming_prompt_q0(cfg, params, tokens, n_doc):
    """Layer-0 prompt queries for a streamed admission (DESIGN.md §16).

    embed -> ln1 -> Wq (-> q-norm) -> RoPE at the prompt's final order
    positions ``n_doc + 0..Sq-1`` — exactly what layer 0 of
    ``decode_step_rows`` computes for these tokens, but computable the
    moment a request is accepted: it depends only on the prompt and the
    (known) composed-prefix length, never on the document KV still in
    flight. The result seeds the ``StreamingPrefix`` carry.

    tokens (B,Sq) int32, n_doc (B,) int32. Returns q0 (B,Sq,H,hd).
    """
    from repro.models.rope import apply_rope, rope_angles
    x = embed_inputs(cfg, params, tokens)
    sq = x.shape[1]
    lp0 = jax.tree.map(lambda a: a[0], params["layers"])
    q = project_q(cfg, lp0["attn"], rms_norm(x, lp0["ln1"], cfg.norm_eps))
    if cfg.use_rope:
        pos = n_doc[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]
        cos, sin = rope_angles(pos, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
    return q


def decode_step_rows_streamed(cfg, params, cache: RowAttnCache, tokens,
                              q0, m, l, acc):
    """Finalize a streamed admission: ``decode_step_rows`` with layer 0's
    prompt-over-document attention replaced by the already-folded streaming
    carry (streaming admission, DESIGN.md §16).

    ``(q0, m, l, acc)`` is the layer-0 carry, folded over the *full*
    document prefix in retrieval order while pages were still landing.
    Layer 0 here only projects/writes the prompt's own K/V, folds the
    prompt's causal self-attention block into the carry, and runs the
    finalize epilogue — using ``q0`` itself (the array the carry was
    computed with) so document and prompt scores share bit-identical
    queries. Layers 1.. run the standard write-then-attend; they need the
    full resident prefix, which is exactly why only layer 0 streams.

    Dense/vlm full-attention only (a sliding window would mask document
    slots the carry already folded). Returns (logits, new_cache) — the
    ``decode_step_rows`` contract.
    """
    fam = cfg.family
    if fam not in ("dense", "vlm") or cfg.sliding_window:
        raise ValueError("decode_step_rows_streamed: dense/vlm "
                         "full-attention families only")
    x = embed_inputs(cfg, params, tokens)
    sq = x.shape[1]
    order_pos = cache.length[:, None] + jnp.arange(sq, dtype=jnp.int32)[None]
    positions = order_pos
    start = (cache.length % cache.buf_size).astype(jnp.int32)      # (B,)
    spos = jax.vmap(
        lambda sp, op, st: jax.lax.dynamic_update_slice(
            sp, op.astype(jnp.int32), (st,)))(
        cache.slot_pos, order_pos, start)

    # ---- layer 0: fold the prompt block into the carry, then finalize ----
    lp0 = jax.tree.map(lambda a: a[0], params["layers"])
    k_new, v_new = project_kv(cfg, lp0["attn"],
                              rms_norm(x, lp0["ln1"], cfg.norm_eps))
    if cfg.use_rope:
        from repro.models.rope import apply_rope, rope_angles
        cos, sin = rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        k_new = apply_rope(k_new, cos, sin)
    kc = k_new.astype(cache.k.dtype)       # the cache write's cast — fold
    vc = v_new.astype(cache.v.dtype)       # what the all-at-once path reads

    def write(buf, new, st):
        zero = jnp.zeros((), jnp.int32)
        return jax.lax.dynamic_update_slice(buf, new, (st, zero, zero))

    pk0 = jax.vmap(write)(cache.k[0], kc, start)
    pv0 = jax.vmap(write)(cache.v[0], vc, start)
    b, _, n_heads, hd = q0.shape
    kvh = cfg.num_kv_heads
    qr = q0.reshape(b, sq, kvh, n_heads // kvh, hd)
    pmask = jnp.broadcast_to(
        jnp.arange(sq)[None, :, None] >= jnp.arange(sq)[None, None, :],
        (b, sq, sq))
    m, l, acc = carry_block(m, l, acc, qr, kc, vc, pmask)
    a0 = carry_finalize(m, l, acc, q0.dtype)
    a0 = a0.reshape(b, sq, cfg.q_dim) @ lp0["attn"]["wo"]
    x = x + a0
    x = x + mlp(cfg, lp0["mlp"], rms_norm(x, lp0["ln2"], cfg.norm_eps))

    # ---- layers 1..L-1: standard write-then-attend over the dense view ---
    def scan_body(x, xs):
        lp, pk, pv = xs
        a, pk, pv = attn_into_cache_rows(
            cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
            positions, order_pos, pk, pv, spos, start)
        x = x + a
        x = x + mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
        return x, (pk, pv)
    rest = jax.tree.map(lambda a: a[1:], params["layers"])
    x, (ks, vs) = scan_layers(scan_body, x, (rest, cache.k[1:], cache.v[1:]))

    new_cache = RowAttnCache(
        k=jnp.concatenate([pk0[None], ks], axis=0),
        v=jnp.concatenate([pv0[None], vs], axis=0),
        slot_pos=spos, length=cache.length + sq)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), new_cache


def decode_step_rows_fused(cfg, params, pool_k, pool_v, k_scale, v_scale,
                           length, tokens, tables, lens, totals, *,
                           buf_size: int, block_size: int,
                           interpret: bool = True, mesh=None,
                           tp_axis: str = "model"):
    """Fused paged decode: one ``paged_decode_fused`` launch per layer,
    straight off the pool block tensors — the kernel twin of
    ``decode_step_rows`` over a gathered ``PagedRowCache`` view.

    ``pool_k/v (L, n_slots, KV, hd)`` are the pool's flat block tensors
    (+ ``k/v_scale (L, n_slots, KV)`` for an int8 pool); ``tables``/``lens``
    (B, n_max) and ``totals`` (B,) are the host-built per-row block runs
    (``PagedRowCache.step_tables``). Single-token steps only (Sq=1 — the
    scheduler's decode cadence; prompt sub-prefills keep the dense row path),
    and no sliding window (the fused mask is pure ragged-length).

    Returns (logits (B,1,V), k_new (L,B,KV,hd), v_new (L,B,KV,hd)) — the
    per-layer new-token K/V in the pool view dtype, which the caller persists
    through the page table (the one remaining token-granularity write).
    """
    fam = cfg.family
    if fam not in ("dense", "vlm", "moe"):
        raise ValueError(f"decode_step_rows_fused: attention-KV families "
                         f"only, got {fam}")
    if tokens.shape[1] != 1:
        raise ValueError("decode_step_rows_fused: single-token steps only "
                         f"(got Sq={tokens.shape[1]}); prompt sub-prefills "
                         "run the dense row path")
    if cfg.sliding_window is not None:
        raise ValueError("decode_step_rows_fused: sliding_window is not "
                         "expressible in the ragged-length mask; serve via "
                         "the three-phase path")
    x = embed_inputs(cfg, params, tokens)
    positions = length[:, None].astype(jnp.int32)      # (B,1) order positions
    n_layers, n_slots, kvh, hd = pool_k.shape
    n_blocks = n_slots // block_size
    pk = pool_k.reshape(n_layers, n_blocks, block_size, kvh, hd)
    pv = pool_v.reshape(n_layers, n_blocks, block_size, kvh, hd)
    if k_scale is None:
        ks = vs = None
        view_dt = pool_k.dtype
    else:
        ks = k_scale.reshape(n_layers, n_blocks, block_size, kvh)
        vs = v_scale.reshape(n_layers, n_blocks, block_size, kvh)
        view_dt = jnp.dtype(cfg.activation_dtype)

    def attend(lp, x, pkb, pvb, ksb, vsb):
        a, kn, vn = attn_paged_fused(
            cfg, lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps), positions,
            pkb, pvb, ksb, vsb, tables, lens, totals, buf_size=buf_size,
            view_dtype=view_dt, interpret=interpret, mesh=mesh,
            tp_axis=tp_axis)
        return x + a, kn, vn

    if fam in ("dense", "vlm"):
        def scan_body(x, xs):
            if ks is None:
                lp, pkb, pvb = xs
                ksb = vsb = None
            else:
                lp, pkb, pvb, ksb, vsb = xs
            x, kn, vn = attend(lp, x, pkb, pvb, ksb, vsb)
            x = x + mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x, (kn, vn)
        xs = ((params["layers"], pk, pv) if ks is None
              else (params["layers"], pk, pv, ks, vs))
        x, (k_new, v_new) = scan_layers(scan_body, x, xs)
    else:  # moe
        n_pre = cfg.first_dense_layers
        new_ks, new_vs = [], []
        for i, lp in enumerate(params["prefix_layers"]):
            x, kn, vn = attend(lp, x, pk[i], pv[i],
                               None if ks is None else ks[i],
                               None if vs is None else vs[i])
            x = x + mlp(cfg, lp["mlp"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            new_ks.append(kn); new_vs.append(vn)
        def scan_body(x, xs):
            if ks is None:
                lp, pkb, pvb = xs
                ksb = vsb = None
            else:
                lp, pkb, pvb, ksb, vsb = xs
            x, kn, vn = attend(lp, x, pkb, pvb, ksb, vsb)
            m, _ = moe_ffn(cfg, lp["moe"], rms_norm(x, lp["ln2"], cfg.norm_eps))
            return x + m, (kn, vn)
        xs = ((params["layers"], pk[n_pre:], pv[n_pre:]) if ks is None
              else (params["layers"], pk[n_pre:], pv[n_pre:],
                    ks[n_pre:], vs[n_pre:]))
        x, (kns, vns) = scan_layers(scan_body, x, xs)
        k_new = kns if not new_ks else jnp.concatenate(
            [jnp.stack(new_ks), kns], axis=0)
        v_new = vns if not new_vs else jnp.concatenate(
            [jnp.stack(new_vs), vns], axis=0)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return unembed(cfg, params, x), k_new, v_new
