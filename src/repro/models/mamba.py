"""Mamba-1 block (Falcon-Mamba architecture): causal depthwise conv + selective
SSM scan, gated output. Functional, with explicit state in/out so the serving
engine (and MatKV's prefix-state materialization) can checkpoint the recurrence.

State carried between calls:
  conv_state (B, ssm_conv-1, d_inner) — last inputs feeding the causal conv
  ssm_state  (B, d_inner, ssm_state)  — the SSM hidden state h

For MatKV, ``mamba_fwd(..., return_state=True)``'s final state is the
materialized artifact (exact for prefix reuse; see DESIGN.md §4).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard  # noqa: F401  (used in scan constraints)


def _dense(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * fan_in ** -0.5).astype(dtype)


def init_mamba(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    d, din, st, dtr = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_dt_rank
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a_init = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (din, 1))
    return {
        "in_proj": _dense(ks[0], (d, 2 * din), d, dt),
        "conv_w": _dense(ks[1], (cfg.ssm_conv, din), cfg.ssm_conv, dt),
        "conv_b": jnp.zeros((din,), dt),
        "x_proj": _dense(ks[2], (din, dtr + 2 * st), din, dt),
        "dt_proj_w": _dense(ks[3], (dtr, din), dtr, dt),
        "dt_proj_b": jnp.log(jnp.expm1(
            jnp.clip(jax.random.uniform(ks[4], (din,)) * 0.099 + 0.001,
                     1e-4, None))).astype(dt),  # softplus^-1 of dt in [1e-3, 0.1]
        "A_log": jnp.log(a_init),               # (din, st) f32
        "D": jnp.ones((din,), jnp.float32),
        "out_proj": _dense(ks[5], (din, d), din, dt),
    }


def _ssm_params(cfg, p, x):
    """x (B,S,din) -> dt (B,S,din), Bmat (B,S,st), Cmat (B,S,st) in f32."""
    dbl = x @ p["x_proj"]
    dtr, st = cfg.ssm_dt_rank, cfg.ssm_state
    dt_in, bmat, cmat = jnp.split(dbl, [dtr, dtr + st], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj_w"] + p["dt_proj_b"]).astype(jnp.float32)
    return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32)


def _causal_conv(p, x, conv_state):
    """Depthwise causal conv width W over x (B,S,din) given (B,W-1,din) history."""
    w = p["conv_w"].astype(jnp.float32)          # (W, din)
    xin = jnp.concatenate([conv_state.astype(jnp.float32),
                           x.astype(jnp.float32)], axis=1)
    width = w.shape[0]
    out = sum(xin[:, i:i + x.shape[1], :] * w[i] for i in range(width))
    new_state = xin[:, -(width - 1):, :].astype(conv_state.dtype)
    return (out + p["conv_b"].astype(jnp.float32)), new_state


def _pick_chunk(s: int, target: int = 64) -> int:
    for c in (target, 32, 16, 8, 4, 2, 1):
        if s % c == 0:
            return c
    return 1


def selective_scan(x, dt, bmat, cmat, a_log, d_skip, h0, chunk: int = 64):
    """The Mamba selective scan: chunked two-level lax.scan with remat.

    x (B,S,din) f32, dt (B,S,din), bmat/cmat (B,S,st), a_log (din,st),
    h0 (B,din,st). Returns (y (B,S,din), h_final).

    The inner chunk is wrapped in jax.checkpoint: AD saves only the hidden
    state at chunk boundaries (S/chunk states) instead of every per-step
    intermediate — at falcon-mamba train_4k scale this is the difference
    between ~51 GiB and ~2 GiB of per-device scan residuals. The Pallas kernel
    in repro.kernels.mamba_scan implements exactly this chunking for TPU VMEM.
    """
    a = -jnp.exp(a_log)                                           # (din, st)
    s = x.shape[1]
    chunk = _pick_chunk(s, chunk)
    nc = s // chunk

    def step(h, inp):
        xt, dtt, bt, ct = inp                                     # (B,din),(B,din),(B,st)
        da = jnp.exp(dtt[..., None] * a)                          # (B,din,st)
        db = dtt[..., None] * bt[:, None, :]                      # (B,din,st)
        h = da * h + db * xt[..., None]
        y = jnp.einsum("bds,bs->bd", h, ct)
        return h, y

    @jax.checkpoint
    def chunk_body(h, xs_chunk):
        return jax.lax.scan(step, h, xs_chunk)

    def to_chunks(t, channel_logical):  # (B,S,...) -> (nc, chunk, B, ...)
        moved = jnp.moveaxis(t, 1, 0)                             # (S,B,...)
        out = moved.reshape((nc, chunk) + moved.shape[1:])
        return shard(out, None, None, "batch", channel_logical)

    xs = (to_chunks(x, "inner"), to_chunks(dt, "inner"),
          to_chunks(bmat, None), to_chunks(cmat, None))
    h0 = shard(h0, "batch", "inner", None)
    h_final, ys = jax.lax.scan(chunk_body, h0, xs)                # ys (nc,chunk,B,din)
    y = jnp.moveaxis(ys.reshape((s,) + ys.shape[2:]), 0, 1)
    return y + d_skip * x, h_final


def mamba_fwd(cfg, p, x, state: Optional[Tuple] = None):
    """Full-sequence forward. x (B,S,D). Returns (out, (conv_state, ssm_state))."""
    b, s, _ = x.shape
    din = cfg.d_inner
    if state is None:
        conv_state = jnp.zeros((b, cfg.ssm_conv - 1, din), x.dtype)
        h0 = jnp.zeros((b, din, cfg.ssm_state), jnp.float32)
    else:
        conv_state, h0 = state
        h0 = h0.astype(jnp.float32)

    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = shard(xin, "batch", None, "inner")
    conv_out, conv_state = _causal_conv(p, xin, conv_state)
    xc = jax.nn.silu(conv_out)                                    # (B,S,din) f32
    dt, bmat, cmat = _ssm_params(cfg, p, xc.astype(x.dtype))
    y, h = selective_scan(xc, dt, bmat, cmat, p["A_log"],
                          p["D"][None, None, :], h0)
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, (conv_state, h)


def mamba_step(cfg, p, x, conv_state, ssm_state):
    """Single-token decode. x (B,1,D). Returns (out, conv_state, ssm_state)."""
    out, (cs, h) = mamba_fwd(cfg, p, x, (conv_state, ssm_state))
    return out, cs, h
