"""Decode-time state containers (registered pytrees).

Slot-position convention: every attention cache carries ``slot_pos`` (S_buf,)
int32 — the *attention-order* global position of the token in each buffer slot,
-1 for empty slots. Masks are derived purely from positions, which makes ring
buffers (sliding-window archs) and MatKV composed prefixes use one mechanism.
RoPE angles are baked into K at write time and are independent of slot_pos
(that's how the paper's "restarted positions" mode coexists with correct
causal masking).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp


def _register(cls, data_fields):
    jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=[])
    return cls


@dataclass
class AttnCache:
    k: jnp.ndarray          # (L, B, S_buf, KV, hd)
    v: jnp.ndarray          # (L, B, S_buf, KV, hd)
    slot_pos: jnp.ndarray   # (S_buf,) int32, -1 = empty
    length: jnp.ndarray     # scalar int32: total tokens seen

    @property
    def buf_size(self) -> int:
        return self.k.shape[2]


_register(AttnCache, ["k", "v", "slot_pos", "length"])


@dataclass
class RowAttnCache:
    """Row-slotted attention cache: each batch row owns its slot map.

    Unlike ``AttnCache`` (one ``slot_pos``/``length`` shared by every row —
    fixed-geometry batches only), rows here carry independent composed-prefix
    lengths and decode offsets, so a continuous-batching scheduler can admit
    and evict rows out of phase: a freshly backfilled row at position 3 decodes
    next to a row 40 tokens into its answer, and rows with different ``top_k``
    or a short final chunk just leave their tail slots at -1.
    """
    k: jnp.ndarray          # (L, B, S_buf, KV, hd)
    v: jnp.ndarray          # (L, B, S_buf, KV, hd)
    slot_pos: jnp.ndarray   # (B, S_buf) int32, -1 = empty
    length: jnp.ndarray     # (B,) int32: per-row tokens seen

    @property
    def buf_size(self) -> int:
        return self.k.shape[2]

    @property
    def batch(self) -> int:
        return self.k.shape[1]


_register(RowAttnCache, ["k", "v", "slot_pos", "length"])


@dataclass
class SSMCache:
    conv: jnp.ndarray       # (L, B, conv_w-1, d_inner)
    h: jnp.ndarray          # (L, B, d_inner, ssm_state) f32
    length: jnp.ndarray     # scalar int32


_register(SSMCache, ["conv", "h", "length"])


@dataclass
class HybridCache:
    """Separate stores for attention layers and recurrent layers."""
    k: jnp.ndarray          # (L_attn, B, W_buf, KV, hd)
    v: jnp.ndarray
    slot_pos: jnp.ndarray   # (W_buf,)
    conv: jnp.ndarray       # (L_rec, B, 3, width)
    h: jnp.ndarray          # (L_rec, B, width) f32
    length: jnp.ndarray

    @property
    def buf_size(self) -> int:
        return self.k.shape[2]


_register(HybridCache, ["k", "v", "slot_pos", "conv", "h", "length"])


@dataclass
class EncDecCache:
    """Whisper: cross-KV is the materialized artifact; self-cache is decoder's."""
    cross_k: jnp.ndarray    # (L_dec, B, S_enc, KV, hd)
    cross_v: jnp.ndarray
    k: jnp.ndarray          # (L_dec, B, S_buf, KV, hd) decoder self-attention
    v: jnp.ndarray
    slot_pos: jnp.ndarray
    length: jnp.ndarray

    @property
    def buf_size(self) -> int:
        return self.k.shape[2]


_register(EncDecCache, ["cross_k", "cross_v", "k", "v", "slot_pos", "length"])


# ---------------------------------------------------------------------------
# constructors
# ---------------------------------------------------------------------------

def _buf(cfg, seq_len: int) -> int:
    """Attention buffer size: the window for sliding-window archs, else seq."""
    if cfg.sliding_window is not None:
        return min(seq_len, cfg.sliding_window)
    return seq_len


def init_attn_cache(cfg, batch: int, seq_len: int, n_layers: Optional[int] = None,
                    dtype=None) -> AttnCache:
    n_layers = n_layers or cfg.num_layers
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    buf = _buf(cfg, seq_len)
    shape = (n_layers, batch, buf, cfg.num_kv_heads, cfg.head_dim)
    return AttnCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        slot_pos=jnp.full((buf,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32))


def init_row_attn_cache(cfg, batch: int, buf_size: int,
                        n_layers: Optional[int] = None,
                        dtype=None) -> RowAttnCache:
    """Empty row-slotted cache. ``buf_size`` is taken literally (the scheduler
    sizes it for the worst-case row, not per sequence)."""
    n_layers = n_layers or cfg.num_layers
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    shape = (n_layers, batch, buf_size, cfg.num_kv_heads, cfg.head_dim)
    return RowAttnCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        slot_pos=jnp.full((batch, buf_size), -1, jnp.int32),
        length=jnp.zeros((batch,), jnp.int32))


def init_ssm_cache(cfg, batch: int, dtype=None) -> SSMCache:
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    return SSMCache(
        conv=jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        h=jnp.zeros((cfg.num_layers, batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        length=jnp.zeros((), jnp.int32))


def init_hybrid_cache(cfg, batch: int, seq_len: int, dtype=None) -> HybridCache:
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    kinds = cfg.layer_kinds
    l_attn = sum(1 for k in kinds if k == "attention")
    l_rec = len(kinds) - l_attn
    buf = _buf(cfg, seq_len)
    kv_shape = (l_attn, batch, buf, cfg.num_kv_heads, cfg.head_dim)
    return HybridCache(
        k=jnp.zeros(kv_shape, dtype), v=jnp.zeros(kv_shape, dtype),
        slot_pos=jnp.full((buf,), -1, jnp.int32),
        conv=jnp.zeros((l_rec, batch, 3, cfg.rglru_width), dtype),
        h=jnp.zeros((l_rec, batch, cfg.rglru_width), jnp.float32),
        length=jnp.zeros((), jnp.int32))


def init_encdec_cache(cfg, batch: int, enc_len: int, dec_buf: int,
                      dtype=None) -> EncDecCache:
    dtype = dtype or jnp.dtype(cfg.activation_dtype)
    cross_shape = (cfg.dec_layers, batch, enc_len, cfg.num_kv_heads, cfg.head_dim)
    self_shape = (cfg.dec_layers, batch, dec_buf, cfg.num_kv_heads, cfg.head_dim)
    return EncDecCache(
        cross_k=jnp.zeros(cross_shape, dtype), cross_v=jnp.zeros(cross_shape, dtype),
        k=jnp.zeros(self_shape, dtype), v=jnp.zeros(self_shape, dtype),
        slot_pos=jnp.full((dec_buf,), -1, jnp.int32),
        length=jnp.zeros((), jnp.int32))


# ---------------------------------------------------------------------------
# ring/bulk writes
# ---------------------------------------------------------------------------

def write_kv(k_buf, v_buf, slot_pos, length, k_new, v_new, positions=None):
    """Write k_new/v_new (L,B,Sq,KV,hd) into buffers at slot ``length % buf``.

    Bulk writes (prefill into an empty cache) must not wrap; decode writes are
    Sq=1 so they never wrap. ``positions`` overrides the attention-order
    positions recorded for the new slots (defaults to length + arange(Sq)).
    Returns (k_buf, v_buf, slot_pos, new_length).
    """
    sq = k_new.shape[2]
    buf = k_buf.shape[2]
    start = (length % buf).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    k_buf = jax.lax.dynamic_update_slice(
        k_buf, k_new.astype(k_buf.dtype), (zero, zero, start, zero, zero))
    v_buf = jax.lax.dynamic_update_slice(
        v_buf, v_new.astype(v_buf.dtype), (zero, zero, start, zero, zero))
    if positions is None:
        positions = length + jnp.arange(sq, dtype=jnp.int32)
    slot_pos = jax.lax.dynamic_update_slice(
        slot_pos, positions.astype(jnp.int32), (start,))
    return k_buf, v_buf, slot_pos, length + sq


def insert_cache_row(cache: RowAttnCache, row_idx: int,
                     row: RowAttnCache) -> RowAttnCache:
    """Overwrite batch row ``row_idx`` of a row-slotted cache with the single
    row of ``row`` (batch=1) — the continuous scheduler's admit/backfill step.
    Buffer sizes must match; the whole row (including stale slots from the
    evicted occupant) is replaced.
    """
    if row.buf_size != cache.buf_size:
        raise ValueError(f"insert_cache_row: buf_size mismatch "
                         f"{row.buf_size} != {cache.buf_size}")
    return RowAttnCache(
        k=cache.k.at[:, row_idx].set(row.k[:, 0]),
        v=cache.v.at[:, row_idx].set(row.v[:, 0]),
        slot_pos=cache.slot_pos.at[row_idx].set(row.slot_pos[0]),
        length=cache.length.at[row_idx].set(row.length[0]))
