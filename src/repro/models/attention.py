"""GQA attention with RoPE / qk-norm / sliding window / prefix (MatKV) support.

Two compute paths:

* ``flash_attention`` — blockwise chunked-q attention with a custom VJP that
  recomputes scores per block (flash-attention backward). Never materializes the
  full (Sq, Sk) score matrix; this is what makes prefill_32k / train_4k fit HBM.
  The Pallas kernel in ``repro.kernels.flash_prefill`` is its TPU twin; this jnp
  version doubles as the kernel's oracle and as the portable fallback.
* plain SDPA for tiny problems (decode, smoke tests) via the same entry point —
  a single q block degenerates to ordinary attention.

Masking is expressed with *global position arrays* for q and k. This one
mechanism covers causal training masks, sliding windows, MatKV composed
prefixes (documents occupy slots [0, P), query continues after), and ring
buffers (slot positions arbitrary, invalid slots = -1).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import current_mesh, shard
from repro.models.norms import rms_norm
from repro.models.rope import rope_q_k
from repro.models.scan_utils import scan_layers

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_attention(cfg, key, cross: bool = False):
    """Attention params. ``cross=True`` adds no extra params; K/V projections are
    used against the encoder sequence instead (whisper cross-attention)."""
    ks = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.param_dtype)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim

    def dense(k, fan_in, fan_out):
        return (jax.random.normal(k, (fan_in, fan_out), jnp.float32)
                * fan_in ** -0.5).astype(dt)

    p = {
        "wq": dense(ks[0], d, qd),
        "wk": dense(ks[1], d, kvd),
        "wv": dense(ks[2], d, kvd),
        "wo": dense(ks[3], qd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dt)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dt)
    return p


def project_q(cfg, p, x):
    b, s, _ = x.shape
    q = (x @ p["wq"]).reshape(b, s, cfg.num_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
    return q


def project_kv(cfg, p, x):
    b, s, _ = x.shape
    k = (x @ p["wk"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = (x @ p["wv"]).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return k, v


# ---------------------------------------------------------------------------
# position-based masking
# ---------------------------------------------------------------------------

def position_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                  window: Optional[int], causal: bool) -> jnp.ndarray:
    """(Sq, Sk) bool mask from global positions. k slots with pos < 0 invalid."""
    qp = q_pos[:, None].astype(jnp.int32)
    kp = k_pos[None, :].astype(jnp.int32)
    m = kp >= 0
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m


def position_mask_rows(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                       window: Optional[int], causal: bool) -> jnp.ndarray:
    """Per-row masks: q_pos (B,Sq), k_pos (B,Sk) -> (B,Sq,Sk). Same semantics
    as ``position_mask`` but every batch row carries its own position maps
    (row-slotted caches: rows are at different decode offsets)."""
    qp = q_pos[:, :, None].astype(jnp.int32)
    kp = k_pos[:, None, :].astype(jnp.int32)
    m = kp >= 0
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m


# ---------------------------------------------------------------------------
# blockwise flash attention with custom VJP
# ---------------------------------------------------------------------------

def _pick_block(s: int, target: int = 0) -> int:
    """k-block size for the blockwise attention. REPRO_ATTN_KBLOCK tunes the
    score-matrix working set (per-block scores = B*H*Sq*kb f32) — a perf lever
    the dry-run / hillclimb loop sets per workload."""
    import os
    target = target or int(os.environ.get("REPRO_ATTN_KBLOCK", "512"))
    if s <= target:
        return s
    for b in (target, 512, 256, 128, 64):
        if b <= target and s % b == 0:
            return b
    return s  # fall back to one block


def _scores(q, k_blk, scale):
    """q (B,Sq,KV,G,hd), k_blk (B,kb,KV,hd) -> (B,KV,G,Sq,kb) f32."""
    return jnp.einsum("bqcgd,bscd->bcgqs", q, k_blk,
                      preferred_element_type=jnp.float32) * scale


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def flash_attention(q, k, v, q_pos, k_pos, window, causal):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), *_pos int32 (S,). Returns (B,Sq,H,hd).

    Online-softmax scan over K-BLOCKS (flash-attention-2 structure): q stays
    whole, so a sequence-sharded q shard never crosses the scan boundary —
    this is what makes context-parallel prefill lower cleanly (the scanned
    k axis is constrained to be replicated by the caller; scanning over a
    *sharded* axis would force GSPMD to gather per iteration). Per-iteration
    working set is (B,H,Sq,kb) f32 scores; nothing S_k-sized materializes.
    """
    out, _ = _flash_fwd(q, k, v, q_pos, k_pos, window, causal)
    return out


def _flash_fwd(q, k, v, q_pos, k_pos, window, causal):
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = hd ** -0.5
    kb = _pick_block(sk)
    if sq <= kb:
        # decode / sub-prefill: q is tiny, K is the (sequence-sharded) cache.
        # One full-K pass: the softmax over the sharded Sk axis lowers to
        # small partial max/sum all-reduces, and K never crosses a scan
        # boundary (scanning a sharded axis would make GSPMD gather it).
        qr = q.reshape(b, sq, kvh, g, hd)
        s = _scores(qr, k, scale)                       # (B,KV,G,Sq,Sk)
        mask = position_mask(q_pos.astype(jnp.int32), k_pos.astype(jnp.int32),
                             window, causal)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
        p = jnp.exp(s - m)
        l = jnp.sum(p, axis=-1, keepdims=True)
        o = jnp.einsum("bcgqs,bscd->bqcgd", p / jnp.maximum(l, 1e-30), v,
                       preferred_element_type=jnp.float32)
        out = o.astype(q.dtype).reshape(b, sq, h, hd)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, (q, k, v, q_pos, k_pos, lse, out)
    nk = sk // kb
    qr = q.reshape(b, sq, kvh, g, hd)
    # all-gather-KV context parallelism: k/v may arrive sequence-sharded
    # (prefill/train under act_seq rules); gather them ONCE here — letting
    # the scan below slice a sharded axis makes GSPMD gather per block
    # (granite train_4k: collective 5.2s -> 31s before this constraint).
    # GQA KV is small (2 x S x KV x hd), so one gather/layer is the cheap
    # direction; q keeps its (head or sequence) sharding.
    k = shard(k, "batch", None, None, None)
    v = shard(v, "batch", None, None, None)
    kr = k.reshape(b, nk, kb, kvh, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kb, kvh, hd).transpose(1, 0, 2, 3, 4)
    kpr = k_pos.reshape(nk, kb)
    qp = q_pos.astype(jnp.int32)

    def body(carry, xs):
        m_run, l_run, acc = carry            # (B,KV,G,Sq,1), same, (B,Sq,KV,G,hd)
        k_blk, v_blk, kp = xs
        s = _scores(qr, k_blk, scale)        # (B,KV,G,Sq,kb)
        mask = position_mask(qp, kp, window, causal)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_run - m_new)       # rescale of old accumulators
        p = jnp.exp(s - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1, keepdims=True)
        pv = jnp.einsum("bcgqs,bscd->bqcgd", p, v_blk,
                        preferred_element_type=jnp.float32)
        acc = acc * alpha.transpose(0, 3, 1, 2, 4) + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, kvh, g, sq, 1), -1e29, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq, 1), jnp.float32)
    acc0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    (m, l, acc), _ = scan_layers(body, (m0, l0, acc0), (kr, vr, kpr))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))           # saved for backward
    out = (acc / jnp.maximum(l.transpose(0, 3, 1, 2, 4), 1e-30)).astype(q.dtype)
    out = out.reshape(b, sq, h, hd)
    return out, (q, k, v, q_pos, k_pos, lse, out)


def _shard_like_q(t):
    """Apply _shard_q's layout policy to any (B,S,H,hd) tensor (shape-based:
    no cfg at hand inside the custom-vjp backward)."""
    mesh = current_mesh()
    if mesh is None or "model" not in mesh.shape:
        return t
    if t.shape[2] % mesh.shape["model"] == 0:
        return shard(t, "batch", None, "heads", None)
    return shard(t, "batch", "act_seq", None, None)


def _flash_bwd(window, causal, res, dout):
    q, k, v, q_pos, k_pos, lse, out = res
    # dout arrives in the residual stream's (sequence-sharded) layout while q
    # is head-sharded — mixing the two makes GSPMD flip score layouts with
    # 4 GiB all-gathers per block (granite train: collective 5.2s -> 31s).
    # Constrain both to q's layout up front; one reshard of dout is cheap.
    dout = _shard_like_q(dout)
    q = _shard_like_q(q)
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    scale = hd ** -0.5
    kb = _pick_block(sk)
    nk = sk // kb
    qr = q.reshape(b, sq, kvh, g, hd)
    dor = dout.reshape(b, sq, kvh, g, hd).astype(jnp.float32)
    k = shard(k, "batch", None, None, None)   # gather once, as in forward
    v = shard(v, "batch", None, None, None)
    kr = k.reshape(b, nk, kb, kvh, hd).transpose(1, 0, 2, 3, 4)
    vr = v.reshape(b, nk, kb, kvh, hd).transpose(1, 0, 2, 3, 4)
    kpr = k_pos.reshape(nk, kb)
    qp = q_pos.astype(jnp.int32)
    # delta = rowsum(do * out) (flash-2 backward; out saved by the forward)
    delta = jnp.sum(dor * out.reshape(b, sq, kvh, g, hd).astype(jnp.float32),
                    axis=-1)[..., None]                  # (B,Sq,KV,G,1)
    delta = delta.transpose(0, 2, 3, 1, 4)               # (B,KV,G,Sq,1)

    def body(dq_acc, xs):
        k_blk, v_blk, kp = xs
        s = _scores(qr, k_blk, scale)
        mask = position_mask(qp, kp, window, causal)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse)                             # exact softmax probs
        dp = jnp.einsum("bqcgd,bscd->bcgqs", dor,
                        v_blk.astype(jnp.float32))
        ds = p * (dp - delta) * scale
        dq_acc = dq_acc + jnp.einsum("bcgqs,bscd->bqcgd", ds,
                                     k_blk.astype(jnp.float32))
        dk_blk = jnp.einsum("bcgqs,bqcgd->bscd", ds, qr.astype(jnp.float32))
        dv_blk = jnp.einsum("bcgqs,bqcgd->bscd", p, dor)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((b, sq, kvh, g, hd), jnp.float32)
    dq, (dks, dvs) = scan_layers(body, dq0, (kr, vr, kpr))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, sk, kvh, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(b, sk, kvh, hd)
    dq = dq.reshape(b, sq, h, hd)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None)


def _flash_fwd_vjp(q, k, v, qp, kp, w, c):
    out, res = _flash_fwd(q, k, v, qp, kp, w, c)
    return out, res


flash_attention.defvjp(_flash_fwd_vjp, _flash_bwd)


# ---------------------------------------------------------------------------
# high-level entry points used by the model definitions
# ---------------------------------------------------------------------------

def _shard_q(cfg, q):
    """Head-shard q when the head count divides the model axis; otherwise
    fall back to sequence sharding (context parallelism) so archs whose head
    count doesn't divide the mesh (qwen3-14b: 40 heads on model=16) don't
    replicate the O(S^2) attention over the model axis (EXPERIMENTS.md §Perf).
    ``act_seq`` resolves to () outside seq-parallel rules, so this degrades
    to the old behaviour on a single device."""
    mesh = current_mesh()
    if (mesh is not None and "model" in mesh.shape
            and cfg.num_heads % mesh.shape["model"] != 0):
        return shard(q, "batch", "act_seq", None, None)
    return shard(q, "batch", None, "heads", None)


def attn_self(cfg, p, x, positions, window: Optional[int] = None
              ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Causal self-attention over x (B,S,D) at ``positions`` (S,) int32.

    Returns (out (B,S,D), (k, v)) — k/v are the MatKV materialization product.
    """
    q = project_q(cfg, p, x)
    k, v = project_kv(cfg, p, x)
    if cfg.use_rope:
        q, k = rope_q_k(q, k, positions, cfg.rope_theta)
    q = _shard_q(cfg, q)
    out = flash_attention(q, k, v, positions, positions,
                          window if window else cfg.sliding_window, True)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    return out @ p["wo"], (k, v)


def attn_with_prefix(cfg, p, x, positions, prefix_k, prefix_v, prefix_pos,
                     window: Optional[int] = None):
    """New tokens x (B,Sq,D) at global ``positions`` (Sq,), attending to a
    prefix KV buffer (B,Sp,KV,hd) whose slots sit at global ``prefix_pos`` (Sp,)
    (-1 = invalid slot), plus causally to themselves.

    This one function is MatKV's serving core: Sq=1 is a decode step against a
    loaded cache; Sq=len(query) is the composed "sub-prefill" of the user query
    over concatenated materialized document KVs.

    Returns (out (B,Sq,D), (k_new, v_new)) — caller owns writing k/v into cache.
    """
    q = project_q(cfg, p, x)
    k_new, v_new = project_kv(cfg, p, x)
    if cfg.use_rope:
        q, k_new = rope_q_k(q, k_new, positions, cfg.rope_theta)
    keys = jnp.concatenate([prefix_k, k_new.astype(prefix_k.dtype)], axis=1)
    vals = jnp.concatenate([prefix_v, v_new.astype(prefix_v.dtype)], axis=1)
    k_pos = jnp.concatenate([prefix_pos.astype(jnp.int32),
                             positions.astype(jnp.int32)])
    out = flash_attention(q, keys, vals, positions.astype(jnp.int32), k_pos,
                          window if window else cfg.sliding_window, True)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    return out @ p["wo"], (k_new, v_new)


def attn_into_cache(cfg, p, x, rope_pos, order_pos, pk, pv, slot_pos, start,
                    window: Optional[int] = None):
    """Write-then-attend decode (flash-decoding friendly).

    Projects x's KV, writes it into this layer's cache buffers
    pk/pv (B,S_buf,KV,hd) at slot ``start`` (scalar, = length % buf), then
    attends over the *updated buffer only*. Unlike ``attn_with_prefix`` there
    is no concatenation, so a sequence-sharded cache keeps its sharding: the
    softmax over the sharded S_buf axis lowers to tiny per-(B,H,q) partial
    max/sum all-reduces instead of an all-gather of the whole KV cache.

    ``rope_pos`` rotates q/k (may be MatKV restart-mode positions);
    ``order_pos`` is the attention-order position of the new tokens — the
    mask runs entirely in order space against ``slot_pos``, which must
    already include the new tokens (caller updates it once for all layers).
    Causal masking by position makes write-before-attend exact for Sq >= 1.

    Returns (out (B,Sq,D), pk, pv) with the updated buffers.
    """
    q = project_q(cfg, p, x)
    k_new, v_new = project_kv(cfg, p, x)
    if cfg.use_rope:
        q, k_new = rope_q_k(q, k_new, rope_pos, cfg.rope_theta)
    zero = jnp.zeros((), jnp.int32)
    pk = jax.lax.dynamic_update_slice(
        pk, k_new.astype(pk.dtype), (zero, start, zero, zero))
    pv = jax.lax.dynamic_update_slice(
        pv, v_new.astype(pv.dtype), (zero, start, zero, zero))
    out = flash_attention(q, pk, pv, order_pos.astype(jnp.int32),
                          slot_pos.astype(jnp.int32),
                          window if window else cfg.sliding_window, True)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    return out @ p["wo"], pk, pv


def attention_rows(q, k, v, q_pos, k_pos, window: Optional[int],
                   causal: bool) -> jnp.ndarray:
    """Row-masked attention: q (B,Sq,H,hd), k/v (B,Sk,KV,hd), q_pos (B,Sq),
    k_pos (B,Sk). One full-K pass with a (B,Sq,Sk) mask — serving-side only
    (decode Sq is tiny and row prefills run at batch=1), so no blockwise scan
    or custom VJP. Numerics match ``flash_attention``'s small-Sq path exactly:
    masked slots contribute an exact 0.0 after the exp, so rows are invariant
    to each other and to trailing empty slots.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = hd ** -0.5
    qr = q.reshape(b, sq, kvh, g, hd)
    s = _scores(qr, k, scale)                           # (B,KV,G,Sq,Sk)
    mask = position_mask_rows(q_pos, k_pos, window, causal)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), -1e29)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bcgqs,bscd->bqcgd", p / jnp.maximum(l, 1e-30), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype).reshape(b, sq, h, hd)


def attn_into_cache_rows(cfg, p, x, rope_pos, order_pos, pk, pv, slot_pos,
                         start, window: Optional[int] = None):
    """Per-row write-then-attend decode over a row-slotted cache.

    Like ``attn_into_cache`` but every row owns its slot map: ``rope_pos`` /
    ``order_pos`` are (B,Sq), ``slot_pos`` (B,S_buf) must already include the
    new tokens, and ``start`` (B,) is each row's ``length % buf``. Rows at
    different decode offsets (continuous batching) write into different slots
    of the same batched buffers.

    Returns (out (B,Sq,D), pk, pv) with the updated buffers.
    """
    q = project_q(cfg, p, x)
    k_new, v_new = project_kv(cfg, p, x)
    if cfg.use_rope:
        q, k_new = rope_q_k(q, k_new, rope_pos, cfg.rope_theta)

    def write(buf, new, st):
        zero = jnp.zeros((), jnp.int32)
        return jax.lax.dynamic_update_slice(
            buf, new.astype(buf.dtype), (st, zero, zero))

    pk = jax.vmap(write)(pk, k_new, start)
    pv = jax.vmap(write)(pv, v_new, start)
    out = attention_rows(q, pk, pv, order_pos.astype(jnp.int32),
                         slot_pos.astype(jnp.int32),
                         window if window else cfg.sliding_window, True)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    return out @ p["wo"], pk, pv


def attn_paged_fused(cfg, p, x, positions, pk_blocks, pv_blocks, ks_blocks,
                     vs_blocks, tables, lens, totals, *, buf_size: int,
                     view_dtype, interpret: bool = True, mesh=None,
                     tp_axis: str = "model"):
    """Single-token decode attention straight off the paged block pool.

    The fused twin of ``attn_into_cache_rows`` for Sq=1: projects/rotates the
    new token, then runs ``paged_decode_fused`` against this layer's pool
    blocks ``pk/pv_blocks (n_blocks, block, KV, hd)`` (+ int8 scales) through
    the per-row block ``tables``/``lens``/``totals`` — no dense gather, no
    write-then-attend buffer. The new token's K/V is cast to the pool view
    dtype (exactly the ``new.astype(buf.dtype)`` the dense path's cache write
    performs) and handed to the kernel, which stages it at ``totals - 1``; the
    caller owns persisting the returned (k_new, v_new) into the pool (the
    scatter half of the three-phase pipeline, now one token-level write).

    Returns (out (B,1,D), k_new (B,KV,hd), v_new (B,KV,hd)).
    """
    from repro.kernels.paged_decode_fused import (paged_decode_fused,
                                                  paged_decode_fused_quant,
                                                  paged_decode_fused_tp)

    q = project_q(cfg, p, x)                      # (B,1,H,hd)
    k_new, v_new = project_kv(cfg, p, x)
    if cfg.use_rope:
        q, k_new = rope_q_k(q, k_new, positions, cfg.rope_theta)
    kn = k_new[:, 0].astype(view_dtype)
    vn = v_new[:, 0].astype(view_dtype)
    q0 = q[:, 0]
    if mesh is not None:
        out = paged_decode_fused_tp(q0, pk_blocks, pv_blocks, kn, vn, tables,
                                    lens, totals, buf_size=buf_size,
                                    mesh=mesh, axis=tp_axis,
                                    k_scale=ks_blocks, v_scale=vs_blocks,
                                    interpret=interpret)
    elif ks_blocks is None:
        out = paged_decode_fused(q0, pk_blocks, pv_blocks, kn, vn, tables,
                                 lens, totals, buf_size=buf_size,
                                 interpret=interpret)
    else:
        out = paged_decode_fused_quant(q0, pk_blocks, pv_blocks, ks_blocks,
                                       vs_blocks, kn, vn, tables, lens,
                                       totals, buf_size=buf_size,
                                       interpret=interpret)
    out = out.reshape(x.shape[0], 1, cfg.q_dim)
    return out @ p["wo"], kn, vn


def attn_cross(cfg, p, x, ck, cv):
    """Cross-attention: x (B,Sq,D) over precomputed encoder K/V (B,Se,KV,hd).

    No mask, no RoPE (whisper-style absolute positions live in the embeddings).
    ck/cv are exactly what MatKV materializes for enc-dec models.
    """
    q = project_q(cfg, p, x)
    se = ck.shape[1]
    k_pos = jnp.arange(se, dtype=jnp.int32)
    q_pos = jnp.full((x.shape[1],), se, dtype=jnp.int32)  # no causal constraint
    out = flash_attention(q, ck, cv, q_pos, k_pos, None, False)
    out = out.reshape(x.shape[0], x.shape[1], cfg.q_dim)
    return out @ p["wo"]


def cross_kv(cfg, p, enc_out):
    """Materialize cross-attention K/V from encoder output (whisper write path)."""
    return project_kv(cfg, p, enc_out)
