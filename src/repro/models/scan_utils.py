"""Scan-or-unroll helper.

XLA's cost model counts a while-loop body ONCE regardless of trip count, so a
lax.scan over layers (or attention blocks) hides almost all FLOPs/bytes from
``compiled.cost_analysis()``. The dry-run therefore lowers with
REPRO_UNROLL=1, which turns these structural scans into Python loops (bigger
HLO, accurate accounting); normal execution keeps lax.scan (small HLO, fast
compiles). Time-step recurrences (mamba / RG-LRU) stay as lax.scan always —
their trip counts are data-length and are corrected analytically in
repro.analysis.roofline instead.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp


def unroll_enabled() -> bool:
    return os.environ.get("REPRO_UNROLL") == "1"


def scan_layers(body, carry, xs, length=None):
    """Drop-in for jax.lax.scan(body, carry, xs) over STRUCTURAL axes."""
    if not unroll_enabled():
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if xs is None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = None if xs is None else jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if all(y is None for y in ys):
        stacked = None
    else:
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, stacked
