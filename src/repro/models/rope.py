"""Rotary position embeddings, plus the MatKV "re-rotation" trick.

RoPE rotates (q, k) by an angle proportional to the absolute position. Because
rotations compose (R(p + d) = R(d) . R(p)), a cached key computed at local
position p can be shifted to global position p + d with a single elementwise
rotation by d — no recomputation of the projection. MatKV's paper-faithful mode
keeps restarted per-chunk positions; ``rerotate`` is our beyond-paper variant
that restores globally consistent positions at compose time (DESIGN.md §9).
"""

from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions: (..., S) int -> cos, sin of shape (..., S, head_dim/2)."""
    half = head_dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, H, D) with cos/sin (B, S, D/2) or (S, D/2). Llama-style halves."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, D/2) -> broadcast over batch and heads
        cos_b = cos[None, :, None, :]
        sin_b = sin[None, :, None, :]
    else:  # (B, S, D/2)
        cos_b = cos[:, :, None, :]
        sin_b = sin[:, :, None, :]
    x1f = x1.astype(jnp.float32)
    x2f = x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos_b - x2f * sin_b, x2f * cos_b + x1f * sin_b], axis=-1)
    return out.astype(x.dtype)


def rope_q_k(q, k, positions, theta):
    """Rotate q (B,S,H,D) and k (B,S,KV,D) at ``positions`` (B,S) or (S,)."""
    cos, sin = rope_angles(positions, q.shape[-1], theta)
    return apply_rope(q, cos, sin), apply_rope(k, cos, sin)


def rerotate_keys(k: jnp.ndarray, offset, theta: float) -> jnp.ndarray:
    """Shift cached keys k (B, S, KV, D) by ``offset`` positions (scalar or (B,)).

    Uses R(p + offset) = R(offset) . R(p): one elementwise rotation, no matmul.
    """
    off = jnp.asarray(offset)
    if off.ndim == 0:
        pos = jnp.broadcast_to(off[None], (k.shape[1],))  # (S,)
    else:
        pos = jnp.broadcast_to(off[:, None], (k.shape[0], k.shape[1]))  # (B,S)
    cos, sin = rope_angles(pos, k.shape[-1], theta)
    return apply_rope(k, cos, sin)
