"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Block structure (arXiv:2402.19427): two input branches of width ``rglru_width``;
the x-branch passes through a width-4 causal conv then the RG-LRU recurrence;
the gate branch is GeLU'd and multiplies the recurrence output; out-projection
returns to d_model.

RG-LRU recurrence (f32):
    r_t = sigmoid(W_r x_t)        (recurrence gate)
    i_t = sigmoid(W_i x_t)        (input gate)
    a_t = a ** (c * r_t)          with a = sigmoid(Lambda), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

State: conv_state (B, 3, W), h (B, W). Like the Mamba block, the final state is
what MatKV materializes for recurrent layers (prefix-reuse semantics).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard

_C = 8.0
_CONV_W = 4


def _dense(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * fan_in ** -0.5).astype(dtype)


def init_rglru(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    d, w = cfg.d_model, cfg.rglru_width
    ks = jax.random.split(key, 6)
    # Lambda init so that a = sigmoid(Lambda) lands in [0.9, 0.999]
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    return {
        "in_x": _dense(ks[0], (d, w), d, dt),
        "in_gate": _dense(ks[1], (d, w), d, dt),
        "conv_w": _dense(ks[2], (_CONV_W, w), _CONV_W, dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_r": _dense(ks[3], (w, w), w, dt),
        "w_i": _dense(ks[5], (w, w), w, dt),
        "lam": jnp.log(u / (1.0 - u)),            # (w,) f32, sigmoid^-1(u)
        "out_proj": _dense(jax.random.fold_in(key, 7), (w, d), w, dt),
    }


def _causal_conv(p, x, conv_state):
    w = p["conv_w"].astype(jnp.float32)
    xin = jnp.concatenate([conv_state.astype(jnp.float32),
                           x.astype(jnp.float32)], axis=1)
    out = sum(xin[:, i:i + x.shape[1], :] * w[i] for i in range(_CONV_W))
    new_state = xin[:, -(_CONV_W - 1):, :].astype(conv_state.dtype)
    return out + p["conv_b"].astype(jnp.float32), new_state


def rglru_scan(x, r, i, lam, h0, chunk: int = 64):
    """x/r/i (B,S,W) f32, lam (W,), h0 (B,W) f32 -> (y (B,S,W), h_final).

    Chunked two-level scan with remat (same residual-memory rationale as
    models.mamba.selective_scan): AD keeps only chunk-boundary states."""
    log_a = -_C * jax.nn.softplus(-lam)           # log sigmoid(lam) * c  (<= 0)
    s = x.shape[1]
    for c in (chunk, 32, 16, 8, 4, 2, 1):
        if s % c == 0:
            chunk = c
            break
    nc = s // chunk

    def step(h, inp):
        xt, rt, it = inp
        log_at = rt * log_a                        # (B,W)
        at = jnp.exp(log_at)
        gated = it * xt
        h = at * h + jnp.sqrt(jnp.maximum(1.0 - at * at, 1e-12)) * gated
        return h, h

    @jax.checkpoint
    def chunk_body(h, xs_chunk):
        return jax.lax.scan(step, h, xs_chunk)

    def to_chunks(t):
        moved = jnp.moveaxis(t, 1, 0)
        out = moved.reshape((nc, chunk) + moved.shape[1:])
        return shard(out, None, None, "batch", "inner")

    h0 = shard(h0, "batch", "inner")
    h_final, ys = jax.lax.scan(chunk_body, h0,
                               (to_chunks(x), to_chunks(r), to_chunks(i)))
    y = jnp.moveaxis(ys.reshape((s,) + ys.shape[2:]), 0, 1)
    return y, h_final


def rglru_fwd(cfg, p, x, state: Optional[Tuple] = None):
    """x (B,S,D) -> (out (B,S,D), (conv_state, h))."""
    b, s, _ = x.shape
    w = cfg.rglru_width
    if state is None:
        conv_state = jnp.zeros((b, _CONV_W - 1, w), x.dtype)
        h0 = jnp.zeros((b, w), jnp.float32)
    else:
        conv_state, h0 = state
        h0 = h0.astype(jnp.float32)

    xb = x @ p["in_x"]
    gate = jax.nn.gelu(x @ p["in_gate"])
    xb = shard(xb, "batch", None, "inner")
    conv_out, conv_state = _causal_conv(p, xb, conv_state)

    xc = conv_out                                  # f32
    r = jax.nn.sigmoid((xc.astype(x.dtype) @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xc.astype(x.dtype) @ p["w_i"]).astype(jnp.float32))
    y, h = rglru_scan(xc, r, i, p["lam"], h0)
    out = (y.astype(x.dtype) * gate) @ p["out_proj"]
    return out, (conv_state, h)
