"""Fine-grained Mixture-of-Experts FFN (DeepSeekMoE / Qwen3-MoE style).

Two dispatch paths:

* **Expert-parallel shard_map** (production, used whenever a mesh with a
  ``model`` axis is active and E divides it): experts are sharded over the
  model axis; tokens stay in their data-axis sharding (they are already
  replicated along the model axis at the layer boundary). Each device routes
  its local tokens to its LOCAL expert shard with a sort-based static-capacity
  dispatch, runs the expert FFNs as batched einsums, scatter-adds weighted
  results, and a single psum over the model axis combines expert outputs —
  the same collective volume as a Megatron TP FFN, with no GSPMD-replicated
  gather/scatter blow-ups (the naive pjit lowering of MoE scatter ops
  replicated the full token buffer per device: +200 GiB/device at
  qwen3-moe-30b train_4k scale; this path removes that).
* **Dense-dispatch fallback** (single device / no mesh): same sort-based
  static-capacity algorithm over all experts.

Overflow tokens are dropped (capacity_factor controls the drop rate),
matching Switch/GShard semantics. Shared experts (DeepSeekMoE) are
algebraically a single dense SwiGLU with hidden size S*moe_d_ff and are
computed outside the routed path.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import current_mesh, shard
from repro.models.mlp import init_mlp, mlp


def _dense(key, shape, fan_in, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * fan_in ** -0.5).astype(dtype)


def init_moe(cfg, key):
    dt = jnp.dtype(cfg.param_dtype)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense(ks[0], (d, e), d, jnp.float32),  # router kept in f32
        "w_gate": _dense(ks[1], (e, d, f), d, dt),
        "w_up": _dense(ks[2], (e, d, f), d, dt),
        "w_down": _dense(ks[3], (e, f, d), f, dt),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(cfg, ks[4], d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
    return p


def _capacity(n_tokens: int, cfg, mult: int = 8) -> int:
    """Per-expert capacity, rounded for hardware alignment.

    Large capacities round to 2048 — a multiple of the 128-wide MXU tile and
    of every batch-axis mesh extent ``expert_cap`` shards over (pod x data =
    32); small (smoke-test) capacities only need the 8-row tile."""
    c = math.ceil(n_tokens * cfg.moe_top_k / cfg.num_experts * cfg.capacity_factor)
    if c >= 2048:
        mult = 2048
    return max(mult, ((c + mult - 1) // mult) * mult)


def _routing(cfg, xf, router):
    logits = xf.astype(jnp.float32) @ router
    probs = jax.nn.softmax(logits, axis=-1)                      # (N, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, cfg.moe_top_k)  # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)
    n, k = expert_idx.shape
    e = cfg.num_experts
    assign_frac = jnp.zeros((e,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (n * k))
    aux = cfg.router_aux_coef * e * jnp.sum(assign_frac
                                            * jnp.mean(probs, axis=0))
    return gate_vals, expert_idx, aux


def _dispatch_compute_combine(cfg, xf, gate_vals, expert_idx, w_gate, w_up,
                              w_down, e_start: int, cap: int):
    """Sort-based static-capacity dispatch against experts
    [e_start, e_start + w_gate.shape[0]). xf (N, D) -> (N, D) partial output
    (tokens routed to experts outside the range contribute zero)."""
    n, d = xf.shape
    k = cfg.moe_top_k
    e_loc = w_gate.shape[0]

    e_flat = expert_idx.reshape(-1)                               # (N*k,)
    order = jnp.argsort(e_flat)
    sorted_e = e_flat[order]
    group_start = jnp.searchsorted(sorted_e, jnp.arange(cfg.num_experts),
                                   side="left")
    pos_in_grp = jnp.arange(n * k) - group_start[sorted_e]
    local_e = sorted_e - e_start
    in_range = (local_e >= 0) & (local_e < e_loc)
    keep = in_range & (pos_in_grp < cap)
    dest = jnp.where(keep, local_e * cap + pos_in_grp, e_loc * cap)
    token_of = order // k

    x_e = jnp.zeros((e_loc * cap, d), xf.dtype).at[dest].set(
        xf[token_of], mode="drop").reshape(e_loc, cap, d)

    act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
    h = act(jnp.einsum("ecd,edf->ecf", x_e, w_gate)) \
        * jnp.einsum("ecd,edf->ecf", x_e, w_up)
    y_e = jnp.einsum("ecf,efd->ecd", h, w_down)                   # (E_loc,C,D)

    y_flat = y_e.reshape(e_loc * cap, d)
    gathered = jnp.take(y_flat, jnp.minimum(dest, e_loc * cap - 1), axis=0)
    w = (gate_vals.reshape(-1)[order] * keep).astype(xf.dtype)
    return jnp.zeros((n, d), xf.dtype).at[token_of].add(
        gathered * w[:, None], mode="drop")


# ---------------------------------------------------------------------------
# paths
# ---------------------------------------------------------------------------

def _moe_dense(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    xf = x.reshape(b * s, d)
    gate_vals, expert_idx, aux = _routing(cfg, xf, p["router"])
    cap = _capacity(b * s, cfg)
    out = _dispatch_compute_combine(cfg, xf, gate_vals, expert_idx,
                                    p["w_gate"], p["w_up"], p["w_down"],
                                    e_start=0, cap=cap)
    return out.reshape(b, s, d), aux


def _moe_expert_parallel(cfg, p, x, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n_model = mesh.shape["model"]
    b = x.shape[0]
    # data axes that evenly divide the batch (long_500k's B=1 -> replicated)
    chosen = []
    for a in ("pod", "data"):
        if a in mesh.shape and b % math.prod(
                mesh.shape[ax] for ax in chosen + [a]) == 0:
            chosen.append(a)
    bd = tuple(chosen) if len(chosen) > 1 else (chosen[0] if chosen else None)
    n_data = math.prod(mesh.shape[a] for a in chosen) if chosen else 1
    n_loc = (b // n_data) * x.shape[1]
    cap = _capacity(n_loc, cfg)
    e_loc = cfg.num_experts // n_model

    def local_fn(router, wg, wu, wd, xl):
        bl, s, d = xl.shape
        xf = xl.reshape(bl * s, d)
        gate_vals, expert_idx, aux = _routing(cfg, xf, router)
        e_start = jax.lax.axis_index("model") * e_loc
        out = _dispatch_compute_combine(cfg, xf, gate_vals, expert_idx,
                                        wg, wu, wd, e_start, cap)
        out = jax.lax.psum(out, axis_name="model")
        if chosen:
            aux = jax.lax.pmean(aux, axis_name=tuple(chosen))
        return out.reshape(bl, s, d), aux

    x_spec = P(bd, None, None)
    out, aux = jax.shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None), x_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(p["router"], p["w_gate"], p["w_up"], p["w_down"], x)
    return out, aux


def moe_ffn(cfg, p, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,D) -> (out (B,S,D), aux_loss scalar)."""
    mesh = current_mesh()
    if (mesh is not None and "model" in mesh.shape
            and cfg.num_experts % mesh.shape["model"] == 0):
        out, aux = _moe_expert_parallel(cfg, p, x, mesh)
    else:
        out, aux = _moe_dense(cfg, p, x)
    if cfg.num_shared_experts:
        out = out + mlp(cfg, p["shared"], x)
    return out, aux
