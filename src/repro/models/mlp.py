"""Feed-forward blocks: SwiGLU / GeGLU / plain GELU (functional)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.sharding import shard


def _dense(key, fan_in, fan_out, dtype):
    return (jax.random.normal(key, (fan_in, fan_out), jnp.float32)
            * fan_in ** -0.5).astype(dtype)


def init_mlp(cfg, key, d_ff: int = 0):
    d_ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi_gate": _dense(ks[0], cfg.d_model, d_ff, dt),
            "wi_up": _dense(ks[1], cfg.d_model, d_ff, dt),
            "wo": _dense(ks[2], d_ff, cfg.d_model, dt),
        }
    return {
        "wi": _dense(ks[0], cfg.d_model, d_ff, dt),
        "wo": _dense(ks[2], d_ff, cfg.d_model, dt),
    }


def mlp(cfg, p, x):
    if "wi_gate" in p:
        act = jax.nn.silu if cfg.act == "swiglu" else jax.nn.gelu
        h = act(x @ p["wi_gate"]) * (x @ p["wi_up"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    if h.ndim == 3:
        h = shard(h, "batch", None, "ffn")
    return h @ p["wo"]
