"""Unified model API over all families — the single surface the training loop,
serving engine, MatKV core, dry-run, and benchmarks program against.

    model = Model(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)              # training
    logits, artifact = model.prefill(params, batch)        # MatKV write path
    cache = model.init_cache(batch_size, seq_len)
    logits, cache = model.decode_step(params, cache, toks)  # serve path
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import cache as cache_lib, encdec, transformer
from repro.models.scan_utils import scan_layers


def chunked_cross_entropy(cfg, params, hidden: jnp.ndarray,
                          labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None,
                          chunk: int = 512) -> jnp.ndarray:
    """CE without materializing (B,S,V) logits: scan over seq chunks, unembed +
    logsumexp per chunk, remat'd so the backward recomputes chunk logits.

    With a 150k--256k vocab this is the difference between a ~20 GB and a
    ~0.3 GB per-device peak for train_4k."""
    from repro.models.transformer import unembed

    b, s, _ = hidden.shape
    chunk = min(chunk, s)
    while s % chunk:
        chunk //= 2
    nc = s // chunk
    hc = hidden.reshape(b, nc, chunk, -1).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, chunk).transpose(1, 0, 2)
    mc = (mask.reshape(b, nc, chunk).transpose(1, 0, 2)
          if mask is not None else jnp.ones_like(lc, jnp.float32))

    @jax.checkpoint
    def body(carry, xs):
        h, lab, m = xs
        logits = unembed(cfg, params, h).astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        nll_sum, m_sum = carry
        m = m.astype(jnp.float32)
        return (nll_sum + jnp.sum((logz - gold) * m), m_sum + jnp.sum(m)), None

    (nll, msum), _ = scan_layers(body, (jnp.zeros(()), jnp.zeros(())),
                                  (hc, lc, mc))
    return nll / jnp.maximum(msum, 1.0)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token cross-entropy in f32. labels (B,S) int32; mask optional (B,S)."""
    lf = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    m = mask.astype(jnp.float32)
    return jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)


class Model:
    def __init__(self, cfg):
        self.cfg = cfg
        self.is_encdec = cfg.family in ("encdec", "audio")

    # -- params ---------------------------------------------------------------
    def init(self, key, enc_len: Optional[int] = None,
             dec_len: Optional[int] = None):
        if self.is_encdec:
            return encdec.init_params(self.cfg, key, enc_len=enc_len,
                                      dec_len=dec_len)
        return transformer.init_params(self.cfg, key)

    # -- training ----------------------------------------------------------------
    def forward(self, params, batch: Dict[str, Any], remat: bool = False):
        if self.is_encdec:
            return encdec.forward(self.cfg, params, batch["frontend"],
                                  batch["tokens"])
        return transformer.forward(self.cfg, params, batch["tokens"],
                                   frontend=batch.get("frontend"),
                                   remat=remat)

    def loss(self, params, batch: Dict[str, Any], remat: bool = False,
             ce_chunk: int = 0) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
        labels = batch["labels"]
        if ce_chunk and not self.is_encdec:
            hidden, aux, _ = transformer.forward(
                self.cfg, params, batch["tokens"],
                frontend=batch.get("frontend"), remat=remat,
                return_hidden=True)
            if batch.get("frontend") is not None:
                hidden = hidden[:, -labels.shape[1]:]
            ce = chunked_cross_entropy(self.cfg, params, hidden, labels,
                                       batch.get("loss_mask"), ce_chunk)
        else:
            logits, aux, _ = self.forward(params, batch, remat=remat)
            if not self.is_encdec and batch.get("frontend") is not None:
                # frontend tokens carry no LM loss; logits cover [frontend|text]
                logits = logits[:, -labels.shape[1]:]
            ce = cross_entropy(logits, labels, batch.get("loss_mask"))
        total = ce + aux
        return total, {"loss": total, "ce": ce, "aux": aux}

    # -- MatKV write path -----------------------------------------------------
    def prefill(self, params, batch: Dict[str, Any], positions=None):
        """Returns (logits_or_enc, artifact). artifact is what MatKV stores."""
        if self.is_encdec:
            enc_out, (ck, cv) = encdec.encode_and_materialize(
                self.cfg, params, batch["frontend"])
            return enc_out, (ck, cv)
        return transformer.prefill(self.cfg, params, batch["tokens"],
                                   frontend=batch.get("frontend"),
                                   positions=positions)

    # -- serve path ---------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int, enc_len: int = 0, dtype=None):
        cfg = self.cfg
        if self.is_encdec:
            return cache_lib.init_encdec_cache(
                cfg, batch, enc_len or cfg.enc_positions,
                min(seq_len, cfg.max_position), dtype=dtype)
        if cfg.family == "ssm":
            return cache_lib.init_ssm_cache(cfg, batch, dtype=dtype)
        if cfg.family == "hybrid":
            return cache_lib.init_hybrid_cache(cfg, batch, seq_len, dtype=dtype)
        return cache_lib.init_attn_cache(cfg, batch, seq_len, dtype=dtype)

    def decode_step(self, params, cache, tokens, positions=None):
        if self.is_encdec:
            return encdec.decode_step(self.cfg, params, cache, tokens, positions)
        return transformer.decode_step(self.cfg, params, cache, tokens, positions)

    # -- row-slotted serve path (continuous batching) -------------------------
    def init_row_cache(self, batch: int, buf_size: int, dtype=None):
        if self.is_encdec or self.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("row-slotted caches require an attention-KV "
                             f"family, got {self.cfg.family}")
        return cache_lib.init_row_attn_cache(self.cfg, batch, buf_size,
                                             dtype=dtype)

    def decode_step_rows(self, params, cache, tokens, positions=None):
        return transformer.decode_step_rows(self.cfg, params, cache, tokens,
                                            positions)

    def streaming_prompt_q0(self, params, tokens, n_doc):
        """Roped layer-0 prompt queries at order positions n_doc.. — the
        seed of a streamed admission's ``StreamingPrefix`` carry."""
        return transformer.streaming_prompt_q0(self.cfg, params, tokens,
                                               n_doc)

    def decode_step_rows_streamed(self, params, cache, tokens, q0, m, l, acc):
        """``decode_step_rows`` with layer 0's doc-prefix attention taken
        from the streamed (q0, m, l, acc) carry instead of recomputed."""
        return transformer.decode_step_rows_streamed(
            self.cfg, params, cache, tokens, q0, m, l, acc)

    def decode_step_rows_fused(self, params, pool_k, pool_v, k_scale, v_scale,
                               length, tokens, tables, lens, totals, *,
                               buf_size: int, block_size: int,
                               interpret: bool = True, mesh=None,
                               tp_axis: str = "model"):
        """Fused paged decode straight off the pool block tensors — one
        Pallas launch per layer instead of gather -> dense step -> scatter.
        Returns (logits, k_new (L,B,KV,hd), v_new) in the pool view dtype."""
        return transformer.decode_step_rows_fused(
            self.cfg, params, pool_k, pool_v, k_scale, v_scale, length,
            tokens, tables, lens, totals, buf_size=buf_size,
            block_size=block_size, interpret=interpret, mesh=mesh,
            tp_axis=tp_axis)


def build_model(cfg) -> Model:
    return Model(cfg)
