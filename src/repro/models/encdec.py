"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: callers provide precomputed
frame embeddings (B, T, d_model); a learned projector + learned absolute
positions stand in for the conv stack. The decoder is a standard pre-LN
transformer with self-attention + cross-attention.

MatKV mapping: the decoder's cross-attention K/V over the encoded audio are
computed once per document (= audio chunk) and are query-independent — they are
THE materialized artifact (``encode_and_materialize``). Decoding then needs
only the loaded cross-KV plus a small self-attention cache.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.attention import (attn_cross, attn_with_prefix, cross_kv,
                                    flash_attention, init_attention, project_kv,
                                    project_q)
from repro.models.cache import EncDecCache, write_kv
from repro.models.mlp import init_mlp, mlp
from repro.models.norms import layer_norm
from repro.models.scan_utils import scan_layers


def _ln_params(d, dt):
    return {"w": jnp.ones((d,), dt), "b": jnp.zeros((d,), dt)}


def init_params(cfg, key, enc_len: Optional[int] = None, dec_len: Optional[int] = None):
    dt = jnp.dtype(cfg.param_dtype)
    enc_len = enc_len or cfg.enc_positions
    dec_len = dec_len or cfg.max_position
    keys = jax.random.split(key, 8)
    d = cfg.d_model

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"attn": init_attention(cfg, k1), "mlp": init_mlp(cfg, k2),
                "ln1": _ln_params(d, dt), "ln2": _ln_params(d, dt)}

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"self_attn": init_attention(cfg, k1),
                "cross_attn": init_attention(cfg, k2, cross=True),
                "mlp": init_mlp(cfg, k3),
                "ln1": _ln_params(d, dt), "ln2": _ln_params(d, dt),
                "ln3": _ln_params(d, dt)}

    return {
        "frontend_proj": (jax.random.normal(keys[0], (d, d), jnp.float32)
                          * d ** -0.5).astype(dt),
        "enc_pos": (jax.random.normal(keys[1], (enc_len, d), jnp.float32)
                    * 0.02).astype(dt),
        "dec_pos": (jax.random.normal(keys[2], (dec_len, d), jnp.float32)
                    * 0.02).astype(dt),
        "embed": (jax.random.normal(keys[3], (cfg.vocab_size, d), jnp.float32)
                  * d ** -0.5).astype(dt),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(keys[4], cfg.enc_layers)),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(keys[5], cfg.dec_layers)),
        "enc_ln": _ln_params(d, dt),
        "dec_ln": _ln_params(d, dt),
    }


def _ln(x, p, eps):
    return layer_norm(x, p["w"], p["b"], eps)


def encode(cfg, params, frames):
    """frames (B,T,D) stub embeddings -> encoder output (B,T,D)."""
    t = frames.shape[1]
    x = frames.astype(cfg.activation_dtype) @ params["frontend_proj"]
    x = x + params["enc_pos"][:t][None].astype(x.dtype)
    nocausal_pos = jnp.arange(t, dtype=jnp.int32)

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        q = project_q(cfg, lp["attn"], h)
        k, v = project_kv(cfg, lp["attn"], h)
        # bidirectional: q_pos = T for all queries, so every key is visible
        a = flash_attention(q, k, v,
                            jnp.full((t,), t, jnp.int32), nocausal_pos,
                            None, True)
        x = x + a.reshape(x.shape[0], t, cfg.q_dim) @ lp["attn"]["wo"]
        x = x + mlp(cfg, lp["mlp"], _ln(x, lp["ln2"], cfg.norm_eps))
        return x, None

    x, _ = scan_layers(body, x, params["enc_layers"])
    return _ln(x, params["enc_ln"], cfg.norm_eps)


def encode_and_materialize(cfg, params, frames):
    """MatKV write path: encode audio, emit per-decoder-layer cross K/V stacks
    (L_dec, B, T, KV, hd)."""
    enc_out = encode(cfg, params, frames)

    def body(_, lp):
        k, v = cross_kv(cfg, lp["cross_attn"], enc_out)
        return None, (k, v)

    _, (ck, cv) = scan_layers(body, None, params["dec_layers"])
    return enc_out, (ck, cv)


def decode_tokens(cfg, params, tokens, enc_out, positions=None):
    """Teacher-forced decoder over full token sequence (training)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    x = x + params["dec_pos"][:s][None].astype(x.dtype)
    pos = jnp.arange(s, dtype=jnp.int32) if positions is None else positions

    def body(x, lp):
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        q = project_q(cfg, lp["self_attn"], h)
        k, v = project_kv(cfg, lp["self_attn"], h)
        a = flash_attention(q, k, v, pos, pos, None, True)
        x = x + a.reshape(b, s, cfg.q_dim) @ lp["self_attn"]["wo"]
        x = x + attn_cross(cfg, lp["cross_attn"],
                           _ln(x, lp["ln2"], cfg.norm_eps), *cross_kv(
                               cfg, lp["cross_attn"], enc_out))
        x = x + mlp(cfg, lp["mlp"], _ln(x, lp["ln3"], cfg.norm_eps))
        return x, None

    x, _ = scan_layers(body, x, params["dec_layers"])
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    return x @ params["embed"].T.astype(x.dtype)


def forward(cfg, params, frames, tokens):
    """Full enc-dec forward (training). Returns (logits, aux=0)."""
    enc_out = encode(cfg, params, frames)
    logits = decode_tokens(cfg, params, tokens, enc_out)
    return logits, jnp.zeros((), jnp.float32), None


def decode_step(cfg, params, cache: EncDecCache, tokens, positions=None):
    """tokens (B,Sq) against materialized cross-KV + decoder self cache."""
    b, sq = tokens.shape
    order_pos = cache.length + jnp.arange(sq, dtype=jnp.int32)
    pos = order_pos if positions is None else positions
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.activation_dtype)
    x = x + jnp.take(params["dec_pos"], pos, axis=0)[None].astype(x.dtype)

    def body(x, xs):
        lp, pk, pv, ck, cv = xs
        a, kv = attn_with_prefix(cfg, lp["self_attn"],
                                 _ln(x, lp["ln1"], cfg.norm_eps),
                                 pos, pk, pv, cache.slot_pos)
        x = x + a
        x = x + attn_cross(cfg, lp["cross_attn"],
                           _ln(x, lp["ln2"], cfg.norm_eps), ck, cv)
        x = x + mlp(cfg, lp["mlp"], _ln(x, lp["ln3"], cfg.norm_eps))
        return x, kv

    x, kvs = scan_layers(body, x, (params["dec_layers"], cache.k, cache.v,
                                    cache.cross_k, cache.cross_v))
    k, v, spos, length = write_kv(cache.k, cache.v, cache.slot_pos, cache.length,
                                  kvs[0], kvs[1], positions=order_pos)
    new_cache = EncDecCache(cross_k=cache.cross_k, cross_v=cache.cross_v,
                            k=k, v=v, slot_pos=spos, length=length)
    x = _ln(x, params["dec_ln"], cfg.norm_eps)
    return x @ params["embed"].T.astype(x.dtype), new_cache
