"""Teacher-forced parity probes between row-serving paths.

The codec layer's acceptance bars (tests/test_codec.py and
benchmarks/bench_quant_residency.py) compare serving paths *at the logits
level* while feeding both the SAME token stream each step — a greedy-decode
comparison would cascade into unrelated streams on the first argmax flip,
turning a 1% quantization wobble into a 100% string mismatch. One harness
here so the test and the benchmark are guaranteed to measure the same
protocol.

A "path" is a factory ``init(req) -> {"first": int, "step": fn}``:
``dense_row_path`` composes into a batch=1 row-slotted cache and steps with
``engine.step_rows``; ``paged_row_path`` admits into a 1-slot page-table
cache and steps with ``engine.step_rows_paged``.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.models.cache import insert_cache_row


def dense_row_path(eng, buf: int):
    """The non-paged engine path: compose -> prefill -> step_rows."""
    def init(req):
        row, _, _ = eng.compose_row(req, buf)
        first, row = eng.prefill_row(row, req.prompt)
        cache = eng.init_row_cache(1, buf)   # mesh-placed when eng has one
        state = {"cache": insert_cache_row(cache, 0, row)}

        def step(t):
            logits, state["cache"] = eng.step_rows(state["cache"], t)
            return logits
        return {"first": int(first[0]), "step": step}
    return init


def paged_row_path(eng, buf: int, block_size: int = 64):
    """The paged path: page-table admit -> prefill -> step_rows_paged."""
    def init(req):
        pc = eng.init_paged_cache(1, buf, block_size=block_size)
        eng.compose_row_paged(req, pc, 0)
        first = eng.prefill_row_paged(pc, 0, req.prompt)
        return {"first": int(first[0]),
                "step": lambda t: eng.step_rows_paged(pc, t)}
    return init


def teacher_forced_rel(eng_a, path_a, eng_b, path_b, question: str,
                       steps: int, require_same_first: bool = True) -> float:
    """Max relative logits diff over ``steps`` decode steps, both paths fed
    path A's greedy stream. ``require_same_first`` asserts the prefill's
    first token agrees (drop it when comparing across codecs, where the
    first token may legitimately differ)."""
    max_rel = 0.0
    a_state = path_a(eng_a.prepare_request(question, steps + 2))
    b_state = path_b(eng_b.prepare_request(question, steps + 2))
    tok = a_state["first"]
    if require_same_first:
        assert tok == b_state["first"], (
            f"first token diverged: {tok} vs {b_state['first']}")
    for _ in range(steps):
        t = jnp.asarray([tok])[:, None]
        a = np.asarray(a_state["step"](t), np.float32)
        b = np.asarray(b_state["step"](t), np.float32)
        max_rel = max(max_rel, float(np.abs(a - b).max()
                                     / (np.abs(a).max() + 1e-9)))
        tok = int(np.argmax(a[0, -1]))
    return max_rel
