"""Serving metrics, split per role (DESIGN.md §14) — now a *view* over the
observability registry (DESIGN.md §15).

``ServeMetrics`` lives here (not in ``continuous.py``) so the role facades
in ``serving/roles.py`` can account against it without importing the
scheduler. A disaggregated deployment runs materialization and decode on
different hardware with different clocks, so the blended
``tokens_per_s = n_new_tokens / wall_s`` is misleading there — use the
per-role rates:

* ``materialize_tokens_per_s`` — chunk tokens whose KV was computed and
  durably written to flash, over the time spent doing only that.
* ``decode_tokens_per_s`` — new tokens emitted over the time spent inside
  decode steps (the number a weak decode mesh must hold while the
  materializer fleet scales).

``tokens_per_s`` stays for the composed single-process path ("both" role),
where one wall clock is the honest end-to-end number.

Since PR 8 the scheduler and the materializer role no longer mutate these
fields directly: they write named counters/gauges/histograms into a
:class:`repro.obs.MetricsRegistry`, and ``ServeMetrics.from_registry``
computes this dataclass from it at the end of a run.  The dataclass keeps
its flat field layout (tests and benches read it), gains TTFT and the
per-phase ``phase_s`` breakdown, and round-trips through
``as_dict``/``from_dict`` with a schema version for ``results.jsonl``.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, List

import numpy as np

METRICS_SCHEMA = 1


@dataclass
class ServeMetrics:
    role: str = "both"                     # "materialize" | "decode" | "both"
    wall_s: float = 0.0
    prefill_s: float = 0.0                 # compose + prefill COMPUTE only
                                           # (admission bookkeeping and flash
                                           # wait live in phase_s, not here)
    decode_s: float = 0.0
    n_requests: int = 0
    n_new_tokens: int = 0
    kv_bytes_loaded: int = 0               # bytes composed into rows
    latencies_s: List[float] = field(default_factory=list)
    ttft_s: List[float] = field(default_factory=list)
                                           # request arrival -> first emitted
                                           # token (the cold-load stall the
                                           # overlap claim is about)
    phase_s: Dict[str, float] = field(default_factory=dict)
                                           # wall seconds per lifecycle phase
                                           # (admission / load_stall / compose
                                           # / prefill / decode_step / ...);
                                           # per-request these sum ≈ latency
    # load-link accounting (fed by the paged pool's dedup stats; the
    # row-slotted path reads every chunk per request, so there hits == 0)
    flash_bytes_loaded: int = 0            # bytes actually read from flash
    flash_bytes_per_request: List[int] = field(default_factory=list)
    chunk_hits: int = 0                    # chunk already GPU-resident
    chunk_misses: int = 0                  # chunk had to be read + inserted
    flash_read_s: List[float] = field(default_factory=list)
                                           # per-read flash wall times (from
                                           # the trace's flash_read spans;
                                           # empty when tracing is off)
    load_overlap_frac: float = 0.0         # fraction of flash-read time
                                           # hidden behind decode_step spans
                                           # (the overlap claim, measured)
    hbm_kv_bytes_resident: int = 0         # peak KV bytes resident in HBM
    resident_chunks_peak: int = 0          # paged: peak distinct chunks in
                                           # the pool (codec-sensitive: one
                                           # byte budget holds ~2x under int8)
    pool_shard_bytes: List[int] = field(default_factory=list)
                                           # paged: per-device bytes of the
                                           # pool's block tensors (one entry
                                           # on a single device; under a
                                           # serving mesh the entries sum to
                                           # the single-device footprint)
    # per-step measurement (fused paged path: bytes derived from the block
    # tables actually staged; see repro.obs.compare)
    n_decode_steps: int = 0
    decode_kv_bytes_measured: int = 0
    # materializer-role accounting
    materialize_s: float = 0.0             # time inside materialize calls
    n_materialized_tokens: int = 0         # chunk tokens written to flash
    n_materialize_jobs: int = 0            # jobs processed off the queue
    flash_bytes_written: int = 0           # artifact bytes put to flash

    @property
    def chunk_hit_rate(self) -> float:
        total = self.chunk_hits + self.chunk_misses
        return self.chunk_hits / total if total else 0.0

    @property
    def tokens_per_s(self) -> float:
        """Blended end-to-end rate over one wall clock. Honest only for the
        composed "both" role; disaggregated runs report the per-role rates
        below instead."""
        return self.n_new_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def materialize_tokens_per_s(self) -> float:
        """Chunk tokens durably materialized per second of materializer
        work — the prefill fleet's scaling axis."""
        return (self.n_materialized_tokens / self.materialize_s
                if self.materialize_s else 0.0)

    @property
    def decode_tokens_per_s(self) -> float:
        """New tokens per second of decode-step time — the rate a weak
        decode mesh must hold under a scaling materializer fleet."""
        return self.n_new_tokens / self.decode_s if self.decode_s else 0.0

    def latency_quantile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies_s), q))

    @property
    def p50_latency_s(self) -> float:
        return self.latency_quantile(0.50)

    @property
    def p95_latency_s(self) -> float:
        return self.latency_quantile(0.95)

    def ttft_quantile(self, q: float) -> float:
        if not self.ttft_s:
            return 0.0
        return float(np.quantile(np.asarray(self.ttft_s), q))

    @property
    def p50_ttft_s(self) -> float:
        return self.ttft_quantile(0.50)

    @property
    def p95_ttft_s(self) -> float:
        return self.ttft_quantile(0.95)

    # -- registry view -------------------------------------------------------

    @classmethod
    def from_registry(cls, reg, role: str = "both") -> "ServeMetrics":
        """Compute the dataclass from a ``repro.obs.MetricsRegistry`` — the
        only constructor the instrumented scheduler / roles use.  Field
        semantics are unchanged; ``prefill_s`` is now compose + prefill
        compute only (the satellite fix), with the full split in
        ``phase_s``."""
        phases = {k[:-2] if k.endswith("_s") else k: float(v)
                  for k, v in reg.counters_under("phase.").items()}
        m = cls(
            role=role,
            wall_s=float(reg.value("serve.wall_s")),
            prefill_s=phases.get("compose", 0.0) + phases.get("prefill", 0.0),
            decode_s=phases.get("decode_step", 0.0),
            n_requests=int(reg.value("serve.requests")),
            n_new_tokens=int(reg.value("serve.new_tokens")),
            kv_bytes_loaded=int(reg.value("serve.kv_bytes_composed")),
            latencies_s=[float(x)
                         for x in reg.hist_values("request.latency_s")],
            ttft_s=[float(x) for x in reg.hist_values("request.ttft_s")],
            phase_s=phases,
            flash_bytes_loaded=int(reg.value("serve.flash_bytes")),
            flash_bytes_per_request=[
                int(x) for x in reg.hist_values("request.flash_bytes")],
            chunk_hits=int(reg.value("serve.chunk_hits")),
            chunk_misses=int(reg.value("serve.chunk_misses")),
            flash_read_s=[float(x)
                          for x in reg.hist_values("serve.flash_read_s")],
            load_overlap_frac=float(reg.value("serve.load_overlap_frac")),
            hbm_kv_bytes_resident=int(
                reg.peak("pool.hbm_kv_bytes_resident")),
            resident_chunks_peak=int(reg.peak("pool.resident_chunks")),
            n_decode_steps=int(reg.value("decode.steps")),
            decode_kv_bytes_measured=int(
                reg.value("decode.kv_bytes_measured")),
            materialize_s=float(reg.value("phase.materialize_s")),
            n_materialized_tokens=int(reg.value("mat.tokens")),
            n_materialize_jobs=int(reg.value("mat.jobs")),
            flash_bytes_written=int(reg.value("mat.flash_bytes_written")),
        )
        return m

    # -- serialization -------------------------------------------------------

    def as_dict(self) -> dict:
        d = asdict(self)
        d["schema"] = METRICS_SCHEMA
        # derived rates included read-only for results.jsonl consumers;
        # from_dict drops them (they recompute from the fields)
        d["derived"] = {
            "tokens_per_s": self.tokens_per_s,
            "decode_tokens_per_s": self.decode_tokens_per_s,
            "materialize_tokens_per_s": self.materialize_tokens_per_s,
            "chunk_hit_rate": self.chunk_hit_rate,
            "p50_latency_s": self.p50_latency_s,
            "p95_latency_s": self.p95_latency_s,
            "p50_ttft_s": self.p50_ttft_s,
            "p95_ttft_s": self.p95_ttft_s,
        }
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "ServeMetrics":
        d = dict(d)
        schema = d.pop("schema", None)
        if schema != METRICS_SCHEMA:
            raise ValueError(f"unknown ServeMetrics schema {schema!r} "
                             f"(expected {METRICS_SCHEMA})")
        d.pop("derived", None)
        return cls(**d)
