"""Serving metrics, split per role (DESIGN.md §14).

``ServeMetrics`` lives here (not in ``continuous.py``) so the role facades
in ``serving/roles.py`` can account against it without importing the
scheduler. A disaggregated deployment runs materialization and decode on
different hardware with different clocks, so the blended
``tokens_per_s = n_new_tokens / wall_s`` is misleading there — use the
per-role rates:

* ``materialize_tokens_per_s`` — chunk tokens whose KV was computed and
  durably written to flash, over the time spent doing only that.
* ``decode_tokens_per_s`` — new tokens emitted over the time spent inside
  decode steps (the number a weak decode mesh must hold while the
  materializer fleet scales).

``tokens_per_s`` stays for the composed single-process path ("both" role),
where one wall clock is the honest end-to-end number.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List

import numpy as np


@dataclass
class ServeMetrics:
    role: str = "both"                     # "materialize" | "decode" | "both"
    wall_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    n_requests: int = 0
    n_new_tokens: int = 0
    kv_bytes_loaded: int = 0               # bytes composed into rows
    latencies_s: List[float] = field(default_factory=list)
    # load-link accounting (fed by the paged pool's dedup stats; the
    # row-slotted path reads every chunk per request, so there hits == 0)
    flash_bytes_loaded: int = 0            # bytes actually read from flash
    flash_bytes_per_request: List[int] = field(default_factory=list)
    chunk_hits: int = 0                    # chunk already GPU-resident
    chunk_misses: int = 0                  # chunk had to be read + inserted
    hbm_kv_bytes_resident: int = 0         # peak KV bytes resident in HBM
    resident_chunks_peak: int = 0          # paged: peak distinct chunks in
                                           # the pool (codec-sensitive: one
                                           # byte budget holds ~2x under int8)
    pool_shard_bytes: List[int] = field(default_factory=list)
                                           # paged: per-device bytes of the
                                           # pool's block tensors (one entry
                                           # on a single device; under a
                                           # serving mesh the entries sum to
                                           # the single-device footprint)
    # materializer-role accounting
    materialize_s: float = 0.0             # time inside materialize calls
    n_materialized_tokens: int = 0         # chunk tokens written to flash
    n_materialize_jobs: int = 0            # jobs processed off the queue
    flash_bytes_written: int = 0           # artifact bytes put to flash

    @property
    def chunk_hit_rate(self) -> float:
        total = self.chunk_hits + self.chunk_misses
        return self.chunk_hits / total if total else 0.0

    @property
    def tokens_per_s(self) -> float:
        """Blended end-to-end rate over one wall clock. Honest only for the
        composed "both" role; disaggregated runs report the per-role rates
        below instead."""
        return self.n_new_tokens / self.wall_s if self.wall_s else 0.0

    @property
    def materialize_tokens_per_s(self) -> float:
        """Chunk tokens durably materialized per second of materializer
        work — the prefill fleet's scaling axis."""
        return (self.n_materialized_tokens / self.materialize_s
                if self.materialize_s else 0.0)

    @property
    def decode_tokens_per_s(self) -> float:
        """New tokens per second of decode-step time — the rate a weak
        decode mesh must hold under a scaling materializer fleet."""
        return self.n_new_tokens / self.decode_s if self.decode_s else 0.0

    def latency_quantile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies_s), q))

    @property
    def p50_latency_s(self) -> float:
        return self.latency_quantile(0.50)

    @property
    def p95_latency_s(self) -> float:
        return self.latency_quantile(0.95)
