"""Role-scoped serving facades for disaggregated MatKV (DESIGN.md §14).

MatKV's second headline result — decode speed is far less sensitive to GPU
grade than KV computation once materialized KVs are loaded — means prefill
and decode capacity should scale on separate axes. This module splits the
engine into the two roles:

``MaterializerWorker``
    Owns ingest / prefill / artifact refresh. Runs chunk prefills on its
    (large) mesh and writes codec-tagged artifacts through the flash store.
    Never touches a decode cache or the paged pool. Drains
    chunk-materialize jobs off the shared ``WorkQueue`` (materialize-on-miss
    requests posted by decode workers), stamping each artifact with a
    monotonically increasing generation and publishing it only after the
    durable flash put.

``DecodeWorker``
    Owns only the paged pool plumbing, the ``AsyncKvLoader``, and the
    decode-step entry points (``step_rows`` / ``step_rows_paged`` / fused).
    Runs no retrieval and no prefill-from-tokens: requests arrive as
    ``HandoffRecord``s on the queue (or explicit chunk id lists), KV bytes
    arrive from flash. Pool pages are keyed ``"<chunk_id>@g<generation>"``
    so a refreshed artifact can never be served from stale resident pages —
    the fresh generation is a pool miss by construction, and the superseded
    key is dropped from the refcount-0 LRU eagerly.

The flash artifact plane plus the ``serving/queue.py`` work queue are the
*sole* interface between the roles: no params, no device buffers, no KV
tensors ever cross directly.

``_DecodePlane`` holds the decode-side implementation (moved verbatim from
the pre-split ``RagEngine``). ``RagEngine`` still inherits it and composes
a ``MaterializerWorker`` over a shared in-process queue — the ``--role
both`` configuration — so the composed engine stays bit-identical to the
monolith on every path: same jitted fns, same compose/prefill/step code,
identity page keys.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.chunking import Chunk, chunk_document
from repro.core.compose import StreamingPrefix, compose_attn_cache_rows
from repro.core.materialize import (Materializer, load_artifact,
                                    load_artifact_encoded)
from repro.core.quantize import get_codec, quantize_kv
from repro.data.tokenizer import ByteTokenizer, SEP
from repro.kvstore.async_loader import AsyncKvLoader
from repro.models.cache import RowAttnCache
from repro.obs import MetricsRegistry, NULL_TRACER
from repro.serving.metrics import ServeMetrics
from repro.serving.queue import MaterializeJob, WorkQueue
from repro.serving.sampling import greedy


@dataclass(eq=False)
class RowRequest:
    """One serving request in row-level form: retrieval done, KV artifacts not
    necessarily loaded yet (a prefetcher fills ``payloads`` asynchronously).
    ``chunk_ids == []`` is a legal query-only request (empty retrieval).
    Identity equality: lifecycle object holding an ndarray prompt."""
    question: str
    max_new_tokens: int
    chunk_ids: List[str]
    prompt: np.ndarray
    payloads: Optional[List[bytes]] = None


def _place_params(params, mesh, rules):
    """Shard params onto ``mesh`` by the repro.dist partition specs."""
    from repro.dist.partition import param_specs, to_shardings
    return jax.device_put(
        params, to_shardings(mesh, param_specs(mesh, params, rules)))


def _serving_rules(rules):
    from repro.dist.sharding import SERVING_RULES
    return {**SERVING_RULES, **(rules or {})}


class _DecodePlane:
    """The decode-side serving surface, shared by ``RagEngine`` (composed
    "both" role) and ``DecodeWorker`` (standalone decode role).

    Hosts expect on ``self``: model, cfg, params, codec, tok, reader, store,
    mesh, rules, rerotate, chunk_tokens, top_k — plus the jit caches set up
    by ``_init_decode_plane``. Pool entries are looked up through
    ``page_key`` (identity here; generation-tagged in ``DecodeWorker``), so
    the composed engine's pool behavior is byte-for-byte the pre-split
    monolith's.
    """

    # role interface defaults (the composed engine materializes at ingest,
    # so every artifact it can retrieve is ready by construction)
    role = "both"

    def _init_decode_plane(self):
        # span sink (DESIGN.md §15); constructors may have set one already
        self.tracer = getattr(self, "tracer", None) or NULL_TRACER
        self._decode_fn = jax.jit(
            self._meshed(lambda p, c, t: self.model.decode_step(p, c, t)))
        self._subprefill_fns = {}
        # row-slotted step (continuous batching); jit retraces per shape
        self._row_step_fn = jax.jit(
            self._meshed(lambda p, c, t: self.model.decode_step_rows(p, c, t)))
        # streaming admission (DESIGN.md §16): layer-0 prompt queries + the
        # carry-finalizing streamed step; both retrace per prompt shape
        self._q0_fn = jax.jit(
            self._meshed(lambda p, t, n: self.model.streaming_prompt_q0(
                p, t, n)))
        self._streamed_step_fn = jax.jit(
            self._meshed(
                lambda p, c, t, q0, m, l, acc:
                self.model.decode_step_rows_streamed(p, c, t, q0, m, l, acc)))
        # fused paged steps, keyed by (table width, codec, pool geometry)
        self._fused_step_fns = {}
        # chunk_id -> last generation-tagged pool key this worker installed
        # (stale-generation eviction; empty under identity page keys)
        self._prev_page_key: Dict[str, str] = {}

    def _meshed(self, fn):
        """Wrap a model fn so jit TRACING runs under the engine's mesh
        context — the ``shard()`` constraints in the model code read the
        active (mesh, rules) pair at trace time. Identity without a mesh."""
        if self.mesh is None:
            return fn
        from repro.dist.sharding import mesh_context
        mesh, rules = self.mesh, self.rules

        def wrapped(*args):
            with mesh_context(mesh, rules):
                return fn(*args)
        return wrapped

    # -- role interface ----------------------------------------------------------
    def page_key(self, chunk_id: str) -> str:
        """Pool-entry key for a chunk's resident pages. Identity for the
        composed engine; ``DecodeWorker`` tags it with the artifact
        generation so refreshed chunks never alias stale pages."""
        return chunk_id

    def artifact_ready(self, chunk_id: str) -> bool:
        """Whether the chunk's flash artifact exists (and so a load can be
        issued). The composed engine materializes at ingest, so anything
        retrievable is ready."""
        return True

    def request_materialize(self, chunk_id: str) -> bool:
        """Ask the materializer role for this chunk's artifact. No-op for
        the composed engine (nothing is ever missing)."""
        return False

    # -- helpers -----------------------------------------------------------------
    def _pad_chunk(self, tokens: np.ndarray) -> np.ndarray:
        out = np.zeros((self.chunk_tokens,), np.int32)
        out[:len(tokens)] = tokens
        return out

    def _prompt(self, question: str) -> np.ndarray:
        return np.concatenate([[SEP], self.tok.encode(" " + question + " "),
                               [SEP]]).astype(np.int32)

    def _subprefill(self, cache, query: jnp.ndarray):
        key = (query.shape, type(cache).__name__)
        if key not in self._subprefill_fns:
            self._subprefill_fns[key] = jax.jit(
                self._meshed(lambda p, c, t: self.model.decode_step(p, c, t)))
        return self._subprefill_fns[key](self.params, cache, query)

    def _decode_loop(self, cache, first_token, max_new_tokens: int
                     ) -> Tuple[List[np.ndarray], object]:
        toks = [np.asarray(first_token)]
        cur = first_token
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode_fn(self.params, cache, cur[:, None])
            cur = greedy(logits[:, -1])
            toks.append(np.asarray(cur))
        return toks, cache

    # -- row-level request API (shared by both schedulers) -----------------------
    #
    # The lifecycle a scheduler drives:
    #   req  = engine.prepare_request(q, max_new)        # retrieval only
    #   ...payloads prefetched into req.payloads (AsyncKvLoader) or fetched
    #      synchronously via engine.fetch_payloads(req)...
    #   row, n_doc, nbytes = engine.compose_row(req, buf_size)
    #   first, row = engine.prefill_row(row, req.prompt)  # admit
    #   logits, cache = engine.step_rows(cache, tokens)   # batched decode
    #
    # compose/prefill run at batch=1 (ragged prompt lengths); step_rows runs
    # the whole slot table in one fixed-shape call.

    def prepare_request(self, question: str, max_new_tokens: int = 20,
                        chunk_ids: Optional[Sequence[str]] = None
                        ) -> RowRequest:
        """Retrieve for one request; no KV bytes are read yet."""
        cids = list(self.retrieve(question) if chunk_ids is None
                    else chunk_ids)
        if not cids:
            warnings.warn(f"retrieval returned no chunks for {question!r}; "
                          f"serving query-only")
        return RowRequest(question=question, max_new_tokens=max_new_tokens,
                          chunk_ids=cids, prompt=self._prompt(question))

    def fetch_payloads(self, req: RowRequest) -> int:
        """Synchronously read the request's KV payloads (the non-overlapped
        path); returns bytes read. No-op if a prefetcher already filled them."""
        if req.payloads is None:
            req.payloads = [self.reader.get(c) for c in req.chunk_ids]
        return sum(len(p) for p in req.payloads)

    def compose_row(self, req: RowRequest, buf_size: int
                    ) -> Tuple[RowAttnCache, int, int]:
        """Deserialize + compose one request's artifacts into a batch=1
        row-slotted cache. Returns (row_cache, n_doc_tokens, bytes_loaded).
        Empty retrieval composes an empty row (query-only)."""
        if self.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("row-slotted serving requires an attention-KV "
                             f"family, got {self.cfg.family}")
        nbytes = self.fetch_payloads(req)
        arts = [load_artifact(self.cfg, p)[0] for p in req.payloads]
        cache = compose_attn_cache_rows(self.cfg, [arts], buf_size,
                                        rerotate=self.rerotate)
        return cache, int(cache.length[0]), nbytes

    def prefill_row(self, row_cache: RowAttnCache, prompt: np.ndarray
                    ) -> Tuple[jnp.ndarray, RowAttnCache]:
        """Sub-prefill one row's prompt over its composed prefix (batch=1).
        Returns (first_token (1,), updated row_cache)."""
        logits, row_cache = self._row_step_fn(
            self.params, row_cache, jnp.asarray(prompt)[None])
        return greedy(logits[:, -1]), row_cache

    def step_rows(self, cache: RowAttnCache, tokens: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, RowAttnCache]:
        """One batched decode step over the whole slot table: tokens (B,Sq)."""
        return self._row_step_fn(self.params, cache, tokens)

    def init_row_cache(self, batch: int, buf_size: int) -> RowAttnCache:
        """Empty row-slotted cache, placed for this engine's mesh: the KV
        buffers' head axis lands on the model axis (SERVING_RULES), the
        bookkeeping replicates. Without a mesh this is exactly
        ``model.init_row_cache`` — schedulers and parity paths go through
        here so both layouts share one entry point."""
        cache = self.model.init_row_cache(batch, buf_size)
        if self.mesh is None:
            return cache
        from repro.dist.partition import cache_specs, to_shardings
        return jax.device_put(
            cache, to_shardings(self.mesh,
                                cache_specs(self.mesh, cache, self.rules)))

    # -- paged row-level API (page-table serving over a shared block pool) -------
    #
    # Paged counterparts of compose_row / prefill_row / step_rows. KV bytes
    # live once in a ``PagedKvPool``: rows that retrieved the same chunk
    # share its pages (ref-counted); only the prompt/decode tail is private.
    # Every step gathers the dense RowAttnCache *view* through the page
    # table and runs the SAME jitted ``_row_step_fn`` as the row-slotted
    # path, so per-row answers are bit-identical by construction
    # (repro.paged.runtime docstring).

    def init_paged_cache(self, max_slots: int, buf_size: int,
                         block_size: int = 64,
                         n_blocks: Optional[int] = None,
                         pool_budget_bytes: Optional[int] = None,
                         host_tier=None):
        """Build the pool + page-table cache for ``max_slots`` decode slots.

        The pool stores blocks in the engine codec's layout (int8 pages +
        f16 scales under ``Int8Codec``); ``pool_budget_bytes`` sizes
        ``n_blocks`` from an HBM byte budget codec-aware, so one budget
        holds ~2x the chunks under int8 — the equal-budget comparison the
        quantized-residency benchmark runs.

        Paged mode requires the paper-faithful restarted-positions mode:
        shared chunk pages must be position-independent, and ``rerotate``
        bakes the row-specific global offset into K at compose time.

        Under a serving mesh the pool's block tensors come back KV-head-
        sharded (DESIGN.md §12); block ids and all pool accounting stay
        global, so schedulers drive the sharded pool unchanged.
        """
        from repro.paged import PagedKvPool, PagedRowCache
        if self.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("paged serving requires an attention-KV family, "
                             f"got {self.cfg.family}")
        if self.rerotate:
            raise ValueError("paged serving requires rerotate=False: "
                             "re-rotated keys are position-dependent and "
                             "cannot be shared across rows")
        if n_blocks is None and pool_budget_bytes is not None:
            n_blocks = PagedKvPool.blocks_for_budget(
                self.cfg, pool_budget_bytes, block_size, self.codec)
        if n_blocks is None:
            per_row = -(-buf_size // block_size)
            # scratch + private tail + worst-case unshared chunk pages
            chunk_blocks = -(-self.chunk_tokens // block_size)
            n_blocks = max_slots * (1 + per_row
                                    + self.top_k * chunk_blocks) + 4
        pool = PagedKvPool(self.cfg, n_blocks=n_blocks,
                           block_size=block_size, codec=self.codec,
                           mesh=self.mesh, rules=self.rules,
                           host_tier=host_tier)
        return PagedRowCache(pool, max_slots, buf_size)

    def _drop_stale_generation(self, pool, chunk_id: str, key: str) -> None:
        """Under generation-tagged page keys: when a chunk's current key
        moved past the one this worker last installed, evict the superseded
        entry from the refcount-0 LRU (rows still decoding against it keep
        their refs — only an unreferenced stale copy is dropped)."""
        if key == chunk_id:
            return
        prev = self._prev_page_key.get(chunk_id)
        if prev is not None and prev != key:
            pool.drop_if_unreferenced(prev)
        self._prev_page_key[chunk_id] = key

    def compose_row_paged(self, req: RowRequest, pcache, slot: int,
                          payloads: Optional[Dict[str, bytes]] = None
                          ) -> Tuple[int, int, int, int, int]:
        """Install one request's page table into ``slot``: acquire (or
        insert) each chunk's shared pages, allocate the private tail, and
        build the gather row. ``payloads`` maps chunk_id -> serialized
        artifact for chunks the caller prefetched; chunks in neither the
        pool nor ``payloads`` are read synchronously (the fallback for
        pages reclaimed while the request queued). Returns (n_doc_tokens,
        flash_bytes_loaded, composed_bytes, chunk_hits, chunk_misses) —
        composed_bytes counts every chunk serving the row (hits included),
        comparable to ``compose_row``'s bytes; flash_bytes only the
        misses actually read. Artifacts flow into the pool in *encoded*
        form (``load_artifact_encoded``): an int8 artifact lands in int8
        pages without ever widening on the host. Pool entries are keyed by
        ``page_key`` — identity on the composed engine, generation-tagged
        on a ``DecodeWorker`` so a refreshed chunk is a fresh entry."""
        from repro.paged import RowPages
        pool = pcache.pool
        payloads = payloads or {}
        handle = RowPages()
        nbytes = composed = hits = misses = 0
        gather = pcache.scratch_row(slot)
        pos = 0
        for cid in req.chunk_ids:
            key = self.page_key(cid)
            self._drop_stale_generation(pool, cid, key)
            # ownership transfers to the RowPages handle; every ref taken
            # here is dropped by release_row_paged at row eviction.
            if pool.acquire(key) is not None:  # repro: noqa[RP101]
                hits += 1
            elif pool.promote(key) is not None:
                # host-DRAM mid-tier re-promotion (DESIGN.md §16): a chunk
                # whose pages were reclaimed-and-demoted rehydrates from
                # host bytes with ZERO flash bytes re-read — counted as a
                # hit here (no flash traffic), disambiguated by
                # pool.stats.promotions
                hits += 1
            else:
                payload = payloads.get(cid)
                if payload is None:
                    # reclaimed-while-queued fallback: a synchronous read on
                    # the scheduler thread — worth seeing in a trace
                    with self.tracer.span("flash_read", chunk=cid, sync=True):
                        payload = self.reader.get(cid)
                enc, _ = load_artifact_encoded(self.cfg, payload)
                pool.insert(key, encoded=enc, nbytes=len(payload))
                nbytes += len(payload)
                misses += 1
            composed += pool.chunk_payload_bytes(key)
            handle.chunk_refs.append(key)
            slots = pool.chunk_slot_ids(key)
            if pos + len(slots) > pcache.buf_size:
                raise ValueError(
                    f"compose_row_paged: composed prefix exceeds buf_size "
                    f"{pcache.buf_size} (the row-slotted path would wrap "
                    f"here too — size the buffer for the worst-case row)")
            gather[pos:pos + len(slots)] = slots
            pos += len(slots)
        handle.n_doc = pos
        need = len(req.prompt) + req.max_new_tokens
        if pos + need > pcache.buf_size:
            # the dense path would wrap into the row's own buffer here; a
            # paged row wrapping would scatter decode tokens into SHARED
            # chunk pages and corrupt co-resident requests — hard error
            raise ValueError(
                f"compose_row_paged: prefix {pos} + prompt/decode {need} "
                f"exceeds buf_size {pcache.buf_size}; size the buffer for "
                f"the worst-case row")
        tail = min(need + 4, pcache.buf_size - pos)
        # the private tail belongs to the RowPages handle;
        # release_row_paged frees it at row eviction.
        handle.private_blocks = pool.alloc_private(  # repro: noqa[RP101]
            max(1, tail))
        tail_slots = pool.token_slot_ids(handle.private_blocks,
                                         min(len(handle.private_blocks)
                                             * pool.block_size,
                                             pcache.buf_size - pos))
        handle.tail_slots = tail_slots
        gather[pos:pos + len(tail_slots)] = tail_slots
        pcache.install_row(slot, handle, gather)
        # position state mirrors compose_attn_cache_rows exactly: composed
        # prefix at slots [0, n_doc), -1 padding, per-row length
        spos = np.full((pcache.buf_size,), -1, np.int32)
        spos[:pos] = np.arange(pos, dtype=np.int32)
        pcache.set_row_state(slot, jnp.asarray(spos),
                             jnp.asarray(pos, jnp.int32))
        return pos, nbytes, composed, hits, misses

    def prefill_row_paged(self, pcache, slot: int, prompt: np.ndarray
                          ) -> jnp.ndarray:
        """Sub-prefill one admitted slot's prompt over its paged prefix
        (batch=1): gather the dense row view, run the shared row-step fn,
        scatter the prompt's new KV into the slot's private tail (codec
        dispatch lives in the runtime). Returns the first token (1,)."""
        row = pcache.dense_row_view(slot)
        n_doc = pcache.rows[slot].n_doc
        first, row = self.prefill_row(row, prompt)
        sq = len(prompt)
        # host-side tail map from compose time — no device round-trip
        pcache.scatter_range(pcache.rows[slot].tail_slots[:sq],
                             row.k, row.v, n_doc)
        pcache.set_row_state(slot, row.slot_pos[0], row.length[0])
        return first

    # -- streaming admission (block-granular arrival, DESIGN.md §16) -------------
    #
    # A cold request need not wait for its last page: the scheduler starts
    # per-chunk block streams (AsyncKvLoader.load_stream), the pool grows a
    # per-chunk resident frontier (begin/extend/commit_stream), and the
    # layer-0 prompt-over-document attention folds incrementally into a
    # StreamingPrefix carry — in retrieval-token order — while the loader
    # races the tail. Admission then runs ``prefill_row_streamed``, whose
    # first token matches the all-at-once path (greedy-identical; the carry
    # restates _flash_fwd's exact online body).

    def streaming_supported(self) -> bool:
        """Streamed admission serves dense/vlm full-attention paged mode:
        the layer-0 peel needs a homogeneous scanned stack, and a sliding
        window would mask document slots the carry already folded."""
        return (self.cfg.family in ("dense", "vlm")
                and self.cfg.sliding_window is None and not self.rerotate)

    def begin_streaming_prefix(self, req: RowRequest, n_doc: int,
                               bucket: int = 64) -> StreamingPrefix:
        """Seed a request's carry once its composed-prefix length is known
        (every chunk's token count — resident chunks from the pool, in-
        flight ones from their stream headers)."""
        q0 = self._q0_fn(self.params, jnp.asarray(req.prompt)[None],
                         jnp.asarray([n_doc], jnp.int32))
        return StreamingPrefix.begin(q0, self.cfg.num_kv_heads,
                                     bucket=bucket)

    def feed_streaming_block(self, sp: StreamingPrefix, enc) -> int:
        """Fold one arriving block's layer-0 K/V into the carry. The block
        is decoded exactly as the pool view would decode it (identity for
        bf16; ``dequantize_kv`` math for int8), so the carry consumes the
        same values the all-at-once gather would."""
        dt = jnp.dtype(self.cfg.activation_dtype)
        k = enc.codec.decode(
            jnp.asarray(enc.k[0]),
            None if enc.k_scale is None else jnp.asarray(enc.k_scale[0]), dt)
        v = enc.codec.decode(
            jnp.asarray(enc.v[0]),
            None if enc.v_scale is None else jnp.asarray(enc.v_scale[0]), dt)
        return sp.update(k, v)

    def feed_streaming_resident(self, sp: StreamingPrefix, pool,
                                key: str) -> int:
        """Fold a pool-resident chunk's layer-0 pages into the carry (the
        warm-chunk path: no flash bytes, values straight off the pool in
        the same decode the dense gather performs)."""
        slots = jnp.asarray(pool.chunk_slot_ids(key))
        k0 = jnp.take(pool.k[0], slots, axis=0)
        v0 = jnp.take(pool.v[0], slots, axis=0)
        if pool.k_scale is not None:
            ks = jnp.take(pool.k_scale[0], slots, axis=0)
            vs = jnp.take(pool.v_scale[0], slots, axis=0)
            k0 = (k0.astype(jnp.float32)
                  * ks.astype(jnp.float32)[..., None]).astype(pool.dtype)
            v0 = (v0.astype(jnp.float32)
                  * vs.astype(jnp.float32)[..., None]).astype(pool.dtype)
        return sp.update(k0, v0)

    def prefill_row_streamed(self, pcache, slot: int, prompt: np.ndarray,
                             sp: StreamingPrefix) -> jnp.ndarray:
        """Streamed counterpart of ``prefill_row_paged``: same gather /
        scatter / row-state bookkeeping, but the row step consumes the
        already-folded layer-0 carry instead of recomputing the document
        attention the stream already paid for."""
        row = pcache.dense_row_view(slot)
        n_doc = pcache.rows[slot].n_doc
        logits, row = self._streamed_step_fn(
            self.params, row, jnp.asarray(prompt)[None],
            sp.q0, sp.m, sp.l, sp.acc)
        first = greedy(logits[:, -1])
        sq = len(prompt)
        pcache.scatter_range(pcache.rows[slot].tail_slots[:sq],
                             row.k, row.v, n_doc)
        pcache.set_row_state(slot, row.slot_pos[0], row.length[0])
        return first

    def fused_step_supported(self, tokens: jnp.ndarray) -> bool:
        """Whether the fused single-launch kernel can serve this step.
        Unsupported shapes (multi-token steps, sliding-window configs, a
        mesh the KV-head count doesn't divide) fall back to the three-phase
        pipeline — same answers, three HBM round trips."""
        if tokens.shape[1] != 1:
            return False
        if self.cfg.sliding_window is not None:
            return False
        if (self.mesh is not None and "model" in self.mesh.shape
                and self.cfg.num_kv_heads % self.mesh.shape["model"] != 0):
            return False
        return True

    def _fused_step_fn(self, pcache, n_max: int):
        """Jitted fused paged step for one (table width, codec, geometry)
        key: run ``decode_step_rows_fused`` (one kernel launch per layer),
        then advance slot_pos/length and persist the new token through the
        gather table — bit-identical bookkeeping to
        ``scatter_decode_token(_quant)``, but at token granularity instead
        of a full dense-buffer scatter."""
        from repro.kernels.ops import _interpret_default
        quantized = pcache.quantized
        buf_size = pcache.buf_size
        block_size = pcache.pool.block_size
        key = (n_max, quantized, buf_size, block_size)
        if key in self._fused_step_fns:
            return self._fused_step_fns[key]
        interpret = _interpret_default()
        mesh = self.mesh

        def fn(params, pool_k, pool_v, k_scale, v_scale, length, slot_pos,
               gather_idx, tokens, tables, lens, totals):
            logits, k_new, v_new = self.model.decode_step_rows_fused(
                params, pool_k, pool_v, k_scale, v_scale, length, tokens,
                tables, lens, totals, buf_size=buf_size,
                block_size=block_size, interpret=interpret, mesh=mesh)
            order_pos = length[:, None].astype(jnp.int32)
            start = (length % buf_size).astype(jnp.int32)
            spos = jax.vmap(
                lambda sp, op, st: jax.lax.dynamic_update_slice(
                    sp, op.astype(jnp.int32), (st,)))(
                slot_pos, order_pos, start)
            phys = jnp.take_along_axis(gather_idx, start[:, None],
                                       axis=1)[:, 0]
            if quantized:
                qk, sk = quantize_kv(k_new)
                qv, sv = quantize_kv(v_new)
                pool_k = pool_k.at[:, phys].set(qk)
                pool_v = pool_v.at[:, phys].set(qv)
                k_scale = k_scale.at[:, phys].set(
                    sk[..., 0].astype(k_scale.dtype))
                v_scale = v_scale.at[:, phys].set(
                    sv[..., 0].astype(v_scale.dtype))
            else:
                pool_k = pool_k.at[:, phys].set(k_new.astype(pool_k.dtype))
                pool_v = pool_v.at[:, phys].set(v_new.astype(pool_v.dtype))
            return (logits, pool_k, pool_v, k_scale, v_scale, spos,
                    length + 1)

        donate = (1, 2, 3, 4) if quantized else (1, 2)
        self._fused_step_fns[key] = jax.jit(self._meshed(fn),
                                            donate_argnums=donate)
        return self._fused_step_fns[key]

    def step_rows_paged(self, pcache, tokens: jnp.ndarray,
                        fused: Optional[bool] = None) -> jnp.ndarray:
        """One batched decode step over the whole paged slot table.

        ``fused=True`` serves the step as ONE Pallas launch per layer
        (``kernels.paged_decode_fused``): KV pages stream from HBM exactly
        once, straight through the block table, and the only write-back is
        the new token itself. Steps the kernel can't express (see
        ``fused_step_supported``) silently fall back. ``fused=None/False``
        keeps the three-phase gather -> (shared) step_rows -> scatter
        pipeline — the parity oracle and the stable low-level API default.
        Returns logits (B,Sq,V)."""
        if fused and self.fused_step_supported(tokens):
            # host-built block tables; raises on a shared-page append hazard
            tables, lens, totals, n_max = pcache.step_tables()
            fn = self._fused_step_fn(pcache, n_max)
            pool = pcache.pool
            (logits, pool.k, pool.v, pool.k_scale, pool.v_scale,
             pcache.slot_pos, pcache.length) = fn(
                self.params, pool.k, pool.v, pool.k_scale, pool.v_scale,
                pcache.length, pcache.slot_pos, pcache.gather_idx, tokens,
                tables, lens, totals)
            pcache.note_step()
            return logits
        cache = pcache.dense_view()
        prev_len = cache.length
        logits, new_cache = self.step_rows(cache, tokens)
        pcache.scatter_step(prev_len, new_cache.k, new_cache.v)
        pcache.slot_pos = new_cache.slot_pos
        pcache.length = new_cache.length
        pcache.note_step()
        return logits

    def release_row_paged(self, pcache, slot: int) -> None:
        """Retire a slot: decref shared pages, free the private tail."""
        pcache.release_row(slot)


class MaterializerWorker:
    """The materializer role: chunk prefill + artifact refresh, nothing else.

    Owns its own params placement (potentially a large prefill mesh), a
    chunk registry (chunk_id -> token content), and the ``Materializer``
    write path. Artifacts it writes carry a ``generation`` meta tag drawn
    from the shared ``WorkQueue``; the generation is published to the queue
    only *after* ``store.put`` returns (the put is atomic + durable), so a
    decode worker that observes generation g can always load g's bytes.

    ``process_jobs`` drains chunk-materialize jobs decode workers posted on
    artifact misses — the materialize-on-miss path that keeps a cold chunk
    from stalling a decode mesh.
    """

    role = "materialize"

    def __init__(self, model, params, store, *, codec=None,
                 chunk_tokens: int = 256, queue: Optional[WorkQueue] = None,
                 mesh=None, rules=None, place_params: bool = True,
                 tracer=None):
        self.model = model
        self.cfg = model.cfg
        self.store = store
        self.chunk_tokens = chunk_tokens
        self.queue = queue
        self.mesh = mesh
        if mesh is not None:
            self.rules = _serving_rules(rules)
            if place_params:
                params = _place_params(params, mesh, self.rules)
        else:
            self.rules = None
        self.params = params
        self.codec = get_codec(codec)
        self.tok = ByteTokenizer()
        self.tracer = tracer or NULL_TRACER
        self.materializer = Materializer(model, self.params, store,
                                         codec=self.codec,
                                         tracer=self.tracer)
        self._chunks: Dict[str, Chunk] = {}
        # accounting goes through the obs registry; ``metrics`` below is a
        # derived view (DESIGN.md §15)
        self.registry = MetricsRegistry()

    @property
    def metrics(self) -> ServeMetrics:
        return ServeMetrics.from_registry(self.registry, role="materialize")

    # -- chunk registry ----------------------------------------------------------
    def register_chunk(self, chunk: Chunk) -> None:
        self._chunks[chunk.chunk_id] = chunk

    def chunk(self, chunk_id: str) -> Chunk:
        return self._chunks[chunk_id]

    # -- write path --------------------------------------------------------------
    def materialize(self, chunk: Chunk, reason: str = "ingest") -> int:
        """Prefill one chunk and write its artifact; returns the generation
        stamped into the artifact meta. Publish happens strictly after the
        durable put — the queue never names a generation whose bytes could
        be lost to a crash between compute and rename."""
        self.register_chunk(chunk)
        t0 = time.perf_counter()
        with self.tracer.span("materialize", chunk=chunk.chunk_id,
                              reason=reason):
            gen = (self.queue.next_generation(chunk.chunk_id)
                   if self.queue is not None else 0)
            nbytes = self.materializer.ingest(chunk,
                                              extra_meta={"generation": gen})
            if self.queue is not None:
                self.queue.publish(chunk.chunk_id, gen)
        reg = self.registry
        reg.counter("phase.materialize_s").inc(time.perf_counter() - t0)
        reg.counter("mat.tokens").inc(len(chunk))
        reg.counter("mat.flash_bytes_written").inc(nbytes)
        return gen

    def refresh(self, chunk_id: str) -> int:
        """Re-materialize a registered chunk (params/codec changed): same
        chunk id, next generation."""
        return self.materialize(self._chunks[chunk_id], reason="refresh")

    def ingest_document(self, doc_id: str, text: str) -> List[str]:
        """Chunk + register + materialize a document; returns chunk ids.
        Chunks whose artifact already exists on flash are registered but
        not recomputed (content-hashed ids make re-ingest idempotent)."""
        toks = self.tok.encode(text)
        ids = []
        for c in chunk_document(doc_id, toks, self.chunk_tokens):
            self.register_chunk(c)
            if not self.store.exists(c.chunk_id):
                self.materialize(c)
            elif (self.queue is not None
                  and self.queue.generation(c.chunk_id) is None):
                # artifact predates this worker: announce it as gen 0
                self.queue.publish(c.chunk_id, 0)
            ids.append(c.chunk_id)
        return ids

    # -- queue drain -------------------------------------------------------------
    def process_jobs(self, max_jobs: Optional[int] = None) -> int:
        """Drain materialize jobs off the queue; returns jobs processed.
        A job for an unregistered chunk is a deployment error (the decode
        role cannot supply tokens — it never sees them)."""
        if self.queue is None:
            return 0
        done = 0
        while max_jobs is None or done < max_jobs:
            job = self.queue.next_job()
            if job is None:
                break
            chunk = self._chunks.get(job.chunk_id)
            if chunk is None:
                raise KeyError(
                    f"materializer has no registered chunk for job "
                    f"{job.chunk_id!r} (reason={job.reason}); ingest the "
                    f"document on the materializer role first")
            self.registry.counter("mat.jobs").inc()
            self.materialize(chunk, reason=job.reason)
            done += 1
        return done


class DecodeWorker(_DecodePlane):
    """The decode role: paged pool + loader + decode steps, nothing else.

    Never prefills document tokens, never writes flash, runs no retrieval —
    requests arrive as ``HandoffRecord``s on the shared queue (or explicit
    ``chunk_ids``), KV bytes arrive from the flash artifact plane through
    ``AsyncKvLoader``. Missing artifacts become materialize jobs on the
    queue (``request_materialize``) so a cold chunk costs queue latency on
    one request instead of a prefill stall on the decode mesh.

    With a queue, pool pages are keyed ``"<chunk_id>@g<generation>"``: a
    refreshed artifact (same content-hashed chunk id, new params or codec)
    is a different pool entry, so a mid-refresh mix of stale resident pages
    and fresh flash bytes is impossible by construction.
    """

    role = "decode"
    mode = "matkv"                  # schedulers validate against this

    def __init__(self, model, params, store, *, codec=None,
                 chunk_tokens: int = 256, top_k: int = 2, reader=None,
                 queue: Optional[WorkQueue] = None, mesh=None, rules=None,
                 rerotate: bool = False, n_load_workers: int = 4,
                 place_params: bool = True, tracer=None):
        if model.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("DecodeWorker serves attention-KV families "
                             f"only, got {model.cfg.family}")
        self.model = model
        self.cfg = model.cfg
        self.store = store
        self.reader = reader or store
        self.chunk_tokens = chunk_tokens
        self.top_k = top_k
        self.rerotate = rerotate
        self.queue = queue
        self.mesh = mesh
        if mesh is not None:
            self.rules = _serving_rules(rules)
            if place_params:
                params = _place_params(params, mesh, self.rules)
        else:
            self.rules = None
        self.params = params
        self.codec = get_codec(codec)
        self.tok = ByteTokenizer()
        self.tracer = tracer or NULL_TRACER
        self.loader = AsyncKvLoader(self.reader, n_workers=n_load_workers,
                                    tracer=self.tracer)
        self.metrics = ServeMetrics(role="decode")
        self._init_decode_plane()

    # -- role interface ----------------------------------------------------------
    def page_key(self, chunk_id: str) -> str:
        if self.queue is None:
            return chunk_id
        gen = self.queue.generation(chunk_id)
        return chunk_id if gen is None else f"{chunk_id}@g{gen}"

    def artifact_ready(self, chunk_id: str) -> bool:
        return self.store.exists(chunk_id)

    def request_materialize(self, chunk_id: str) -> bool:
        if self.queue is None:
            raise LookupError(
                f"no artifact for {chunk_id!r} and no work queue to post a "
                f"materialize job on")
        return self.queue.submit_job(MaterializeJob(chunk_id, reason="miss"))

    def prepare_request(self, question: str, max_new_tokens: int = 20,
                        chunk_ids: Optional[Sequence[str]] = None
                        ) -> RowRequest:
        """Resolve a request WITHOUT retrieval: explicit ``chunk_ids`` win;
        otherwise the question's oldest ``HandoffRecord`` on the queue is
        consumed. No record means the front-end never handed the request
        off — a deployment error, not a silent query-only answer."""
        if chunk_ids is None:
            rec = (self.queue.take_handoff(question)
                   if self.queue is not None else None)
            if rec is None:
                raise LookupError(
                    f"DecodeWorker runs no retrieval: no HandoffRecord "
                    f"queued for {question!r} (submit one, or pass "
                    f"chunk_ids explicitly)")
            chunk_ids = rec.chunk_ids
        cids = list(chunk_ids)
        if not cids:
            warnings.warn(f"empty chunk list for {question!r}; serving "
                          f"query-only")
        return RowRequest(question=question, max_new_tokens=max_new_tokens,
                          chunk_ids=cids, prompt=self._prompt(question))

    def shutdown(self) -> None:
        self.loader.shutdown()
