"""Token sampling strategies for the decode loop."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jnp.ndarray) -> jnp.ndarray:
    """logits (B, V) -> (B,) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature_sample(key, logits: jnp.ndarray, temperature: float = 1.0,
                       top_k: int = 0) -> jnp.ndarray:
    lf = logits.astype(jnp.float32) / max(temperature, 1e-6)
    if top_k:
        thresh = jax.lax.top_k(lf, top_k)[0][..., -1:]
        lf = jnp.where(lf < thresh, -1e30, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
