"""Cross-role work queue for disaggregated serving (DESIGN.md §14).

The materializer and decode roles share exactly two things: the flash
artifact plane (``FlashKVStore`` / ``TieredStore``) and this queue. Nothing
else crosses the role boundary — no params, no device buffers, no Python
objects holding KV. The queue carries three kinds of state:

* **Materialize jobs** (``MaterializeJob``): "chunk X needs an artifact".
  Posted by ingest pipelines and by the decode role when admission finds a
  chunk with no flash artifact (materialize-on-miss). Jobs carry only the
  chunk id + a reason — the materializer resolves token content from its
  own chunk registry, so the decode role never needs to see tokens.
* **Request hand-off records** (``HandoffRecord``): a front-end's finished
  retrieval for one request — question, chunk ids, decode budget, and the
  artifact generations the retrieval saw. A decode-role worker serves
  requests from these records instead of running retrieval itself.
* **Artifact generations**: a monotonically increasing integer per chunk
  id, bumped by the materializer every time it (re-)writes the chunk's
  artifact and published here only *after* the durable flash put. The
  decode role keys its resident pool pages by ``(chunk_id, generation)``
  (``DecodeWorker.page_key``), so a refreshed artifact — new params, codec
  migration — can never be served from stale resident pages: the new
  generation is a pool miss by construction, and old-generation pages age
  out of the refcount-0 LRU.

In one process the queue is a lock-guarded object shared by both workers
(``RagEngine`` wires one through its internal facades). Across processes
the JSON manifest (``save``/``load``) carries the generation table and any
unconsumed jobs/hand-offs through the filesystem — the launcher's
``--role materialize`` then ``--role decode`` flow; a deployment would back
the same interface with a real queue service.
"""

from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional


@dataclass(eq=False)
class MaterializeJob:
    """One chunk that needs (re-)materialization. ``reason`` is one of
    ``"ingest"`` / ``"miss"`` / ``"refresh"`` — accounting only."""
    chunk_id: str
    reason: str = "ingest"
    doc_id: Optional[str] = None


@dataclass(eq=False)
class HandoffRecord:
    """A front-end's retrieval result handed to the decode role.
    ``generations`` snapshots the artifact generation the front-end saw per
    chunk id (decode admits against the *current* table — a refresh landing
    between hand-off and admit simply serves the fresher artifact)."""
    question: str
    chunk_ids: List[str]
    max_new_tokens: int = 20
    generations: Dict[str, int] = field(default_factory=dict)


class WorkQueue:
    """Thread-safe in-process work queue + generation registry."""

    def __init__(self, tracer=None):
        from repro.obs import NULL_TRACER
        self._lock = threading.Lock()
        # queue events are instants (no duration): who posted/consumed what
        # crosses the role boundary, stamped on whichever role's tracer is
        # attached (assignable post-construction)
        self.tracer = tracer or NULL_TRACER
        self._jobs: "deque[MaterializeJob]" = deque()
        self._queued_ids: set = set()      # dedup: one open job per chunk
        self._handoffs: "deque[HandoffRecord]" = deque()
        self._generations: Dict[str, int] = {}

    # -- materialize jobs -------------------------------------------------------
    def submit_job(self, job: MaterializeJob) -> bool:
        """Queue a materialize job; returns False if the chunk already has
        an open job (K decode workers missing one cold chunk cost one
        materialization, mirroring the loader's in-flight read dedup)."""
        with self._lock:
            if job.chunk_id in self._queued_ids:
                return False
            self._queued_ids.add(job.chunk_id)
            self._jobs.append(job)
        self.tracer.instant("queue_job", chunk=job.chunk_id,
                            reason=job.reason)
        return True

    def next_job(self) -> Optional[MaterializeJob]:
        with self._lock:
            if not self._jobs:
                return None
            job = self._jobs.popleft()
            self._queued_ids.discard(job.chunk_id)
            return job

    @property
    def n_jobs(self) -> int:
        with self._lock:
            return len(self._jobs)

    # -- request hand-off -------------------------------------------------------
    def submit_handoff(self, rec: HandoffRecord) -> None:
        with self._lock:
            self._handoffs.append(rec)
        self.tracer.instant("queue_handoff", question=rec.question,
                            chunks=len(rec.chunk_ids))

    def take_handoff(self, question: Optional[str] = None
                     ) -> Optional[HandoffRecord]:
        """Pop the oldest hand-off record — or, with ``question``, the
        oldest record for that question (duplicate questions are distinct
        requests and resolve FIFO)."""
        with self._lock:
            if question is None:
                return self._handoffs.popleft() if self._handoffs else None
            for i, rec in enumerate(self._handoffs):
                if rec.question == question:
                    del self._handoffs[i]
                    return rec
            return None

    @property
    def n_handoffs(self) -> int:
        with self._lock:
            return len(self._handoffs)

    # -- artifact generations ---------------------------------------------------
    def generation(self, chunk_id: str) -> Optional[int]:
        """Currently published generation for a chunk, or None if the
        materializer has never announced an artifact for it."""
        with self._lock:
            return self._generations.get(chunk_id)

    def next_generation(self, chunk_id: str) -> int:
        """The generation a re-materialization should stamp into its
        artifact meta (current + 1; 0 for a first materialization). The
        materializer writes the artifact with this tag FIRST and calls
        ``publish`` after the durable flash put — so a published generation
        always has its artifact on flash."""
        with self._lock:
            cur = self._generations.get(chunk_id)
            return 0 if cur is None else cur + 1

    def publish(self, chunk_id: str, generation: int) -> None:
        """Announce a durably-stored artifact generation. Monotonic: a
        stale publish (concurrent materializers racing) never rolls the
        table backward."""
        with self._lock:
            cur = self._generations.get(chunk_id, -1)
            if generation > cur:
                self._generations[chunk_id] = generation
        self.tracer.instant("queue_publish", chunk=chunk_id,
                            generation=generation)

    def generations_snapshot(self, chunk_ids) -> Dict[str, int]:
        with self._lock:
            return {c: self._generations[c] for c in chunk_ids
                    if c in self._generations}

    # -- manifest persistence (the cross-process form) --------------------------
    def to_manifest(self) -> dict:
        with self._lock:
            return {
                "generations": dict(self._generations),
                "jobs": [{"chunk_id": j.chunk_id, "reason": j.reason,
                          "doc_id": j.doc_id} for j in self._jobs],
                "handoffs": [{"question": h.question,
                              "chunk_ids": list(h.chunk_ids),
                              "max_new_tokens": h.max_new_tokens,
                              "generations": dict(h.generations)}
                             for h in self._handoffs],
            }

    @classmethod
    def from_manifest(cls, manifest: dict) -> "WorkQueue":
        q = cls()
        q._generations = {k: int(v)
                          for k, v in manifest.get("generations", {}).items()}
        for j in manifest.get("jobs", []):
            q.submit_job(MaterializeJob(chunk_id=j["chunk_id"],
                                        reason=j.get("reason", "ingest"),
                                        doc_id=j.get("doc_id")))
        for h in manifest.get("handoffs", []):
            q.submit_handoff(HandoffRecord(
                question=h["question"], chunk_ids=list(h["chunk_ids"]),
                max_new_tokens=int(h.get("max_new_tokens", 20)),
                generations={k: int(v)
                             for k, v in h.get("generations", {}).items()}))
        return q

    def save(self, path) -> None:
        Path(path).write_text(json.dumps(self.to_manifest(), indent=1))

    @classmethod
    def load(cls, path) -> "WorkQueue":
        return cls.from_manifest(json.loads(Path(path).read_text()))
