"""The MatKV RAG serving engine (paper Fig. 3b).

Modes:
  vanilla    — full KV recomputation: one prefill over [docs | query], decode.
  matkv      — load materialized chunk KVs from flash, compose, sub-prefill the
               query only, decode. (paper-faithful; ``rerotate=True`` switches
               on the beyond-paper position re-rotation)
  cacheblend — matkv + selective recompute of r=18% of doc tokens (baseline).

Per-request phase timings (load / prefill / decode) mirror the paper's §V-A
latency breakdown. SSM/hybrid archs serve via prefix-state reuse + chained
recompute of later chunks (DESIGN.md §4).
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blend import blend
from repro.core.chunking import Chunk, chunk_document
from repro.core.compose import (compose_attn_cache, compose_attn_cache_rows,
                                compose_hybrid_cache, compose_ssm_cache)
from repro.core.materialize import (Materializer, load_artifact,
                                    load_artifact_encoded)
from repro.core.quantize import get_codec, quantize_kv
from repro.data.tokenizer import EOS, SEP, ByteTokenizer
from repro.models.cache import (AttnCache, RowAttnCache, init_attn_cache,
                                init_hybrid_cache, init_ssm_cache, write_kv)
from repro.retrieval.embed import HashingEmbedder
from repro.retrieval.vectordb import VectorDB
from repro.serving.sampling import greedy


@dataclass
class PhaseTimings:
    load_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    n_doc_tokens: int = 0
    n_new_tokens: int = 0
    kv_bytes_loaded: int = 0

    @property
    def total_s(self) -> float:
        return self.load_s + self.prefill_s + self.decode_s


@dataclass(eq=False)
class RowRequest:
    """One serving request in row-level form: retrieval done, KV artifacts not
    necessarily loaded yet (a prefetcher fills ``payloads`` asynchronously).
    ``chunk_ids == []`` is a legal query-only request (empty retrieval).
    Identity equality: lifecycle object holding an ndarray prompt."""
    question: str
    max_new_tokens: int
    chunk_ids: List[str]
    prompt: np.ndarray
    payloads: Optional[List[bytes]] = None


class RagEngine:
    def __init__(self, model, params, store, mode: str = "matkv",
                 chunk_tokens: int = 256, top_k: int = 2,
                 rerotate: bool = False, blend_ratio: float = 0.18,
                 codec=None, reader=None, mesh=None, rules=None):
        assert mode in ("vanilla", "matkv", "cacheblend")
        self.model = model
        self.cfg = model.cfg
        self.store = store
        self.reader = reader or store          # SimulatedReader for timing runs
        self.mode = mode
        self.chunk_tokens = chunk_tokens
        self.top_k = top_k
        self.rerotate = rerotate
        self.blend_ratio = blend_ratio
        # tensor-parallel serving (DESIGN.md §12): with a mesh, params are
        # placed by the repro.dist partition specs (wk/wv column-parallel
        # onto the model axis), caches and the paged pool shard their
        # KV-HEAD axis under SERVING_RULES (cache_seq off — the sequence
        # layout is the train/prefill artifact story, not decode's), and
        # every jitted step traces inside mesh_context so the shard()
        # constraints in the model code apply. Without a mesh everything
        # below is byte-for-byte the single-device path.
        self.mesh = mesh
        if mesh is not None:
            from repro.dist.partition import param_specs, to_shardings
            from repro.dist.sharding import SERVING_RULES
            self.rules = {**SERVING_RULES, **(rules or {})}
            params = jax.device_put(
                params, to_shardings(mesh,
                                     param_specs(mesh, params, self.rules)))
        else:
            self.rules = None
        self.params = params
        # KV storage codec ("bf16" passthrough / "int8"), end to end: the
        # materializer encodes with it, the paged pool stores its layout,
        # the dense compose paths widen on decode (DESIGN.md §11)
        self.codec = get_codec(codec)
        self.tok = ByteTokenizer()
        self.embedder = HashingEmbedder()
        self.vdb = VectorDB(self.embedder.dim)
        self.materializer = Materializer(model, self.params, store,
                                         codec=self.codec)
        self._chunks: Dict[str, Chunk] = {}
        self._decode_fn = jax.jit(
            self._meshed(lambda p, c, t: self.model.decode_step(p, c, t)))
        self._subprefill_fns = {}
        self._vanilla_fns = {}
        # row-slotted step (continuous batching); jit retraces per shape
        self._row_step_fn = jax.jit(
            self._meshed(lambda p, c, t: self.model.decode_step_rows(p, c, t)))
        # fused paged steps, keyed by (table width, codec, pool geometry)
        self._fused_step_fns = {}

    def _meshed(self, fn):
        """Wrap a model fn so jit TRACING runs under the engine's mesh
        context — the ``shard()`` constraints in the model code read the
        active (mesh, rules) pair at trace time. Identity without a mesh."""
        if self.mesh is None:
            return fn
        from repro.dist.sharding import mesh_context
        mesh, rules = self.mesh, self.rules

        def wrapped(*args):
            with mesh_context(mesh, rules):
                return fn(*args)
        return wrapped

    # -- ingest ------------------------------------------------------------------
    def ingest(self, doc_id: str, text: str) -> List[str]:
        toks = self.tok.encode(text)
        ids = []
        for c in chunk_document(doc_id, toks, self.chunk_tokens):
            self._chunks[c.chunk_id] = c
            self.vdb.add(c.chunk_id, self.embedder.embed_tokens(c.tokens))
            if self.mode != "vanilla" and not self.store.exists(c.chunk_id):
                self.materializer.ingest(c)
            ids.append(c.chunk_id)
        return ids

    def delete(self, chunk_id: str) -> None:
        self.vdb.delete(chunk_id, kv_store=self.store)
        self._chunks.pop(chunk_id, None)

    # -- retrieval ----------------------------------------------------------------
    def retrieve(self, question: str) -> List[str]:
        q = self.embedder.embed_tokens(self.tok.encode(question))
        return [cid for cid, _ in self.vdb.search(q, self.top_k)]

    # -- helpers --------------------------------------------------------------------
    def _pad_chunk(self, tokens: np.ndarray) -> np.ndarray:
        out = np.zeros((self.chunk_tokens,), np.int32)
        out[:len(tokens)] = tokens
        return out

    def _prompt(self, question: str) -> np.ndarray:
        return np.concatenate([[SEP], self.tok.encode(" " + question + " "),
                               [SEP]]).astype(np.int32)

    def _subprefill(self, cache, query: jnp.ndarray):
        key = (query.shape, type(cache).__name__)
        if key not in self._subprefill_fns:
            self._subprefill_fns[key] = jax.jit(
                self._meshed(lambda p, c, t: self.model.decode_step(p, c, t)))
        return self._subprefill_fns[key](self.params, cache, query)

    def _decode_loop(self, cache, first_token, max_new_tokens: int
                     ) -> Tuple[List[np.ndarray], object]:
        toks = [np.asarray(first_token)]
        cur = first_token
        for _ in range(max_new_tokens - 1):
            logits, cache = self._decode_fn(self.params, cache, cur[:, None])
            cur = greedy(logits[:, -1])
            toks.append(np.asarray(cur))
        return toks, cache

    # -- load + compose (the MatKV read path) ---------------------------------------
    def load_and_compose(self, chunk_ids: Sequence[str], buf_size: int,
                         batch_rows: int = 1):
        """Returns (cache, n_doc_tokens, bytes_loaded). One row; rows replicate.

        ``chunk_ids == []`` (empty retrieval) yields an empty cache: the query
        is then served with no document prefix instead of crashing on a
        zero-artifact compose.
        """
        fam = self.cfg.family
        if not chunk_ids:
            if fam in ("dense", "vlm", "moe"):
                cache = init_attn_cache(self.cfg, batch_rows, buf_size)
            elif fam == "ssm":
                cache = init_ssm_cache(self.cfg, batch_rows)
            elif fam == "hybrid":
                cache = init_hybrid_cache(self.cfg, batch_rows, buf_size)
            else:
                raise ValueError(f"engine: unsupported family {fam}")
            return cache, 0, 0
        t_bytes = 0
        artifacts, metas = [], []
        for cid in chunk_ids:
            payload = self.reader.get(cid)
            t_bytes += len(payload)
            art, meta = load_artifact(self.cfg, payload)
            artifacts.append(art)
            metas.append(meta)
        if fam in ("dense", "vlm", "moe"):
            if batch_rows > 1:
                artifacts = [jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a, (a.shape[0], batch_rows) + a.shape[2:]), art)
                    for art in artifacts]
            cache = compose_attn_cache(self.cfg, artifacts, buf_size,
                                       rerotate=self.rerotate)
            n_doc = int(cache.length)
        elif fam == "ssm":
            # prefix reuse of chunk 1; chain-recompute chunks 2..k
            n_doc = metas[0]["n_tokens"]
            cache = compose_ssm_cache(self.cfg, artifacts[0], n_doc)
            for cid, meta in zip(chunk_ids[1:], metas[1:]):
                toks = jnp.asarray(self._chunks[cid].tokens)[None]
                _, cache = self._subprefill(cache, toks)
                n_doc += meta["n_tokens"]
        elif fam == "hybrid":
            n_doc = metas[0]["n_tokens"]
            cache = compose_hybrid_cache(self.cfg, artifacts[0], n_doc, buf_size)
            for cid, meta in zip(chunk_ids[1:], metas[1:]):
                toks = jnp.asarray(self._chunks[cid].tokens)[None]
                _, cache = self._subprefill(cache, toks)
                n_doc += meta["n_tokens"]
        else:
            raise ValueError(f"engine: unsupported family {fam}")
        return cache, n_doc, t_bytes

    # -- row-level request API (shared by both schedulers) -----------------------------
    #
    # The lifecycle a scheduler drives:
    #   req  = engine.prepare_request(q, max_new)        # retrieval only
    #   ...payloads prefetched into req.payloads (AsyncKvLoader) or fetched
    #      synchronously via engine.fetch_payloads(req)...
    #   row, n_doc, nbytes = engine.compose_row(req, buf_size)
    #   first, row = engine.prefill_row(row, req.prompt)  # admit
    #   logits, cache = engine.step_rows(cache, tokens)   # batched decode
    #
    # compose/prefill run at batch=1 (ragged prompt lengths); step_rows runs
    # the whole slot table in one fixed-shape call.

    def prepare_request(self, question: str, max_new_tokens: int = 20,
                        chunk_ids: Optional[Sequence[str]] = None
                        ) -> RowRequest:
        """Retrieve for one request; no KV bytes are read yet."""
        cids = list(self.retrieve(question) if chunk_ids is None
                    else chunk_ids)
        if not cids:
            warnings.warn(f"retrieval returned no chunks for {question!r}; "
                          f"serving query-only")
        return RowRequest(question=question, max_new_tokens=max_new_tokens,
                          chunk_ids=cids, prompt=self._prompt(question))

    def fetch_payloads(self, req: RowRequest) -> int:
        """Synchronously read the request's KV payloads (the non-overlapped
        path); returns bytes read. No-op if a prefetcher already filled them."""
        if req.payloads is None:
            req.payloads = [self.reader.get(c) for c in req.chunk_ids]
        return sum(len(p) for p in req.payloads)

    def compose_row(self, req: RowRequest, buf_size: int
                    ) -> Tuple[RowAttnCache, int, int]:
        """Deserialize + compose one request's artifacts into a batch=1
        row-slotted cache. Returns (row_cache, n_doc_tokens, bytes_loaded).
        Empty retrieval composes an empty row (query-only)."""
        if self.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("row-slotted serving requires an attention-KV "
                             f"family, got {self.cfg.family}")
        nbytes = self.fetch_payloads(req)
        arts = [load_artifact(self.cfg, p)[0] for p in req.payloads]
        cache = compose_attn_cache_rows(self.cfg, [arts], buf_size,
                                        rerotate=self.rerotate)
        return cache, int(cache.length[0]), nbytes

    def prefill_row(self, row_cache: RowAttnCache, prompt: np.ndarray
                    ) -> Tuple[jnp.ndarray, RowAttnCache]:
        """Sub-prefill one row's prompt over its composed prefix (batch=1).
        Returns (first_token (1,), updated row_cache)."""
        logits, row_cache = self._row_step_fn(
            self.params, row_cache, jnp.asarray(prompt)[None])
        return greedy(logits[:, -1]), row_cache

    def step_rows(self, cache: RowAttnCache, tokens: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, RowAttnCache]:
        """One batched decode step over the whole slot table: tokens (B,Sq)."""
        return self._row_step_fn(self.params, cache, tokens)

    def init_row_cache(self, batch: int, buf_size: int) -> RowAttnCache:
        """Empty row-slotted cache, placed for this engine's mesh: the KV
        buffers' head axis lands on the model axis (SERVING_RULES), the
        bookkeeping replicates. Without a mesh this is exactly
        ``model.init_row_cache`` — schedulers and parity paths go through
        here so both layouts share one entry point."""
        cache = self.model.init_row_cache(batch, buf_size)
        if self.mesh is None:
            return cache
        from repro.dist.partition import cache_specs, to_shardings
        return jax.device_put(
            cache, to_shardings(self.mesh,
                                cache_specs(self.mesh, cache, self.rules)))

    # -- paged row-level API (page-table serving over a shared block pool) --------------
    #
    # Paged counterparts of compose_row / prefill_row / step_rows. KV bytes
    # live once in a ``PagedKvPool``: rows that retrieved the same chunk
    # share its pages (ref-counted); only the prompt/decode tail is private.
    # Every step gathers the dense RowAttnCache *view* through the page
    # table and runs the SAME jitted ``_row_step_fn`` as the row-slotted
    # path, so per-row answers are bit-identical by construction
    # (repro.paged.runtime docstring).

    def init_paged_cache(self, max_slots: int, buf_size: int,
                         block_size: int = 64,
                         n_blocks: Optional[int] = None,
                         pool_budget_bytes: Optional[int] = None):
        """Build the pool + page-table cache for ``max_slots`` decode slots.

        The pool stores blocks in the engine codec's layout (int8 pages +
        f16 scales under ``Int8Codec``); ``pool_budget_bytes`` sizes
        ``n_blocks`` from an HBM byte budget codec-aware, so one budget
        holds ~2x the chunks under int8 — the equal-budget comparison the
        quantized-residency benchmark runs.

        Paged mode requires the paper-faithful restarted-positions mode:
        shared chunk pages must be position-independent, and ``rerotate``
        bakes the row-specific global offset into K at compose time.

        Under a serving mesh the pool's block tensors come back KV-head-
        sharded (DESIGN.md §12); block ids and all pool accounting stay
        global, so schedulers drive the sharded pool unchanged.
        """
        from repro.paged import PagedKvPool, PagedRowCache
        if self.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("paged serving requires an attention-KV family, "
                             f"got {self.cfg.family}")
        if self.rerotate:
            raise ValueError("paged serving requires rerotate=False: "
                             "re-rotated keys are position-dependent and "
                             "cannot be shared across rows")
        if n_blocks is None and pool_budget_bytes is not None:
            n_blocks = PagedKvPool.blocks_for_budget(
                self.cfg, pool_budget_bytes, block_size, self.codec)
        if n_blocks is None:
            per_row = -(-buf_size // block_size)
            # scratch + private tail + worst-case unshared chunk pages
            chunk_blocks = -(-self.chunk_tokens // block_size)
            n_blocks = max_slots * (1 + per_row
                                    + self.top_k * chunk_blocks) + 4
        pool = PagedKvPool(self.cfg, n_blocks=n_blocks,
                           block_size=block_size, codec=self.codec,
                           mesh=self.mesh, rules=self.rules)
        return PagedRowCache(pool, max_slots, buf_size)

    def compose_row_paged(self, req: RowRequest, pcache, slot: int,
                          payloads: Optional[Dict[str, bytes]] = None
                          ) -> Tuple[int, int, int, int, int]:
        """Install one request's page table into ``slot``: acquire (or
        insert) each chunk's shared pages, allocate the private tail, and
        build the gather row. ``payloads`` maps chunk_id -> serialized
        artifact for chunks the caller prefetched; chunks in neither the
        pool nor ``payloads`` are read synchronously (the fallback for
        pages reclaimed while the request queued). Returns (n_doc_tokens,
        flash_bytes_loaded, composed_bytes, chunk_hits, chunk_misses) —
        composed_bytes counts every chunk serving the row (hits included),
        comparable to ``compose_row``'s bytes; flash_bytes only the
        misses actually read. Artifacts flow into the pool in *encoded*
        form (``load_artifact_encoded``): an int8 artifact lands in int8
        pages without ever widening on the host."""
        from repro.paged import RowPages
        pool = pcache.pool
        payloads = payloads or {}
        handle = RowPages()
        nbytes = composed = hits = misses = 0
        gather = pcache.scratch_row(slot)
        pos = 0
        for cid in req.chunk_ids:
            if pool.acquire(cid) is not None:
                hits += 1
            else:
                payload = payloads.get(cid)
                if payload is None:
                    payload = self.reader.get(cid)
                enc, _ = load_artifact_encoded(self.cfg, payload)
                pool.insert(cid, encoded=enc, nbytes=len(payload))
                nbytes += len(payload)
                misses += 1
            composed += pool.chunk_payload_bytes(cid)
            handle.chunk_refs.append(cid)
            slots = pool.chunk_slot_ids(cid)
            if pos + len(slots) > pcache.buf_size:
                raise ValueError(
                    f"compose_row_paged: composed prefix exceeds buf_size "
                    f"{pcache.buf_size} (the row-slotted path would wrap "
                    f"here too — size the buffer for the worst-case row)")
            gather[pos:pos + len(slots)] = slots
            pos += len(slots)
        handle.n_doc = pos
        need = len(req.prompt) + req.max_new_tokens
        if pos + need > pcache.buf_size:
            # the dense path would wrap into the row's own buffer here; a
            # paged row wrapping would scatter decode tokens into SHARED
            # chunk pages and corrupt co-resident requests — hard error
            raise ValueError(
                f"compose_row_paged: prefix {pos} + prompt/decode {need} "
                f"exceeds buf_size {pcache.buf_size}; size the buffer for "
                f"the worst-case row")
        tail = min(need + 4, pcache.buf_size - pos)
        handle.private_blocks = pool.alloc_private(max(1, tail))
        tail_slots = pool.token_slot_ids(handle.private_blocks,
                                         min(len(handle.private_blocks)
                                             * pool.block_size,
                                             pcache.buf_size - pos))
        handle.tail_slots = tail_slots
        gather[pos:pos + len(tail_slots)] = tail_slots
        pcache.install_row(slot, handle, gather)
        # position state mirrors compose_attn_cache_rows exactly: composed
        # prefix at slots [0, n_doc), -1 padding, per-row length
        spos = np.full((pcache.buf_size,), -1, np.int32)
        spos[:pos] = np.arange(pos, dtype=np.int32)
        pcache.set_row_state(slot, jnp.asarray(spos),
                             jnp.asarray(pos, jnp.int32))
        return pos, nbytes, composed, hits, misses

    def prefill_row_paged(self, pcache, slot: int, prompt: np.ndarray
                          ) -> jnp.ndarray:
        """Sub-prefill one admitted slot's prompt over its paged prefix
        (batch=1): gather the dense row view, run the shared row-step fn,
        scatter the prompt's new KV into the slot's private tail (codec
        dispatch lives in the runtime). Returns the first token (1,)."""
        row = pcache.dense_row_view(slot)
        n_doc = pcache.rows[slot].n_doc
        first, row = self.prefill_row(row, prompt)
        sq = len(prompt)
        # host-side tail map from compose time — no device round-trip
        pcache.scatter_range(pcache.rows[slot].tail_slots[:sq],
                             row.k, row.v, n_doc)
        pcache.set_row_state(slot, row.slot_pos[0], row.length[0])
        return first

    def fused_step_supported(self, tokens: jnp.ndarray) -> bool:
        """Whether the fused single-launch kernel can serve this step.
        Unsupported shapes (multi-token steps, sliding-window configs, a
        mesh the KV-head count doesn't divide) fall back to the three-phase
        pipeline — same answers, three HBM round trips."""
        if tokens.shape[1] != 1:
            return False
        if self.cfg.sliding_window is not None:
            return False
        if (self.mesh is not None and "model" in self.mesh.shape
                and self.cfg.num_kv_heads % self.mesh.shape["model"] != 0):
            return False
        return True

    def _fused_step_fn(self, pcache, n_max: int):
        """Jitted fused paged step for one (table width, codec, geometry)
        key: run ``decode_step_rows_fused`` (one kernel launch per layer),
        then advance slot_pos/length and persist the new token through the
        gather table — bit-identical bookkeeping to
        ``scatter_decode_token(_quant)``, but at token granularity instead
        of a full dense-buffer scatter."""
        from repro.kernels.ops import _interpret_default
        quantized = pcache.quantized
        buf_size = pcache.buf_size
        block_size = pcache.pool.block_size
        key = (n_max, quantized, buf_size, block_size)
        if key in self._fused_step_fns:
            return self._fused_step_fns[key]
        interpret = _interpret_default()
        mesh = self.mesh

        def fn(params, pool_k, pool_v, k_scale, v_scale, length, slot_pos,
               gather_idx, tokens, tables, lens, totals):
            logits, k_new, v_new = self.model.decode_step_rows_fused(
                params, pool_k, pool_v, k_scale, v_scale, length, tokens,
                tables, lens, totals, buf_size=buf_size,
                block_size=block_size, interpret=interpret, mesh=mesh)
            order_pos = length[:, None].astype(jnp.int32)
            start = (length % buf_size).astype(jnp.int32)
            spos = jax.vmap(
                lambda sp, op, st: jax.lax.dynamic_update_slice(
                    sp, op.astype(jnp.int32), (st,)))(
                slot_pos, order_pos, start)
            phys = jnp.take_along_axis(gather_idx, start[:, None],
                                       axis=1)[:, 0]
            if quantized:
                qk, sk = quantize_kv(k_new)
                qv, sv = quantize_kv(v_new)
                pool_k = pool_k.at[:, phys].set(qk)
                pool_v = pool_v.at[:, phys].set(qv)
                k_scale = k_scale.at[:, phys].set(
                    sk[..., 0].astype(k_scale.dtype))
                v_scale = v_scale.at[:, phys].set(
                    sv[..., 0].astype(v_scale.dtype))
            else:
                pool_k = pool_k.at[:, phys].set(k_new.astype(pool_k.dtype))
                pool_v = pool_v.at[:, phys].set(v_new.astype(pool_v.dtype))
            return (logits, pool_k, pool_v, k_scale, v_scale, spos,
                    length + 1)

        donate = (1, 2, 3, 4) if quantized else (1, 2)
        self._fused_step_fns[key] = jax.jit(self._meshed(fn),
                                            donate_argnums=donate)
        return self._fused_step_fns[key]

    def step_rows_paged(self, pcache, tokens: jnp.ndarray,
                        fused: Optional[bool] = None) -> jnp.ndarray:
        """One batched decode step over the whole paged slot table.

        ``fused=True`` serves the step as ONE Pallas launch per layer
        (``kernels.paged_decode_fused``): KV pages stream from HBM exactly
        once, straight through the block table, and the only write-back is
        the new token itself. Steps the kernel can't express (see
        ``fused_step_supported``) silently fall back. ``fused=None/False``
        keeps the three-phase gather -> (shared) step_rows -> scatter
        pipeline — the parity oracle and the stable low-level API default.
        Returns logits (B,Sq,V)."""
        if fused and self.fused_step_supported(tokens):
            # host-built block tables; raises on a shared-page append hazard
            tables, lens, totals, n_max = pcache.step_tables()
            fn = self._fused_step_fn(pcache, n_max)
            pool = pcache.pool
            (logits, pool.k, pool.v, pool.k_scale, pool.v_scale,
             pcache.slot_pos, pcache.length) = fn(
                self.params, pool.k, pool.v, pool.k_scale, pool.v_scale,
                pcache.length, pcache.slot_pos, pcache.gather_idx, tokens,
                tables, lens, totals)
            pcache.note_step()
            return logits
        cache = pcache.dense_view()
        prev_len = cache.length
        logits, new_cache = self.step_rows(cache, tokens)
        pcache.scatter_step(prev_len, new_cache.k, new_cache.v)
        pcache.slot_pos = new_cache.slot_pos
        pcache.length = new_cache.length
        pcache.note_step()
        return logits

    def release_row_paged(self, pcache, slot: int) -> None:
        """Retire a slot: decref shared pages, free the private tail."""
        pcache.release_row(slot)

    # -- request paths -----------------------------------------------------------------
    def answer(self, question: str, max_new_tokens: int = 20,
               chunk_ids: Optional[Sequence[str]] = None
               ) -> Tuple[str, PhaseTimings]:
        timings = PhaseTimings()
        chunk_ids = list(self.retrieve(question) if chunk_ids is None
                         else chunk_ids)
        if not chunk_ids:
            warnings.warn(f"retrieval returned no chunks for {question!r}; "
                          f"answering query-only")
        prompt = self._prompt(question)

        if self.mode == "vanilla":
            doc_toks = [self._pad_chunk(self._chunks[c].tokens)
                        for c in chunk_ids]
            full = np.concatenate(doc_toks + [prompt])[None]
            timings.n_doc_tokens = sum(len(d) for d in doc_toks)
            t0 = time.perf_counter()
            cache, logits = self._vanilla_prefill(jnp.asarray(full))
            jax.block_until_ready(logits)
            timings.prefill_s = time.perf_counter() - t0
            first = greedy(logits[:, -1])
        else:
            buf = len(chunk_ids) * self.chunk_tokens
            t0 = time.perf_counter()
            cache, n_doc, nbytes = self.load_and_compose(
                chunk_ids, buf + len(prompt) + max_new_tokens + 8)
            jax.block_until_ready(cache.k if hasattr(cache, "k") else cache.h)
            timings.load_s = time.perf_counter() - t0
            # the composed cache knows the true token count (short final
            # chunks); the old ``len(chunk_ids) * chunk_tokens`` over-reported
            timings.n_doc_tokens = n_doc
            timings.kv_bytes_loaded = nbytes
            t0 = time.perf_counter()
            if self.mode == "cacheblend" and chunk_ids:
                doc_concat = jnp.asarray(np.concatenate(
                    [self._pad_chunk(self._chunks[c].tokens)
                     for c in chunk_ids])[None])
                cache, _ = blend(self.cfg, self.params, doc_concat, cache,
                                 self.blend_ratio)
            logits, cache = self._subprefill(cache, jnp.asarray(prompt)[None])
            jax.block_until_ready(logits)
            timings.prefill_s = time.perf_counter() - t0
            first = greedy(logits[:, -1])

        t0 = time.perf_counter()
        toks, _ = self._decode_loop(cache, first, max_new_tokens)
        timings.decode_s = time.perf_counter() - t0
        timings.n_new_tokens = max_new_tokens
        ids = [int(t[0]) for t in toks]
        if EOS in ids:
            ids = ids[:ids.index(EOS)]
        return self.tok.decode(ids), timings

    def _vanilla_prefill(self, full_tokens: jnp.ndarray):
        """Full forward with KV collection -> decode-ready cache."""
        key = full_tokens.shape
        if key not in self._vanilla_fns:
            def fn(params, toks):
                logits, artifact = self.model.prefill(params, {"tokens": toks})
                s = toks.shape[1]
                if self.cfg.family in ("dense", "vlm", "moe"):
                    k, v = artifact
                    cache = self.model.init_cache(
                        toks.shape[0], s + 64)
                    kb, vb, sp, ln = write_kv(cache.k, cache.v, cache.slot_pos,
                                              cache.length, k, v)
                    cache = AttnCache(k=kb, v=vb, slot_pos=sp, length=ln)
                elif self.cfg.family == "ssm":
                    cache = compose_ssm_cache(self.cfg, artifact, s)
                else:
                    (kv, rec) = artifact
                    cache = compose_hybrid_cache(
                        self.cfg, (kv, rec), s, s + 64)
                return cache, logits
            self._vanilla_fns[key] = jax.jit(self._meshed(fn))
        return self._vanilla_fns[key](self.params, full_tokens)
