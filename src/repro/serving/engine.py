"""The MatKV RAG serving engine (paper Fig. 3b) — the composed "both" role.

Modes:
  vanilla    — full KV recomputation: one prefill over [docs | query], decode.
  matkv      — load materialized chunk KVs from flash, compose, sub-prefill the
               query only, decode. (paper-faithful; ``rerotate=True`` switches
               on the beyond-paper position re-rotation)
  cacheblend — matkv + selective recompute of r=18% of doc tokens (baseline).

Per-request phase timings (load / prefill / decode) mirror the paper's §V-A
latency breakdown. SSM/hybrid archs serve via prefix-state reuse + chained
recompute of later chunks (DESIGN.md §4).

Since the role split (DESIGN.md §14) the engine is a composition over
``serving/roles.py``: the decode-side surface (compose/prefill/step, row
and paged) is inherited from ``_DecodePlane`` — the same code a standalone
``DecodeWorker`` runs — and the write path is a ``MaterializerWorker``
sharing an in-process ``WorkQueue`` with it. Retrieval, the single-request
``answer`` path, and the recurrent-family compose logic live here. With
identity page keys and ingest-time materialization, the composition is
bit-identical to the pre-split monolith on every path.
"""

from __future__ import annotations

import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blend import blend
from repro.core.chunking import Chunk, chunk_document
from repro.core.compose import (compose_attn_cache, compose_hybrid_cache,
                                compose_ssm_cache)
from repro.core.materialize import load_artifact
from repro.core.quantize import get_codec
from repro.data.tokenizer import ByteTokenizer, EOS
from repro.models.cache import (AttnCache, init_attn_cache, init_hybrid_cache,
                                init_ssm_cache, write_kv)
from repro.retrieval.embed import HashingEmbedder
from repro.retrieval.vectordb import VectorDB
from repro.serving.queue import WorkQueue
from repro.serving.roles import (MaterializerWorker, RowRequest,  # noqa: F401
                                 _DecodePlane)
from repro.serving.sampling import greedy


@dataclass
class PhaseTimings:
    load_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    n_doc_tokens: int = 0
    n_new_tokens: int = 0
    kv_bytes_loaded: int = 0

    @property
    def total_s(self) -> float:
        return self.load_s + self.prefill_s + self.decode_s


class RagEngine(_DecodePlane):
    role = "both"

    def __init__(self, model, params, store, mode: str = "matkv",
                 chunk_tokens: int = 256, top_k: int = 2,
                 rerotate: bool = False, blend_ratio: float = 0.18,
                 codec=None, reader=None, mesh=None, rules=None,
                 tracer=None):
        assert mode in ("vanilla", "matkv", "cacheblend")
        self.model = model
        self.cfg = model.cfg
        self.store = store
        self.reader = reader or store          # SimulatedReader for timing runs
        self.mode = mode
        self.chunk_tokens = chunk_tokens
        self.top_k = top_k
        self.rerotate = rerotate
        self.blend_ratio = blend_ratio
        # tensor-parallel serving (DESIGN.md §12): with a mesh, params are
        # placed by the repro.dist partition specs (wk/wv column-parallel
        # onto the model axis), caches and the paged pool shard their
        # KV-HEAD axis under SERVING_RULES (cache_seq off — the sequence
        # layout is the train/prefill artifact story, not decode's), and
        # every jitted step traces inside mesh_context so the shard()
        # constraints in the model code apply. Without a mesh everything
        # below is byte-for-byte the single-device path.
        self.mesh = mesh
        if mesh is not None:
            from repro.dist.partition import param_specs, to_shardings
            from repro.dist.sharding import SERVING_RULES
            self.rules = {**SERVING_RULES, **(rules or {})}
            params = jax.device_put(
                params, to_shardings(mesh,
                                     param_specs(mesh, params, self.rules)))
        else:
            self.rules = None
        self.params = params
        # KV storage codec ("bf16" passthrough / "int8"), end to end: the
        # materializer encodes with it, the paged pool stores its layout,
        # the dense compose paths widen on decode (DESIGN.md §11)
        self.codec = get_codec(codec)
        self.tok = ByteTokenizer()
        self.embedder = HashingEmbedder()
        self.vdb = VectorDB(self.embedder.dim)
        # the write path is the materializer role, sharing this engine's
        # placed params and an in-process work queue (generation tags flow
        # through it even in the composed engine — harmless extra meta)
        self.tracer = tracer          # _init_decode_plane defaults the None
        self.queue = WorkQueue(tracer=tracer)
        self.mat = MaterializerWorker(model, self.params, store,
                                      codec=self.codec,
                                      chunk_tokens=chunk_tokens,
                                      queue=self.queue, mesh=mesh,
                                      rules=self.rules, place_params=False,
                                      tracer=tracer)
        self.materializer = self.mat.materializer   # compat alias
        self._chunks: Dict[str, Chunk] = {}
        self._vanilla_fns = {}
        self._init_decode_plane()

    # -- ingest ------------------------------------------------------------------
    def ingest(self, doc_id: str, text: str) -> List[str]:
        toks = self.tok.encode(text)
        ids = []
        for c in chunk_document(doc_id, toks, self.chunk_tokens):
            self._chunks[c.chunk_id] = c
            self.vdb.add(c.chunk_id, self.embedder.embed_tokens(c.tokens))
            if self.mode != "vanilla" and not self.store.exists(c.chunk_id):
                self.mat.materialize(c)
            ids.append(c.chunk_id)
        return ids

    def delete(self, chunk_id: str) -> None:
        self.vdb.delete(chunk_id, kv_store=self.store)
        self._chunks.pop(chunk_id, None)

    def chunk_n_tokens(self, chunk_id: str) -> Optional[int]:
        """Token count of an ingested chunk, from the retrieval index —
        available before any flash byte arrives, which lets the streaming
        scheduler seed a request's carry at stream START instead of waiting
        for every chunk's artifact header to cross the (shared, possibly
        saturated) link. Returns None for ids this engine never ingested;
        a mismatch vs the artifact surfaces as a carry-fold fallback, not
        a wrong answer."""
        c = self._chunks.get(chunk_id)
        return None if c is None else int(len(c.tokens))

    # -- retrieval ----------------------------------------------------------------
    def retrieve(self, question: str) -> List[str]:
        q = self.embedder.embed_tokens(self.tok.encode(question))
        return [cid for cid, _ in self.vdb.search(q, self.top_k)]

    # -- load + compose (the MatKV read path) ---------------------------------------
    def load_and_compose(self, chunk_ids: Sequence[str], buf_size: int,
                         batch_rows: int = 1):
        """Returns (cache, n_doc_tokens, bytes_loaded). One row; rows replicate.

        ``chunk_ids == []`` (empty retrieval) yields an empty cache: the query
        is then served with no document prefix instead of crashing on a
        zero-artifact compose.
        """
        fam = self.cfg.family
        if not chunk_ids:
            if fam in ("dense", "vlm", "moe"):
                cache = init_attn_cache(self.cfg, batch_rows, buf_size)
            elif fam == "ssm":
                cache = init_ssm_cache(self.cfg, batch_rows)
            elif fam == "hybrid":
                cache = init_hybrid_cache(self.cfg, batch_rows, buf_size)
            else:
                raise ValueError(f"engine: unsupported family {fam}")
            return cache, 0, 0
        t_bytes = 0
        artifacts, metas = [], []
        for cid in chunk_ids:
            payload = self.reader.get(cid)
            t_bytes += len(payload)
            art, meta = load_artifact(self.cfg, payload)
            artifacts.append(art)
            metas.append(meta)
        if fam in ("dense", "vlm", "moe"):
            if batch_rows > 1:
                artifacts = [jax.tree.map(
                    lambda a: jnp.broadcast_to(
                        a, (a.shape[0], batch_rows) + a.shape[2:]), art)
                    for art in artifacts]
            cache = compose_attn_cache(self.cfg, artifacts, buf_size,
                                       rerotate=self.rerotate)
            n_doc = int(cache.length)
        elif fam == "ssm":
            # prefix reuse of chunk 1; chain-recompute chunks 2..k
            n_doc = metas[0]["n_tokens"]
            cache = compose_ssm_cache(self.cfg, artifacts[0], n_doc)
            for cid, meta in zip(chunk_ids[1:], metas[1:]):
                toks = jnp.asarray(self._chunks[cid].tokens)[None]
                _, cache = self._subprefill(cache, toks)
                n_doc += meta["n_tokens"]
        elif fam == "hybrid":
            n_doc = metas[0]["n_tokens"]
            cache = compose_hybrid_cache(self.cfg, artifacts[0], n_doc, buf_size)
            for cid, meta in zip(chunk_ids[1:], metas[1:]):
                toks = jnp.asarray(self._chunks[cid].tokens)[None]
                _, cache = self._subprefill(cache, toks)
                n_doc += meta["n_tokens"]
        else:
            raise ValueError(f"engine: unsupported family {fam}")
        return cache, n_doc, t_bytes

    # -- request paths -----------------------------------------------------------------
    def answer(self, question: str, max_new_tokens: int = 20,
               chunk_ids: Optional[Sequence[str]] = None
               ) -> Tuple[str, PhaseTimings]:
        timings = PhaseTimings()
        chunk_ids = list(self.retrieve(question) if chunk_ids is None
                         else chunk_ids)
        if not chunk_ids:
            warnings.warn(f"retrieval returned no chunks for {question!r}; "
                          f"answering query-only")
        prompt = self._prompt(question)

        if self.mode == "vanilla":
            doc_toks = [self._pad_chunk(self._chunks[c].tokens)
                        for c in chunk_ids]
            full = np.concatenate(doc_toks + [prompt])[None]
            timings.n_doc_tokens = sum(len(d) for d in doc_toks)
            t0 = time.perf_counter()
            cache, logits = self._vanilla_prefill(jnp.asarray(full))
            jax.block_until_ready(logits)
            timings.prefill_s = time.perf_counter() - t0
            first = greedy(logits[:, -1])
        else:
            buf = len(chunk_ids) * self.chunk_tokens
            t0 = time.perf_counter()
            cache, n_doc, nbytes = self.load_and_compose(
                chunk_ids, buf + len(prompt) + max_new_tokens + 8)
            jax.block_until_ready(cache.k if hasattr(cache, "k") else cache.h)
            timings.load_s = time.perf_counter() - t0
            # the composed cache knows the true token count (short final
            # chunks); the old ``len(chunk_ids) * chunk_tokens`` over-reported
            timings.n_doc_tokens = n_doc
            timings.kv_bytes_loaded = nbytes
            t0 = time.perf_counter()
            if self.mode == "cacheblend" and chunk_ids:
                doc_concat = jnp.asarray(np.concatenate(
                    [self._pad_chunk(self._chunks[c].tokens)
                     for c in chunk_ids])[None])
                cache, _ = blend(self.cfg, self.params, doc_concat, cache,
                                 self.blend_ratio)
            logits, cache = self._subprefill(cache, jnp.asarray(prompt)[None])
            jax.block_until_ready(logits)
            timings.prefill_s = time.perf_counter() - t0
            first = greedy(logits[:, -1])

        t0 = time.perf_counter()
        toks, _ = self._decode_loop(cache, first, max_new_tokens)
        timings.decode_s = time.perf_counter() - t0
        timings.n_new_tokens = max_new_tokens
        ids = [int(t[0]) for t in toks]
        if EOS in ids:
            ids = ids[:ids.index(EOS)]
        return self.tok.decode(ids), timings

    def _vanilla_prefill(self, full_tokens: jnp.ndarray):
        """Full forward with KV collection -> decode-ready cache."""
        key = full_tokens.shape
        if key not in self._vanilla_fns:
            def fn(params, toks):
                logits, artifact = self.model.prefill(params, {"tokens": toks})
                s = toks.shape[1]
                if self.cfg.family in ("dense", "vlm", "moe"):
                    k, v = artifact
                    cache = self.model.init_cache(
                        toks.shape[0], s + 64)
                    kb, vb, sp, ln = write_kv(cache.k, cache.v, cache.slot_pos,
                                              cache.length, k, v)
                    cache = AttnCache(k=kb, v=vb, slot_pos=sp, length=ln)
                elif self.cfg.family == "ssm":
                    cache = compose_ssm_cache(self.cfg, artifact, s)
                else:
                    (kv, rec) = artifact
                    cache = compose_hybrid_cache(
                        self.cfg, (kv, rec), s, s + 64)
                return cache, logits
            self._vanilla_fns[key] = jax.jit(self._meshed(fn))
        return self._vanilla_fns[key](self.params, full_tokens)
