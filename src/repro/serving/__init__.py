from repro.serving.continuous import (ContinuousScheduler, RequestRecord,
                                      ServeMetrics)
from repro.serving.engine import PhaseTimings, RagEngine, RowRequest
from repro.serving.parity import (dense_row_path, paged_row_path,
                                  teacher_forced_rel)
from repro.serving.sampling import greedy, temperature_sample
from repro.serving.scheduler import BatchScheduler

__all__ = ["ContinuousScheduler", "RequestRecord", "ServeMetrics",
           "PhaseTimings", "RagEngine", "RowRequest", "greedy",
           "temperature_sample", "BatchScheduler", "dense_row_path",
           "paged_row_path", "teacher_forced_rel"]
