from repro.serving.engine import PhaseTimings, RagEngine
from repro.serving.sampling import greedy, temperature_sample
from repro.serving.scheduler import BatchScheduler

__all__ = ["PhaseTimings", "RagEngine", "greedy", "temperature_sample",
           "BatchScheduler"]
