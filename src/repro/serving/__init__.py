from repro.serving.continuous import ContinuousScheduler, RequestRecord
from repro.serving.engine import PhaseTimings, RagEngine, RowRequest
from repro.serving.metrics import ServeMetrics
from repro.serving.parity import (dense_row_path, paged_row_path,
                                  teacher_forced_rel)
from repro.serving.queue import HandoffRecord, MaterializeJob, WorkQueue
from repro.serving.roles import DecodeWorker, MaterializerWorker
from repro.serving.sampling import greedy, temperature_sample
from repro.serving.scheduler import BatchScheduler

__all__ = ["ContinuousScheduler", "RequestRecord", "ServeMetrics",
           "PhaseTimings", "RagEngine", "RowRequest", "greedy",
           "temperature_sample", "BatchScheduler", "dense_row_path",
           "paged_row_path", "teacher_forced_rel", "MaterializerWorker",
           "DecodeWorker", "WorkQueue", "MaterializeJob", "HandoffRecord"]
