from repro.serving.continuous import (ContinuousScheduler, RequestRecord,
                                      ServeMetrics)
from repro.serving.engine import PhaseTimings, RagEngine, RowRequest
from repro.serving.sampling import greedy, temperature_sample
from repro.serving.scheduler import BatchScheduler

__all__ = ["ContinuousScheduler", "RequestRecord", "ServeMetrics",
           "PhaseTimings", "RagEngine", "RowRequest", "greedy",
           "temperature_sample", "BatchScheduler"]
