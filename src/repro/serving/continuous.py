"""Continuous-batching serving core (beyond-paper; motivated by the
KV-offloading bottleneck analysis in PAPERS.md).

``BatchScheduler`` overlaps flash loads with decode at *batch* granularity:
every row shares one composed-cache geometry, the batch stalls on its slowest
load, and finished rows decode dead air until the whole batch drains.
``ContinuousScheduler`` replaces that with per-request admission over a
row-slotted cache (``RowAttnCache``):

  arrive   retrieval runs immediately; the request's KV payloads start
           loading on ``AsyncKvLoader`` worker threads (per-request prefetch —
           loads overlap with whatever is currently decoding)
  admit    when a decode slot is free and the payloads have landed, the row is
           composed + sub-prefilled at batch=1 and inserted into the slot
  step     one fixed-shape batched decode step advances every occupied slot;
           rows sit at independent lengths/positions (per-row slot maps)
  evict    a row leaves at EOS or its own ``max_new_tokens``; the freed slot
           is backfilled from the pending queue on the next loop turn

Idle slots keep stepping on a dummy token into their stale row (masked-out,
ignored, fully overwritten at the next admit) so the decode step keeps one
compiled shape. Per-row results are bit-identical to the single-request
``RagEngine.answer`` path: masked slots contribute exact zeros, so a row never
sees its neighbours or the buffer tail.

``paged=True`` swaps the dense per-slot cache for the page-table runtime
(``repro.paged``): admit/evict becomes page-table alloc/free over a
ref-counted block pool, concurrent rows that retrieved the same chunk share
one GPU-resident copy of its KV pages, chunks already resident (or in
flight for an earlier queued request) at arrival read zero flash bytes, and
cold chunks wanted by several queued requests are read from flash exactly
once (loader dedup + the wanted registry). Eviction of one request only
drops its own refs — co-resident requests' shared pages are untouched.
Answers stay bit-identical to the row-slotted path (the paged step runs the
same jitted decode executable on the gathered dense view).

An engine built with a serving mesh (``RagEngine(mesh=...)``) makes either
cache flavour tensor-parallel transparently: the row cache / block pool
arrive KV-head-sharded from the engine's constructors and the decode step
traces under the mesh's sharding constraints, while every host-side
decision here (admission, page tables, accounting) is layout-blind
(DESIGN.md §12).
"""

from __future__ import annotations

import concurrent.futures as cf
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS
from repro.kvstore.async_loader import AsyncKvLoader
from repro.models.cache import insert_cache_row
from repro.serving.engine import RagEngine, RowRequest
from repro.serving.sampling import greedy


@dataclass(eq=False)
class RequestRecord:
    """Per-request lifecycle state + latency bookkeeping (offsets from run
    start, seconds). Identity equality (``eq=False``): two requests with the
    same question are distinct lifecycle objects, and field equality would
    compare the prompt ndarray (ambiguous truth value) when the pending
    queue is searched past an identical request."""
    question: str
    max_new_tokens: int
    arrival_s: float = 0.0
    req: Optional[RowRequest] = None
    future: object = None                  # payloads future (AsyncKvLoader)
    tokens: List[int] = field(default_factory=list)
    answer: Optional[str] = None
    admit_s: Optional[float] = None
    finish_s: Optional[float] = None
    n_doc_tokens: int = 0
    flash_bytes: int = 0                   # flash bytes THIS request caused
    to_load: List[str] = field(default_factory=list)  # paged: chunks to read
    expected: List[str] = field(default_factory=list)  # paged: no load needed

    @property
    def latency_s(self) -> float:
        return (self.finish_s or 0.0) - self.arrival_s


@dataclass
class ServeMetrics:
    wall_s: float = 0.0
    prefill_s: float = 0.0
    decode_s: float = 0.0
    n_requests: int = 0
    n_new_tokens: int = 0
    kv_bytes_loaded: int = 0               # bytes composed into rows
    latencies_s: List[float] = field(default_factory=list)
    # load-link accounting (fed by the paged pool's dedup stats; the
    # row-slotted path reads every chunk per request, so there hits == 0)
    flash_bytes_loaded: int = 0            # bytes actually read from flash
    flash_bytes_per_request: List[int] = field(default_factory=list)
    chunk_hits: int = 0                    # chunk already GPU-resident
    chunk_misses: int = 0                  # chunk had to be read + inserted
    hbm_kv_bytes_resident: int = 0         # peak KV bytes resident in HBM
    resident_chunks_peak: int = 0          # paged: peak distinct chunks in
                                           # the pool (codec-sensitive: one
                                           # byte budget holds ~2x under int8)
    pool_shard_bytes: List[int] = field(default_factory=list)
                                           # paged: per-device bytes of the
                                           # pool's block tensors (one entry
                                           # on a single device; under a
                                           # serving mesh the entries sum to
                                           # the single-device footprint)

    @property
    def chunk_hit_rate(self) -> float:
        total = self.chunk_hits + self.chunk_misses
        return self.chunk_hits / total if total else 0.0

    @property
    def tokens_per_s(self) -> float:
        return self.n_new_tokens / self.wall_s if self.wall_s else 0.0

    def latency_quantile(self, q: float) -> float:
        if not self.latencies_s:
            return 0.0
        return float(np.quantile(np.asarray(self.latencies_s), q))

    @property
    def p50_latency_s(self) -> float:
        return self.latency_quantile(0.50)

    @property
    def p95_latency_s(self) -> float:
        return self.latency_quantile(0.95)


class ContinuousScheduler:
    """Admit requests into decode slots as they arrive; evict at EOS or each
    row's ``max_new_tokens``; backfill freed slots from the pending queue whose
    KV loads were prefetched while earlier rows were decoding."""

    def __init__(self, engine: RagEngine, max_slots: int = 4,
                 buf_size: Optional[int] = None, n_load_workers: int = 4,
                 paged: bool = False, block_size: int = 64,
                 pool_blocks: Optional[int] = None,
                 pool_budget_bytes: Optional[int] = None,
                 fused: bool = True):
        if engine.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("ContinuousScheduler requires an attention-KV "
                             "family")
        if engine.mode != "matkv":
            # vanilla stores no artifacts (admit would crash mid-run) and
            # cacheblend's selective recompute has no row-level equivalent yet
            raise ValueError("ContinuousScheduler requires a matkv-mode "
                             f"engine, got mode={engine.mode!r}")
        if paged and engine.rerotate:
            raise ValueError("paged=True requires rerotate=False (shared "
                             "chunk pages must be position-independent)")
        self.engine = engine
        self.max_slots = max_slots
        self.buf_size = buf_size
        self.paged = paged
        # fused=True (default) serves paged decode steps as one Pallas
        # launch per layer (kernels.paged_decode_fused); False pins the
        # three-phase gather -> step -> scatter pipeline (the parity
        # oracle / fallback). No effect on the dense row-slotted path.
        self.fused = fused
        self.block_size = block_size
        self.pool_blocks = pool_blocks
        # HBM byte budget alternative to pool_blocks: the pool's codec
        # decides how many blocks (and so resident chunks) the budget buys
        self.pool_budget_bytes = pool_budget_bytes
        self.loader = AsyncKvLoader(engine.reader, n_workers=n_load_workers)

    def shutdown(self):
        self.loader.shutdown()

    # -- sizing ----------------------------------------------------------------
    def _buf_for(self, records: Sequence[RequestRecord]) -> int:
        """One buffer geometry for the whole run: worst-case composed prefix +
        prompt + per-request decode budget (rows smaller than this just leave
        tail slots empty)."""
        if self.buf_size is not None:
            return self.buf_size
        eng = self.engine
        worst = 0
        for r in records:
            worst = max(worst, eng.top_k * eng.chunk_tokens
                        + len(eng._prompt(r.question)) + r.max_new_tokens + 8)
        # bucket to a multiple of 64 so successive runs with slightly
        # different workloads reuse the compiled decode step
        return (worst + 63) // 64 * 64

    # -- top-level run ---------------------------------------------------------
    def run(self, questions: Sequence[str],
            max_new_tokens: Union[int, Sequence[int]] = 20,
            arrivals_s: Optional[Sequence[float]] = None
            ) -> Tuple[List[str], ServeMetrics]:
        """Serve ``questions``; ``max_new_tokens`` may be per-request.
        ``arrivals_s`` (offsets from run start) simulates an open-loop arrival
        process — requests are invisible to the scheduler before their arrival
        time. Returns (answers in input order, metrics)."""
        n = len(questions)
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * n
        if arrivals_s is None:
            arrivals_s = [0.0] * n
        records = [RequestRecord(q, m, a) for q, m, a
                   in zip(questions, max_new_tokens, arrivals_s)]
        order = {id(r): i for i, r in enumerate(records)}
        metrics = ServeMetrics(n_requests=n)

        eng = self.engine
        buf = self._buf_for(records)
        pcache = None
        cache = None
        if self.paged:
            pcache = eng.init_paged_cache(
                self.max_slots, buf, block_size=self.block_size,
                n_blocks=self.pool_blocks,
                pool_budget_bytes=self.pool_budget_bytes)
        else:
            # engine-placed: KV-head-sharded under a serving mesh
            cache = eng.init_row_cache(self.max_slots, buf)
        cur = np.zeros((self.max_slots,), np.int32)
        upcoming = deque(sorted(records, key=lambda r: r.arrival_s))
        pending: deque = deque()           # arrived, payloads prefetching
        active: Dict[int, RequestRecord] = {}
        wanted: Dict[str, int] = {}        # paged: chunk -> pending loaders
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0

        def poll_arrivals():
            while upcoming and upcoming[0].arrival_s <= now():
                r = upcoming.popleft()
                r.req = eng.prepare_request(r.question, r.max_new_tokens)
                if self.paged:
                    # chunks already GPU-resident, or in flight for an
                    # earlier pending request, are *expected*: no flash read
                    # is issued, and admit acquires the shared pages (or
                    # falls back to a synchronous read in the rare case the
                    # pages were reclaimed while this request queued). Only
                    # admitted rows pin pages, so queue depth never inflates
                    # the pinned working set; K queued requests wanting one
                    # cold chunk still cost exactly one flash read
                    for cid in r.req.chunk_ids:
                        if cid in r.to_load:
                            # within-request duplicate: this request's own
                            # load serves both occurrences (marking it
                            # expected would deadlock ready() on a wanted
                            # count this request itself holds)
                            continue
                        if (pcache.pool.has(cid)
                                or wanted.get(cid, 0) > 0):
                            r.expected.append(cid)
                        else:
                            r.to_load.append(cid)
                            wanted[cid] = wanted.get(cid, 0) + 1
                    r.future = self.loader.load_many(r.to_load)
                else:
                    # start the flash reads immediately: they overlap with
                    # the decode steps below (per-request load/decode
                    # overlap)
                    r.future = self.loader.load_many(r.req.chunk_ids)
                pending.append(r)

        def finish(r: RequestRecord):
            ids = r.tokens
            if EOS in ids:
                ids = ids[:ids.index(EOS)]
            r.answer = eng.tok.decode(ids)
            r.finish_s = now()
            metrics.n_new_tokens += len(r.tokens)
            metrics.latencies_s.append(r.latency_s)
            metrics.flash_bytes_per_request.append(r.flash_bytes)

        def admit(r: RequestRecord, slot: int) -> bool:
            """Compose + sub-prefill one row into ``slot``. Returns False if
            the request finished at its first token (slot stays free)."""
            nonlocal cache
            t_adm = time.perf_counter()
            if self.paged:
                payloads = dict(zip(r.to_load, r.future.result()))
                n_doc, flash_bytes, nbytes, hits, misses = \
                    eng.compose_row_paged(r.req, pcache, slot, payloads)
                for cid in r.to_load:
                    wanted[cid] -= 1
                first = eng.prefill_row_paged(pcache, slot, r.req.prompt)
                metrics.chunk_hits += hits
                metrics.chunk_misses += misses
            else:
                r.req.payloads = r.future.result()
                row, n_doc, nbytes = eng.compose_row(r.req, buf)
                first, row = eng.prefill_row(row, r.req.prompt)
                # flash bytes are attributed to the request that initiated
                # each read; coalesced in-flight duplicates cost 0 here
                flags = getattr(r.future, "initiated_flags",
                                [True] * len(r.req.payloads))
                flash_bytes = sum(len(p) for p, owned
                                  in zip(r.req.payloads, flags) if owned)
                metrics.chunk_misses += len(r.req.chunk_ids)
            metrics.prefill_s += time.perf_counter() - t_adm
            metrics.kv_bytes_loaded += nbytes     # composed into the row
            metrics.flash_bytes_loaded += flash_bytes  # actually read
            r.flash_bytes = flash_bytes
            r.n_doc_tokens = n_doc
            r.admit_s = now()
            r.tokens = [int(first[0])]
            if r.tokens[0] == EOS or r.max_new_tokens <= 1:
                if self.paged:
                    eng.release_row_paged(pcache, slot)
                finish(r)
                return False
            if not self.paged:
                cache = insert_cache_row(cache, slot, row)
            cur[slot] = r.tokens[0]
            active[slot] = r
            return True

        while upcoming or pending or active:
            poll_arrivals()
            # backfill free slots with loaded requests (FIFO, skip-ahead only
            # past requests whose loads are still in flight)
            def ready(r: RequestRecord) -> bool:
                if not r.future.done():
                    return False
                # paged: a chunk another pending request is loading isn't
                # admissible until its pages land (wanted drops to 0 once
                # the loader admits; if the pages were since reclaimed the
                # compose fallback reads them synchronously)
                return all(pcache.pool.has(c) or wanted.get(c, 0) == 0
                           for c in r.expected)
            free = [s for s in range(self.max_slots) if s not in active]
            for slot in free:
                ready_r = next((r for r in pending if ready(r)), None)
                if ready_r is None:
                    break
                pending.remove(ready_r)
                admit(ready_r, slot)
            if not active:
                if pending:
                    # nothing decoding: wait for the FIRST load to land (not
                    # the oldest — a tiny chunk behind a huge one must not
                    # stall), briefly so arrivals keep being polled
                    cf.wait([r.future for r in pending], timeout=0.01,
                            return_when=cf.FIRST_COMPLETED)
                elif upcoming:
                    time.sleep(max(0.0, min(
                        upcoming[0].arrival_s - now(), 0.01)))
                continue
            t_dec = time.perf_counter()
            if self.paged:
                logits = eng.step_rows_paged(pcache,
                                             jnp.asarray(cur)[:, None],
                                             fused=self.fused)
            else:
                logits, cache = eng.step_rows(cache,
                                              jnp.asarray(cur)[:, None])
            nxt = np.asarray(greedy(logits[:, -1]))
            metrics.decode_s += time.perf_counter() - t_dec
            for slot, r in list(active.items()):
                tok = int(nxt[slot])
                r.tokens.append(tok)
                cur[slot] = tok
                if tok == EOS or len(r.tokens) >= r.max_new_tokens:
                    if self.paged:
                        # eviction only drops THIS row's refs + private
                        # tail; pages shared with co-resident rows stay put
                        eng.release_row_paged(pcache, slot)
                    finish(r)
                    del active[slot]

        metrics.wall_s = now()
        if self.paged:
            # required working set only: refs>0 shared pages + private
            # tails. Refcount-0 LRU pages are a reclaimable hot-set cache
            # (the flash-read savings), not required residency.
            pool = pcache.pool
            metrics.hbm_kv_bytes_resident = (pool.stats.peak_pinned_blocks
                                             * pool.bytes_per_block)
            metrics.resident_chunks_peak = pool.stats.peak_resident_chunks
            metrics.pool_shard_bytes = pool.device_bytes_per_shard()
        else:
            metrics.hbm_kv_bytes_resident = (cache.k.nbytes
                                             + cache.v.nbytes)
        answers = [None] * n
        for r in records:
            answers[order[id(r)]] = r.answer
        return answers, metrics
