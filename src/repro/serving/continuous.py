"""Continuous-batching serving core (beyond-paper; motivated by the
KV-offloading bottleneck analysis in PAPERS.md).

``BatchScheduler`` overlaps flash loads with decode at *batch* granularity:
every row shares one composed-cache geometry, the batch stalls on its slowest
load, and finished rows decode dead air until the whole batch drains.
``ContinuousScheduler`` replaces that with per-request admission over a
row-slotted cache (``RowAttnCache``):

  arrive   retrieval runs immediately; the request's KV payloads start
           loading on ``AsyncKvLoader`` worker threads (per-request prefetch —
           loads overlap with whatever is currently decoding)
  admit    when a decode slot is free and the payloads have landed, the row is
           composed + sub-prefilled at batch=1 and inserted into the slot
  step     one fixed-shape batched decode step advances every occupied slot;
           rows sit at independent lengths/positions (per-row slot maps)
  evict    a row leaves at EOS or its own ``max_new_tokens``; the freed slot
           is backfilled from the pending queue on the next loop turn

Idle slots keep stepping on a dummy token into their stale row (masked-out,
ignored, fully overwritten at the next admit) so the decode step keeps one
compiled shape. Per-row results are bit-identical to the single-request
``RagEngine.answer`` path: masked slots contribute exact zeros, so a row never
sees its neighbours or the buffer tail.

``paged=True`` swaps the dense per-slot cache for the page-table runtime
(``repro.paged``): admit/evict becomes page-table alloc/free over a
ref-counted block pool, concurrent rows that retrieved the same chunk share
one GPU-resident copy of its KV pages, chunks already resident (or in
flight for an earlier queued request) at arrival read zero flash bytes, and
cold chunks wanted by several queued requests are read from flash exactly
once (loader dedup + the wanted registry). Eviction of one request only
drops its own refs — co-resident requests' shared pages are untouched.
Answers stay bit-identical to the row-slotted path (the paged step runs the
same jitted decode executable on the gathered dense view).

An engine built with a serving mesh (``RagEngine(mesh=...)``) makes either
cache flavour tensor-parallel transparently: the row cache / block pool
arrive KV-head-sharded from the engine's constructors and the decode step
traces under the mesh's sharding constraints, while every host-side
decision here (admission, page tables, accounting) is layout-blind
(DESIGN.md §12).

The scheduler drives the *decode role* surface only (DESIGN.md §14): a
``RagEngine`` (composed "both") or a standalone ``DecodeWorker`` both
satisfy it. Pool residency is checked through ``engine.page_key`` (identity
on the engine, generation-tagged on a decode worker), and a chunk whose
flash artifact doesn't exist yet is NOT a decode stall: the request parks
with a materialize job posted on the work queue
(``engine.request_materialize``) and its flash loads start only once the
materializer role publishes the artifact — decode slots keep stepping other
requests meanwhile.
"""

from __future__ import annotations

import concurrent.futures as cf
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS
from repro.kvstore.async_loader import AsyncKvLoader
from repro.models.cache import insert_cache_row
from repro.serving.engine import RagEngine, RowRequest
from repro.serving.metrics import ServeMetrics  # noqa: F401  (re-export)
from repro.serving.sampling import greedy


@dataclass(eq=False)
class RequestRecord:
    """Per-request lifecycle state + latency bookkeeping (offsets from run
    start, seconds). Identity equality (``eq=False``): two requests with the
    same question are distinct lifecycle objects, and field equality would
    compare the prompt ndarray (ambiguous truth value) when the pending
    queue is searched past an identical request."""
    question: str
    max_new_tokens: int
    arrival_s: float = 0.0
    req: Optional[RowRequest] = None
    future: object = None                  # payloads future (AsyncKvLoader)
    tokens: List[int] = field(default_factory=list)
    answer: Optional[str] = None
    admit_s: Optional[float] = None
    finish_s: Optional[float] = None
    n_doc_tokens: int = 0
    flash_bytes: int = 0                   # flash bytes THIS request caused
    to_load: List[str] = field(default_factory=list)  # paged: chunks to read
    expected: List[str] = field(default_factory=list)  # paged: no load needed
    pending_mat: List[str] = field(default_factory=list)
                                           # chunks with no flash artifact
                                           # yet: materialize job posted,
                                           # loads deferred until published

    @property
    def latency_s(self) -> float:
        return (self.finish_s or 0.0) - self.arrival_s


class ContinuousScheduler:
    """Admit requests into decode slots as they arrive; evict at EOS or each
    row's ``max_new_tokens``; backfill freed slots from the pending queue whose
    KV loads were prefetched while earlier rows were decoding."""

    def __init__(self, engine: RagEngine, max_slots: int = 4,
                 buf_size: Optional[int] = None, n_load_workers: int = 4,
                 paged: bool = False, block_size: int = 64,
                 pool_blocks: Optional[int] = None,
                 pool_budget_bytes: Optional[int] = None,
                 fused: bool = True):
        if engine.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("ContinuousScheduler requires an attention-KV "
                             "family")
        if engine.mode != "matkv":
            # vanilla stores no artifacts (admit would crash mid-run) and
            # cacheblend's selective recompute has no row-level equivalent yet
            raise ValueError("ContinuousScheduler requires a matkv-mode "
                             f"engine, got mode={engine.mode!r}")
        if paged and engine.rerotate:
            raise ValueError("paged=True requires rerotate=False (shared "
                             "chunk pages must be position-independent)")
        self.engine = engine
        self.max_slots = max_slots
        self.buf_size = buf_size
        self.paged = paged
        # fused=True (default) serves paged decode steps as one Pallas
        # launch per layer (kernels.paged_decode_fused); False pins the
        # three-phase gather -> step -> scatter pipeline (the parity
        # oracle / fallback). No effect on the dense row-slotted path.
        self.fused = fused
        self.block_size = block_size
        self.pool_blocks = pool_blocks
        # HBM byte budget alternative to pool_blocks: the pool's codec
        # decides how many blocks (and so resident chunks) the budget buys
        self.pool_budget_bytes = pool_budget_bytes
        # a DecodeWorker brings its own loader (one flash-read dedup domain
        # per worker, shared across scheduler instances); the composed
        # engine doesn't, so the scheduler owns one
        self.loader = getattr(engine, "loader", None)
        self._owns_loader = self.loader is None
        if self._owns_loader:
            self.loader = AsyncKvLoader(engine.reader,
                                        n_workers=n_load_workers)

    def shutdown(self):
        if self._owns_loader:
            self.loader.shutdown()

    # -- sizing ----------------------------------------------------------------
    def _buf_for(self, records: Sequence[RequestRecord]) -> int:
        """One buffer geometry for the whole run: worst-case composed prefix +
        prompt + per-request decode budget (rows smaller than this just leave
        tail slots empty)."""
        if self.buf_size is not None:
            return self.buf_size
        eng = self.engine
        worst = 0
        for r in records:
            worst = max(worst, eng.top_k * eng.chunk_tokens
                        + len(eng._prompt(r.question)) + r.max_new_tokens + 8)
        # bucket to a multiple of 64 so successive runs with slightly
        # different workloads reuse the compiled decode step
        return (worst + 63) // 64 * 64

    # -- top-level run ---------------------------------------------------------
    def run(self, questions: Sequence[str],
            max_new_tokens: Union[int, Sequence[int]] = 20,
            arrivals_s: Optional[Sequence[float]] = None
            ) -> Tuple[List[str], ServeMetrics]:
        """Serve ``questions``; ``max_new_tokens`` may be per-request.
        ``arrivals_s`` (offsets from run start) simulates an open-loop arrival
        process — requests are invisible to the scheduler before their arrival
        time. Returns (answers in input order, metrics)."""
        n = len(questions)
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * n
        if arrivals_s is None:
            arrivals_s = [0.0] * n
        records = [RequestRecord(q, m, a) for q, m, a
                   in zip(questions, max_new_tokens, arrivals_s)]
        order = {id(r): i for i, r in enumerate(records)}
        metrics = ServeMetrics(n_requests=n,
                               role=getattr(self.engine, "role", "both"))

        eng = self.engine
        buf = self._buf_for(records)
        pcache = None
        cache = None
        if self.paged:
            pcache = eng.init_paged_cache(
                self.max_slots, buf, block_size=self.block_size,
                n_blocks=self.pool_blocks,
                pool_budget_bytes=self.pool_budget_bytes)
        else:
            # engine-placed: KV-head-sharded under a serving mesh
            cache = eng.init_row_cache(self.max_slots, buf)
        cur = np.zeros((self.max_slots,), np.int32)
        upcoming = deque(sorted(records, key=lambda r: r.arrival_s))
        pending: deque = deque()           # arrived, payloads prefetching
        active: Dict[int, RequestRecord] = {}
        wanted: Dict[str, int] = {}        # paged: chunk -> pending loaders
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0

        def start_loads(r: RequestRecord):
            """Classify chunks + kick the flash reads for one request.
            Requires every artifact to exist (``artifact_ready``)."""
            if self.paged:
                # chunks already GPU-resident, or in flight for an
                # earlier pending request, are *expected*: no flash read
                # is issued, and admit acquires the shared pages (or
                # falls back to a synchronous read in the rare case the
                # pages were reclaimed while this request queued). Only
                # admitted rows pin pages, so queue depth never inflates
                # the pinned working set; K queued requests wanting one
                # cold chunk still cost exactly one flash read.
                # Residency is checked under the engine's page key: on a
                # decode worker a refreshed chunk's resident stale
                # generation is NOT a hit — the fresh artifact is read
                for cid in r.req.chunk_ids:
                    if cid in r.to_load:
                        # within-request duplicate: this request's own
                        # load serves both occurrences (marking it
                        # expected would deadlock ready() on a wanted
                        # count this request itself holds)
                        continue
                    if (pcache.pool.has(eng.page_key(cid))
                            or wanted.get(cid, 0) > 0):
                        r.expected.append(cid)
                    else:
                        r.to_load.append(cid)
                        wanted[cid] = wanted.get(cid, 0) + 1
                r.future = self.loader.load_many(r.to_load)
            else:
                # start the flash reads immediately: they overlap with
                # the decode steps below (per-request load/decode
                # overlap)
                r.future = self.loader.load_many(r.req.chunk_ids)

        def poll_arrivals():
            while upcoming and upcoming[0].arrival_s <= now():
                r = upcoming.popleft()
                r.req = eng.prepare_request(r.question, r.max_new_tokens)
                # materialize-on-miss (DESIGN.md §14): a chunk with no
                # flash artifact parks the request behind a materialize
                # job instead of crashing the loader (or stalling a decode
                # slot); its loads start once the artifact is published
                missing = [c for c in r.req.chunk_ids
                           if not eng.artifact_ready(c)]
                if missing:
                    r.pending_mat = missing
                    for c in missing:
                        eng.request_materialize(c)
                else:
                    start_loads(r)
                pending.append(r)

        def poll_materialized():
            for r in pending:
                if r.future is None and all(eng.artifact_ready(c)
                                            for c in r.pending_mat):
                    r.pending_mat = []
                    start_loads(r)

        def finish(r: RequestRecord):
            ids = r.tokens
            if EOS in ids:
                ids = ids[:ids.index(EOS)]
            r.answer = eng.tok.decode(ids)
            r.finish_s = now()
            metrics.n_new_tokens += len(r.tokens)
            metrics.latencies_s.append(r.latency_s)
            metrics.flash_bytes_per_request.append(r.flash_bytes)

        def admit(r: RequestRecord, slot: int) -> bool:
            """Compose + sub-prefill one row into ``slot``. Returns False if
            the request finished at its first token (slot stays free)."""
            nonlocal cache
            t_adm = time.perf_counter()
            if self.paged:
                payloads = dict(zip(r.to_load, r.future.result()))
                n_doc, flash_bytes, nbytes, hits, misses = \
                    eng.compose_row_paged(r.req, pcache, slot, payloads)
                for cid in r.to_load:
                    wanted[cid] -= 1
                first = eng.prefill_row_paged(pcache, slot, r.req.prompt)
                metrics.chunk_hits += hits
                metrics.chunk_misses += misses
            else:
                r.req.payloads = r.future.result()
                row, n_doc, nbytes = eng.compose_row(r.req, buf)
                first, row = eng.prefill_row(row, r.req.prompt)
                # flash bytes are attributed to the request that initiated
                # each read; coalesced in-flight duplicates cost 0 here
                flags = getattr(r.future, "initiated_flags",
                                [True] * len(r.req.payloads))
                flash_bytes = sum(len(p) for p, owned
                                  in zip(r.req.payloads, flags) if owned)
                metrics.chunk_misses += len(r.req.chunk_ids)
            metrics.prefill_s += time.perf_counter() - t_adm
            metrics.kv_bytes_loaded += nbytes     # composed into the row
            metrics.flash_bytes_loaded += flash_bytes  # actually read
            r.flash_bytes = flash_bytes
            r.n_doc_tokens = n_doc
            r.admit_s = now()
            r.tokens = [int(first[0])]
            if r.tokens[0] == EOS or r.max_new_tokens <= 1:
                if self.paged:
                    eng.release_row_paged(pcache, slot)
                finish(r)
                return False
            if not self.paged:
                cache = insert_cache_row(cache, slot, row)
            cur[slot] = r.tokens[0]
            active[slot] = r
            return True

        while upcoming or pending or active:
            poll_arrivals()
            poll_materialized()
            # backfill free slots with loaded requests (FIFO, skip-ahead only
            # past requests whose loads are still in flight)
            def ready(r: RequestRecord) -> bool:
                if r.future is None or not r.future.done():
                    return False     # loads not started (materializing) /
                                     # still in flight
                # paged: a chunk another pending request is loading isn't
                # admissible until its pages land (wanted drops to 0 once
                # the loader admits; if the pages were since reclaimed the
                # compose fallback reads them synchronously)
                return all(pcache.pool.has(eng.page_key(c))
                           or wanted.get(c, 0) == 0
                           for c in r.expected)
            free = [s for s in range(self.max_slots) if s not in active]
            for slot in free:
                ready_r = next((r for r in pending if ready(r)), None)
                if ready_r is None:
                    break
                pending.remove(ready_r)
                admit(ready_r, slot)
            if not active:
                in_flight = [r.future for r in pending
                             if r.future is not None]
                if in_flight:
                    # nothing decoding: wait for the FIRST load to land (not
                    # the oldest — a tiny chunk behind a huge one must not
                    # stall), briefly so arrivals keep being polled
                    cf.wait(in_flight, timeout=0.01,
                            return_when=cf.FIRST_COMPLETED)
                elif pending:
                    # every pending request is parked on materialization:
                    # yield so the materializer role gets cycles
                    time.sleep(0.002)
                elif upcoming:
                    time.sleep(max(0.0, min(
                        upcoming[0].arrival_s - now(), 0.01)))
                continue
            t_dec = time.perf_counter()
            if self.paged:
                logits = eng.step_rows_paged(pcache,
                                             jnp.asarray(cur)[:, None],
                                             fused=self.fused)
            else:
                logits, cache = eng.step_rows(cache,
                                              jnp.asarray(cur)[:, None])
            nxt = np.asarray(greedy(logits[:, -1]))
            metrics.decode_s += time.perf_counter() - t_dec
            for slot, r in list(active.items()):
                tok = int(nxt[slot])
                r.tokens.append(tok)
                cur[slot] = tok
                if tok == EOS or len(r.tokens) >= r.max_new_tokens:
                    if self.paged:
                        # eviction only drops THIS row's refs + private
                        # tail; pages shared with co-resident rows stay put
                        eng.release_row_paged(pcache, slot)
                    finish(r)
                    del active[slot]

        metrics.wall_s = now()
        if self.paged:
            # required working set only: refs>0 shared pages + private
            # tails. Refcount-0 LRU pages are a reclaimable hot-set cache
            # (the flash-read savings), not required residency.
            pool = pcache.pool
            metrics.hbm_kv_bytes_resident = (pool.stats.peak_pinned_blocks
                                             * pool.bytes_per_block)
            metrics.resident_chunks_peak = pool.stats.peak_resident_chunks
            metrics.pool_shard_bytes = pool.device_bytes_per_shard()
        else:
            metrics.hbm_kv_bytes_resident = (cache.k.nbytes
                                             + cache.v.nbytes)
        answers = [None] * n
        for r in records:
            answers[order[id(r)]] = r.answer
        return answers, metrics
