"""Continuous-batching serving core (beyond-paper; motivated by the
KV-offloading bottleneck analysis in PAPERS.md).

``BatchScheduler`` overlaps flash loads with decode at *batch* granularity:
every row shares one composed-cache geometry, the batch stalls on its slowest
load, and finished rows decode dead air until the whole batch drains.
``ContinuousScheduler`` replaces that with per-request admission over a
row-slotted cache (``RowAttnCache``):

  arrive   retrieval runs immediately; the request's KV payloads start
           loading on ``AsyncKvLoader`` worker threads (per-request prefetch —
           loads overlap with whatever is currently decoding)
  admit    when a decode slot is free and the payloads have landed, the row is
           composed + sub-prefilled at batch=1 and inserted into the slot
  step     one fixed-shape batched decode step advances every occupied slot;
           rows sit at independent lengths/positions (per-row slot maps)
  evict    a row leaves at EOS or its own ``max_new_tokens``; the freed slot
           is backfilled from the pending queue on the next loop turn

Idle slots keep stepping on a dummy token into their stale row (masked-out,
ignored, fully overwritten at the next admit) so the decode step keeps one
compiled shape. Per-row results are bit-identical to the single-request
``RagEngine.answer`` path: masked slots contribute exact zeros, so a row never
sees its neighbours or the buffer tail.

``paged=True`` swaps the dense per-slot cache for the page-table runtime
(``repro.paged``): admit/evict becomes page-table alloc/free over a
ref-counted block pool, concurrent rows that retrieved the same chunk share
one GPU-resident copy of its KV pages, chunks already resident (or in
flight for an earlier queued request) at arrival read zero flash bytes, and
cold chunks wanted by several queued requests are read from flash exactly
once (loader dedup + the wanted registry). Eviction of one request only
drops its own refs — co-resident requests' shared pages are untouched.
Answers stay bit-identical to the row-slotted path (the paged step runs the
same jitted decode executable on the gathered dense view).

``streaming=True`` (requires ``paged=True``) admits cold requests at *block*
granularity instead of all-or-nothing (DESIGN.md §16): each cold chunk gets
a per-chunk flash stream (``AsyncKvLoader.load_stream``) whose arriving
token blocks advance a pool-resident frontier
(``begin_stream``/``extend_stream``/``commit_stream``), and the layer-0
prompt-over-document attention folds landed blocks into an online-softmax
carry (m/ℓ running maxima) while later blocks are still on the link — so
admission pays ``max(link, fold) + finalize`` instead of
``link + compose + prefill``, and the first token is still bit-identical to
the all-at-once path. A ``host_tier`` byte budget adds a host-DRAM demotion
tier under the pool: LRU-reclaimed refs-0 pages demote to host bytes and
``promote`` rehydrates them with zero flash re-reads.

An engine built with a serving mesh (``RagEngine(mesh=...)``) makes either
cache flavour tensor-parallel transparently: the row cache / block pool
arrive KV-head-sharded from the engine's constructors and the decode step
traces under the mesh's sharding constraints, while every host-side
decision here (admission, page tables, accounting) is layout-blind
(DESIGN.md §12).

The scheduler drives the *decode role* surface only (DESIGN.md §14): a
``RagEngine`` (composed "both") or a standalone ``DecodeWorker`` both
satisfy it. Pool residency is checked through ``engine.page_key`` (identity
on the engine, generation-tagged on a decode worker), and a chunk whose
flash artifact doesn't exist yet is NOT a decode stall: the request parks
with a materialize job posted on the work queue
(``engine.request_materialize``) and its flash loads start only once the
materializer role publishes the artifact — decode slots keep stepping other
requests meanwhile.
"""

from __future__ import annotations

import concurrent.futures as cf
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import paged_step_kv_bytes_for_pool
from repro.data.tokenizer import EOS
from repro.kvstore.async_loader import AsyncKvLoader
from repro.models.cache import insert_cache_row
from repro.obs import (MetricsRegistry, NULL_TRACER,
                       fused_step_kv_bytes_measured, span_overlap_frac)
from repro.serving.engine import RagEngine, RowRequest
from repro.serving.metrics import ServeMetrics  # noqa: F401  (re-export)
from repro.serving.sampling import greedy


@dataclass(eq=False)
class RequestRecord:
    """Per-request lifecycle state + latency bookkeeping (offsets from run
    start, seconds). Identity equality (``eq=False``): two requests with the
    same question are distinct lifecycle objects, and field equality would
    compare the prompt ndarray (ambiguous truth value) when the pending
    queue is searched past an identical request."""
    question: str
    max_new_tokens: int
    arrival_s: float = 0.0
    req: Optional[RowRequest] = None
    future: object = None                  # payloads future (AsyncKvLoader)
    tokens: List[int] = field(default_factory=list)
    answer: Optional[str] = None
    admit_s: Optional[float] = None
    finish_s: Optional[float] = None
    n_doc_tokens: int = 0
    flash_bytes: int = 0                   # flash bytes THIS request caused
    to_load: List[str] = field(default_factory=list)  # paged: chunks to read
    loading: List[str] = field(default_factory=list)  # chunks in the CURRENT
                                           # future (suffix of to_load after
                                           # a re-park salvages earlier ones)
    preloaded: Dict[str, bytes] = field(default_factory=dict)
                                           # payloads salvaged across re-parks
    expected: List[str] = field(default_factory=list)  # paged: no load needed
    stream: Optional["_RowStream"] = None  # streaming admission state
    pending_mat: List[str] = field(default_factory=list)
                                           # chunks with no flash artifact
                                           # yet: materialize job posted,
                                           # loads deferred until published
    # per-request phase split (seconds; DESIGN.md §15). queue_wait covers
    # arrival -> admission start (materialize parking included); load_stall
    # is the flash-read wait at admit; decode_share accumulates the full
    # duration of every decode step this row was live in. Their sum plus
    # compose + prefill ≈ latency (scheduler bookkeeping is the remainder).
    first_token_s: Optional[float] = None  # offset from run start
    queue_wait_s: float = 0.0
    load_stall_s: float = 0.0
    compose_s: float = 0.0
    prefill_s: float = 0.0
    decode_share_s: float = 0.0

    @property
    def latency_s(self) -> float:
        return (self.finish_s or 0.0) - self.arrival_s

    @property
    def ttft_s(self) -> float:
        """Arrival to first emitted token (the cold-load stall the paper's
        load/decode-overlap claim is about)."""
        return (self.first_token_s or self.finish_s or 0.0) - self.arrival_s

    @property
    def phase_sum_s(self) -> float:
        """Sum of attributed phases — asserted ≈ latency (within scheduler
        bookkeeping) by the trace-invariant tests."""
        return (self.queue_wait_s + self.load_stall_s + self.compose_s
                + self.prefill_s + self.decode_share_s)


@dataclass
class _RowStream:
    """Streaming-admission state for one pending request (DESIGN.md §16).

    Tracks the request's per-chunk block streams, the pool streams it has
    begun/committed (plus any host-tier promotions it pinned), and the
    retrieval-order carry-fold cursor: ``fold_idx`` indexes the request's
    chunk occurrence being folded, ``fold_off`` the tokens folded of it,
    ``fold_blk`` the blocks of its buffer consumed. The carry only ever
    advances in retrieval-token order — chunk i+1's blocks stay buffered
    until chunk i is fully folded — so the online-softmax fold is
    deterministic regardless of inter-chunk arrival order.
    """
    streams: Dict[str, object] = field(default_factory=dict)
                                           # cid -> AsyncKvLoader.ChunkStream
    keys: List[str] = field(default_factory=list)
                                           # cold chunks this request streams
    started: bool = False                  # classification + streams opened
    begun: set = field(default_factory=set)        # page keys begin_stream'd
    committed: set = field(default_factory=set)    # page keys committed
    cursors: Dict[str, int] = field(default_factory=dict)
    blocks: Dict[str, List] = field(default_factory=dict)
                                           # cid -> [(t0, t1, EncodedKV)] in
                                           # arrival (= token) order
    fold_idx: int = 0
    fold_off: int = 0
    fold_blk: int = 0
    carry: object = None                   # StreamingPrefix once n_doc known
    carry_dropped: bool = False            # an unfolded chunk's pages
                                           # vanished: the admit falls back
                                           # to the all-at-once prefill
    n_doc: Optional[int] = None
    bytes: int = 0                         # flash bytes streamed in
    done: bool = False                     # every cold stream committed


class ContinuousScheduler:
    """Admit requests into decode slots as they arrive; evict at EOS or each
    row's ``max_new_tokens``; backfill freed slots from the pending queue whose
    KV loads were prefetched while earlier rows were decoding."""

    def __init__(self, engine: RagEngine, max_slots: int = 4,
                 buf_size: Optional[int] = None, n_load_workers: int = 4,
                 paged: bool = False, block_size: int = 64,
                 pool_blocks: Optional[int] = None,
                 pool_budget_bytes: Optional[int] = None,
                 fused: bool = True, tracer=None,
                 streaming: bool = False, host_tier=None,
                 pre_admit_hook=None):
        if engine.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("ContinuousScheduler requires an attention-KV "
                             "family")
        if engine.mode != "matkv":
            # vanilla stores no artifacts (admit would crash mid-run) and
            # cacheblend's selective recompute has no row-level equivalent yet
            raise ValueError("ContinuousScheduler requires a matkv-mode "
                             f"engine, got mode={engine.mode!r}")
        if paged and engine.rerotate:
            raise ValueError("paged=True requires rerotate=False (shared "
                             "chunk pages must be position-independent)")
        if streaming and not paged:
            raise ValueError("streaming=True requires paged=True (the "
                             "resident frontier lives in the block pool)")
        if streaming and not engine.streaming_supported():
            raise ValueError("engine does not support streamed admission "
                             "(needs a dense/vlm full-attention config, "
                             "rerotate off)")
        self.engine = engine
        # streaming=True admits cold requests block-granularly: per-chunk
        # flash streams advance a pool resident frontier while the layer-0
        # prompt-over-document attention folds into an online-softmax carry,
        # so admission is just the finalize step (DESIGN.md §16)
        self.streaming = streaming
        # host-DRAM demotion tier between flash and the HBM pool: None (off),
        # a byte capacity, or an LruBytesCache instance (kvstore.cache_tier)
        self.host_tier = host_tier
        # test seam: called with the ready record just before admission; the
        # admit-time reclaim-race regression forces a reclaim here
        self.pre_admit_hook = pre_admit_hook
        self.max_slots = max_slots
        self.buf_size = buf_size
        self.paged = paged
        # fused=True (default) serves paged decode steps as one Pallas
        # launch per layer (kernels.paged_decode_fused); False pins the
        # three-phase gather -> step -> scatter pipeline (the parity
        # oracle / fallback). No effect on the dense row-slotted path.
        self.fused = fused
        self.block_size = block_size
        self.pool_blocks = pool_blocks
        # HBM byte budget alternative to pool_blocks: the pool's codec
        # decides how many blocks (and so resident chunks) the budget buys
        self.pool_budget_bytes = pool_budget_bytes
        # observability (DESIGN.md §15): spans go to the given tracer (or
        # the engine's, or the shared disabled singleton); per-run counters
        # land in a fresh MetricsRegistry that ``ServeMetrics`` is computed
        # from at the end of each run (kept as ``last_registry``)
        self.tracer = (tracer or getattr(engine, "tracer", None)
                       or NULL_TRACER)
        self.last_registry: Optional[MetricsRegistry] = None
        self.last_records: List[RequestRecord] = []
        self.last_buf_size: Optional[int] = None
        self.last_pool = None              # paged: the run's block pool
                                           # (predicted_vs_measured reads
                                           # widths/geometry off it)
        # a DecodeWorker brings its own loader (one flash-read dedup domain
        # per worker, shared across scheduler instances); the composed
        # engine doesn't, so the scheduler owns one
        self.loader = getattr(engine, "loader", None)
        self._owns_loader = self.loader is None
        if self._owns_loader:
            self.loader = AsyncKvLoader(engine.reader,
                                        n_workers=n_load_workers,
                                        tracer=self.tracer)
        elif (self.tracer.enabled
              and not getattr(self.loader, "tracer", NULL_TRACER).enabled):
            # engine-owned loader with no tracer of its own: adopt ours so
            # flash_read spans land in this run's trace
            self.loader.tracer = self.tracer

    def shutdown(self):
        if self._owns_loader:
            self.loader.shutdown()

    # -- sizing ----------------------------------------------------------------
    def _buf_for(self, records: Sequence[RequestRecord]) -> int:
        """One buffer geometry for the whole run: worst-case composed prefix +
        prompt + per-request decode budget (rows smaller than this just leave
        tail slots empty)."""
        if self.buf_size is not None:
            return self.buf_size
        eng = self.engine
        worst = 0
        for r in records:
            worst = max(worst, eng.top_k * eng.chunk_tokens
                        + len(eng._prompt(r.question)) + r.max_new_tokens + 8)
        # bucket to a multiple of 64 so successive runs with slightly
        # different workloads reuse the compiled decode step
        return (worst + 63) // 64 * 64

    # -- top-level run ---------------------------------------------------------
    def run(self, questions: Sequence[str],
            max_new_tokens: Union[int, Sequence[int]] = 20,
            arrivals_s: Optional[Sequence[float]] = None
            ) -> Tuple[List[str], ServeMetrics]:
        """Serve ``questions``; ``max_new_tokens`` may be per-request.
        ``arrivals_s`` (offsets from run start) simulates an open-loop arrival
        process — requests are invisible to the scheduler before their arrival
        time. Returns (answers in input order, metrics)."""
        n = len(questions)
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * n
        if arrivals_s is None:
            arrivals_s = [0.0] * n
        records = [RequestRecord(q, m, a) for q, m, a
                   in zip(questions, max_new_tokens, arrivals_s)]
        order = {id(r): i for i, r in enumerate(records)}
        reg = MetricsRegistry()
        tr = self.tracer
        self.last_registry = reg
        self.last_records = records
        reg.counter("serve.requests").inc(n)

        eng = self.engine
        buf = self._buf_for(records)
        self.last_buf_size = buf
        pcache = None
        cache = None
        if self.paged:
            n_blocks = self.pool_blocks
            if (self.streaming and n_blocks is None
                    and self.pool_budget_bytes is None):
                # pending streams reserve pages before admission, so the
                # default sizing gets headroom for max_slots concurrent
                # in-flight streams on top of the admitted working set
                per_row = -(-buf // self.block_size)
                chunk_blocks = -(-eng.chunk_tokens // self.block_size)
                n_blocks = self.max_slots * (
                    1 + per_row + 2 * eng.top_k * chunk_blocks) + 4
            pcache = eng.init_paged_cache(
                self.max_slots, buf, block_size=self.block_size,
                n_blocks=n_blocks,
                pool_budget_bytes=self.pool_budget_bytes,
                host_tier=self.host_tier)
            self.last_pool = pcache.pool
            if tr.enabled:
                pcache.pool.tracer = tr
        else:
            # engine-placed: KV-head-sharded under a serving mesh
            cache = eng.init_row_cache(self.max_slots, buf)
        cur = np.zeros((self.max_slots,), np.int32)
        upcoming = deque(sorted(records, key=lambda r: r.arrival_s))
        pending: deque = deque()           # arrived, payloads prefetching
        active: Dict[int, RequestRecord] = {}
        wanted: Dict[str, int] = {}        # paged: chunk -> pending loaders
        t0 = time.perf_counter()
        now = lambda: time.perf_counter() - t0

        def start_loads(r: RequestRecord):
            """Classify chunks + kick the flash reads for one request.
            Requires every artifact to exist (``artifact_ready``)."""
            if self.paged and self.streaming:
                # block-granular admission: chunk classification and the
                # per-chunk streams are opened by the pump (FIFO, capped at
                # max_slots concurrent streaming requests so queued streams
                # never exhaust the pool); no payload future is issued
                r.stream = _RowStream()
                return
            if self.paged:
                # chunks already GPU-resident, or in flight for an
                # earlier pending request, are *expected*: no flash read
                # is issued, and admit acquires the shared pages (or
                # falls back to a synchronous read in the rare case the
                # pages were reclaimed while this request queued). Only
                # admitted rows pin pages, so queue depth never inflates
                # the pinned working set; K queued requests wanting one
                # cold chunk still cost exactly one flash read.
                # Residency is checked under the engine's page key: on a
                # decode worker a refreshed chunk's resident stale
                # generation is NOT a hit — the fresh artifact is read
                for cid in r.req.chunk_ids:
                    if cid in r.to_load:
                        # within-request duplicate: this request's own
                        # load serves both occurrences (marking it
                        # expected would deadlock ready() on a wanted
                        # count this request itself holds)
                        continue
                    if (pcache.pool.has(eng.page_key(cid))
                            or wanted.get(cid, 0) > 0):
                        r.expected.append(cid)
                    else:
                        r.to_load.append(cid)
                        wanted[cid] = wanted.get(cid, 0) + 1
                r.loading = list(r.to_load)
                r.future = self.loader.load_many(r.to_load)
            else:
                # start the flash reads immediately: they overlap with
                # the decode steps below (per-request load/decode
                # overlap)
                r.future = self.loader.load_many(r.req.chunk_ids)

        def poll_arrivals():
            while upcoming and upcoming[0].arrival_s <= now():
                r = upcoming.popleft()
                tr.instant("arrive", req=order[id(r)])
                r.req = eng.prepare_request(r.question, r.max_new_tokens)
                # materialize-on-miss (DESIGN.md §14): a chunk with no
                # flash artifact parks the request behind a materialize
                # job instead of crashing the loader (or stalling a decode
                # slot); its loads start once the artifact is published
                missing = [c for c in r.req.chunk_ids
                           if not eng.artifact_ready(c)]
                if missing:
                    r.pending_mat = missing
                    tr.instant("park_materialize", req=order[id(r)],
                               chunks=len(missing))
                    for c in missing:
                        eng.request_materialize(c)
                else:
                    start_loads(r)
                pending.append(r)

        def poll_materialized():
            for r in pending:
                if r.pending_mat and all(eng.artifact_ready(c)
                                         for c in r.pending_mat):
                    r.pending_mat = []
                    start_loads(r)

        def start_streams(r: RequestRecord):
            """Streaming counterpart of the classification in start_loads:
            warm chunks (pool-resident, host-tier demoted, or already in
            flight for an earlier request) become *expected*; cold chunks
            get a block stream each plus a wanted registration so later
            requests mark them expected instead of double-reading."""
            st = r.stream
            for cid in r.req.chunk_ids:
                if cid in st.keys or cid in r.expected:
                    continue            # within-request duplicate
                key = eng.page_key(cid)
                if (pcache.pool.has(key) or pcache.pool.host_has(key)
                        or wanted.get(cid, 0) > 0):
                    # resident, demoted-to-host (the carry fold and admit
                    # compose both re-promote, zero flash bytes), or in
                    # flight for an earlier request
                    r.expected.append(cid)
                else:
                    st.keys.append(cid)
                    st.cursors[cid] = 0
                    st.blocks[cid] = []
                    st.streams[cid] = self.loader.load_stream(
                        cid, block_tokens=self.block_size)
                    wanted[cid] = wanted.get(cid, 0) + 1
            st.started = True

        def pump_streams():
            """Advance every pending request's streams between decode steps:
            drain completed blocks into the pool (begin / extend / commit
            each chunk's resident frontier) and fold the carry forward in
            retrieval-token order. All the compose-and-attend work a cold
            request needs is done by the time its last page lands —
            admission is just the finalize step."""
            live = sum(1 for p in pending
                       if p.stream is not None and p.stream.started
                       and not p.stream.done)
            for r in pending:
                st = r.stream
                if st is None or r.pending_mat:
                    continue
                if not st.started:
                    if live >= self.max_slots:
                        continue
                    start_streams(r)
                    live += 1
                # drain arrived blocks into the pool's resident frontier
                for cid in st.keys:
                    s = st.streams[cid]
                    key = eng.page_key(cid)
                    if s.error is not None:
                        raise s.error
                    if key in st.committed or s.n_tokens is None:
                        continue        # done, or header not read yet
                    if key not in st.begun:
                        try:
                            # stream lifecycle spans pump invocations:
                            # begun here, committed (or aborted at
                            # eviction) by a later pump once the flash
                            # stream drains; st.begun tracks it.
                            pcache.pool.begin_stream(  # repro: noqa[RP101]
                                key, s.n_tokens)
                        except RuntimeError:
                            # pool momentarily full (admitted rows + live
                            # stream reservations hold the pages): retry
                            # next pump once a row evicts or a sibling
                            # stream commits — unless nothing can release
                            if not active and not any(
                                    p.stream is not None
                                    and len(p.stream.begun)
                                    > len(p.stream.committed)
                                    for p in pending):
                                raise
                            continue
                        st.begun.add(key)
                    blks, st.cursors[cid] = s.drain_from(st.cursors[cid])
                    for (bt0, bt1, enc, nb) in blks:
                        pcache.pool.extend_stream(key, enc, bt0, bt1,
                                                  nbytes=nb)
                        st.blocks[cid].append((bt0, bt1, enc))
                    if (s.done and pcache.pool.stream_frontier(key)
                            == s.n_tokens):
                        pcache.pool.commit_stream(key)
                        # drop the commit-time ref: the pages join the
                        # refcount-0 LRU hot set (reclaimable, demotable)
                        # like any loaded chunk; the carry folds VALUES
                        # from the buffered blocks, so it needs no pin,
                        # and the admit-time re-park covers the rare
                        # reclaimed-before-admit race
                        pcache.pool.release(key)
                        st.committed.add(key)
                        st.bytes += s.total_bytes + s.header_bytes
                        wanted[cid] -= 1
                st.done = len(st.committed) == len(st.keys)
                # seed the carry once every chunk's token count is known.
                # The retrieval index already knows each ingested chunk's
                # length (eng.chunk_n_tokens), so a full-stack engine seeds
                # at stream START — waiting on stream headers here used to
                # delay the whole fold behind the LAST header's link slot.
                # Stream headers / the pool remain the source of truth when
                # the index can't answer (disaggregated DecodeWorker).
                cids = r.req.chunk_ids
                if st.carry is None and not st.carry_dropped and st.keys:
                    meta_len = getattr(eng, "chunk_n_tokens",
                                       lambda _c: None)

                    def _len(c):
                        n = (st.streams[c].n_tokens if c in st.streams
                             else pcache.pool.chunk_tokens(eng.page_key(c)))
                        return n if n is not None else meta_len(c)

                    lens = [_len(c) for c in cids]
                    if all(x is not None for x in lens):
                        st.n_doc = int(sum(lens))
                        st.carry = eng.begin_streaming_prefix(
                            r.req, st.n_doc, bucket=self.block_size)
                # fold the carry forward, strictly in retrieval-token order
                if st.carry is None:
                    continue
                while st.fold_idx < len(cids):
                    cid = cids[st.fold_idx]
                    key = eng.page_key(cid)
                    if cid in st.streams:
                        blks = st.blocks[cid]
                        while st.fold_blk < len(blks):
                            _bt0, bt1, enc = blks[st.fold_blk]
                            eng.feed_streaming_block(st.carry, enc)
                            st.fold_off = bt1
                            st.fold_blk += 1
                        nt = st.streams[cid].n_tokens
                        if nt is None or st.fold_off < nt:
                            break       # tail blocks still in flight
                    elif pcache.pool.has(key):
                        eng.feed_streaming_resident(st.carry, pcache.pool,
                                                    key)
                    elif (pcache.pool.host_has(key)
                            and pcache.pool.promote(key) is not None):
                        # zero-flash rehydration just to fold the values;
                        # release straight back into the LRU (the admit
                        # compose re-acquires or re-promotes)
                        eng.feed_streaming_resident(st.carry, pcache.pool,
                                                    key)
                        pcache.pool.release(key)
                    elif wanted.get(cid, 0) > 0:
                        break           # another request's load lands it
                    else:
                        # expected pages vanished (reclaimed, no host copy,
                        # nobody reloading): drop the carry — the admit-time
                        # re-park reloads the pages and the admission falls
                        # back to the all-at-once prefill
                        st.carry = None
                        st.carry_dropped = True
                        break
                    st.fold_idx += 1
                    st.fold_off = 0
                    st.fold_blk = 0

        def finish(r: RequestRecord):
            ids = r.tokens
            if EOS in ids:
                ids = ids[:ids.index(EOS)]
            r.answer = eng.tok.decode(ids)
            r.finish_s = now()
            reg.counter("serve.new_tokens").inc(len(r.tokens))
            reg.hist("request.latency_s").observe(r.latency_s)
            reg.hist("request.ttft_s").observe(r.ttft_s)
            reg.hist("request.queue_wait_s").observe(r.queue_wait_s)
            reg.hist("request.flash_bytes").observe(r.flash_bytes)
            tr.instant("finish", req=order[id(r)], tokens=len(r.tokens))

        def admit(r: RequestRecord, slot: int) -> bool:
            """Compose + sub-prefill one row into ``slot``. Returns False if
            the request finished at its first token (slot stays free).

            The admission window is phase-split (DESIGN.md §15): flash-read
            wait, compose, and prefill compute are separate spans/counters —
            ``metrics.prefill_s`` means compose + prefill COMPUTE only,
            where it used to lump the whole ``t_adm`` window (admission
            bookkeeping and load stall included)."""
            nonlocal cache
            i = order[id(r)]
            r.queue_wait_s = now() - r.arrival_s
            t_adm = time.perf_counter()
            with tr.span("admit", req=i, slot=slot):
                if self.paged:
                    st = r.stream
                    with tr.span("load_wait", req=i):
                        t = time.perf_counter()
                        payloads = dict(r.preloaded)
                        if r.future is not None:
                            payloads.update(zip(r.loading,
                                                r.future.result()))
                        r.load_stall_s = time.perf_counter() - t
                    with tr.span("compose", req=i,
                                 chunks=len(r.req.chunk_ids)):
                        t = time.perf_counter()
                        n_doc, flash_bytes, nbytes, hits, misses = \
                            eng.compose_row_paged(r.req, pcache, slot,
                                                  payloads)
                        r.compose_s = time.perf_counter() - t
                    for cid in r.to_load:
                        wanted[cid] -= 1
                    if st is not None:
                        # streamed chunks were real flash reads that compose
                        # saw as pool hits — reattribute for the counters
                        # (min-guard: a streamed chunk reclaimed before
                        # admit re-entered compose as a genuine miss)
                        n_str = min(len(st.committed), hits)
                        hits -= n_str
                        misses += n_str
                        flash_bytes += st.bytes
                    streamed = (st is not None and st.carry is not None
                                and st.carry.n_seen == n_doc)
                    with tr.span("prefill", req=i, streamed=streamed):
                        t = time.perf_counter()
                        if streamed:
                            first = eng.prefill_row_streamed(
                                pcache, slot, r.req.prompt, st.carry)
                        else:
                            first = eng.prefill_row_paged(pcache, slot,
                                                          r.req.prompt)
                        r.prefill_s = time.perf_counter() - t
                    if st is not None:
                        reg.counter("serve.streamed_admits" if streamed
                                    else "serve.streamed_fallbacks").inc()
                    reg.counter("serve.chunk_hits").inc(hits)
                    reg.counter("serve.chunk_misses").inc(misses)
                else:
                    with tr.span("load_wait", req=i):
                        t = time.perf_counter()
                        r.req.payloads = r.future.result()
                        r.load_stall_s = time.perf_counter() - t
                    with tr.span("compose", req=i,
                                 chunks=len(r.req.chunk_ids)):
                        t = time.perf_counter()
                        row, n_doc, nbytes = eng.compose_row(r.req, buf)
                        r.compose_s = time.perf_counter() - t
                    with tr.span("prefill", req=i):
                        t = time.perf_counter()
                        first, row = eng.prefill_row(row, r.req.prompt)
                        r.prefill_s = time.perf_counter() - t
                    # flash bytes are attributed to the request that
                    # initiated each read; coalesced in-flight duplicates
                    # cost 0 here
                    flags = getattr(r.future, "initiated_flags",
                                    [True] * len(r.req.payloads))
                    flash_bytes = sum(len(p) for p, owned
                                      in zip(r.req.payloads, flags) if owned)
                    reg.counter("serve.chunk_misses").inc(
                        len(r.req.chunk_ids))
            adm_total = time.perf_counter() - t_adm
            reg.counter("phase.load_stall_s").inc(r.load_stall_s)
            reg.counter("phase.compose_s").inc(r.compose_s)
            reg.counter("phase.prefill_s").inc(r.prefill_s)
            # what's left of the window is genuine admission bookkeeping
            reg.counter("phase.admission_s").inc(max(
                0.0, adm_total - r.load_stall_s - r.compose_s - r.prefill_s))
            reg.counter("serve.kv_bytes_composed").inc(nbytes)
            reg.counter("serve.flash_bytes").inc(flash_bytes)
            r.flash_bytes = flash_bytes
            r.n_doc_tokens = n_doc
            r.admit_s = now()
            r.tokens = [int(first[0])]
            r.first_token_s = now()
            tr.instant("first_token", req=i)
            if r.tokens[0] == EOS or r.max_new_tokens <= 1:
                if self.paged:
                    eng.release_row_paged(pcache, slot)
                finish(r)
                return False
            if not self.paged:
                cache = insert_cache_row(cache, slot, row)
            cur[slot] = r.tokens[0]
            active[slot] = r
            return True

        def ready(r: RequestRecord) -> bool:
            if r.stream is not None:
                st = r.stream
                if not st.started or not st.done:
                    return False     # streams not opened / still arriving
                if r.future is not None and not r.future.done():
                    return False     # re-park reloads still in flight
                if st.carry is not None and st.carry.n_seen != st.n_doc:
                    return False     # carry still folding (warm chunks an
                                     # earlier request is landing)
            elif r.future is None or not r.future.done():
                return False         # loads not started (materializing) /
                                     # still in flight
            # paged: a chunk another pending request is loading isn't
            # admissible until its pages land (wanted drops to 0 once the
            # loader admits; if the pages were since reclaimed the
            # pre-admit check below re-parks the request)
            return all(pcache.pool.has(eng.page_key(c))
                       or wanted.get(c, 0) == 0
                       for c in r.expected)

        def repark_reclaimed(r: RequestRecord) -> bool:
            """Admit-time reclaim race: ready() saw the expected pages (or a
            live wanted count), but they were reclaimed while the request
            queued and nobody is reloading them. Re-issue the loads and
            re-park instead of composing over freed blocks (the old
            behavior stalled the scheduler on a synchronous read)."""
            wants = list(r.expected) + (r.stream.keys
                                        if r.stream is not None else [])
            missing = [c for c in dict.fromkeys(wants)
                       if not pcache.pool.has(eng.page_key(c))
                       and not pcache.pool.host_has(eng.page_key(c))
                       and wanted.get(c, 0) == 0
                       and c not in r.preloaded]
            if not missing:
                return False
            if r.future is not None and r.future.done():
                # salvage payloads already read for this request
                r.preloaded.update(zip(r.loading, r.future.result()))
            for c in missing:
                if c in r.expected:
                    r.expected.remove(c)
                r.to_load.append(c)
                wanted[c] = wanted.get(c, 0) + 1
            r.loading = missing
            r.future = self.loader.load_many(missing)
            # NOTE: a completed carry stays valid across a re-park — it
            # folded the chunk VALUES, and the re-read bytes are the same
            # artifact — so the streamed prefill still runs at admit
            reg.counter("serve.reparks").inc()
            tr.instant("repark", req=order[id(r)], chunks=len(missing))
            return True

        while upcoming or pending or active:
            poll_arrivals()
            poll_materialized()
            if self.streaming:
                pump_streams()
            # backfill free slots with loaded requests (FIFO, skip-ahead only
            # past requests whose loads are still in flight)
            free = [s for s in range(self.max_slots) if s not in active]
            for slot in free:
                ready_r = next((r for r in pending if ready(r)), None)
                if ready_r is None:
                    break
                if self.pre_admit_hook is not None:
                    self.pre_admit_hook(ready_r)
                if self.paged and repark_reclaimed(ready_r):
                    continue
                pending.remove(ready_r)
                admit(ready_r, slot)
            if not active:
                in_flight = [r.future for r in pending
                             if r.future is not None]
                streams_live = any(
                    r.stream is not None and r.stream.started
                    and not r.stream.done for r in pending)
                if streams_live:
                    # blocks are landing every ~link/n_blocks seconds and
                    # each pump drains-then-folds them: a 2ms nap here
                    # would stack straight onto cold-request TTFT (the
                    # final block's drain latency is pure admission delay)
                    time.sleep(0.0002)
                elif in_flight:
                    # nothing decoding: wait for the FIRST load to land (not
                    # the oldest — a tiny chunk behind a huge one must not
                    # stall), briefly so arrivals keep being polled
                    cf.wait(in_flight, timeout=0.01,
                            return_when=cf.FIRST_COMPLETED)
                elif pending:
                    # every pending request is parked on materialization:
                    # yield so the materializer role gets cycles
                    time.sleep(0.002)
                elif upcoming:
                    time.sleep(max(0.0, min(
                        upcoming[0].arrival_s - now(), 0.01)))
                continue
            t_dec = time.perf_counter()
            tokens = jnp.asarray(cur)[:, None]
            with tr.span("decode_step", rows=len(active)):
                if self.paged:
                    fused_step = self.fused and eng.fused_step_supported(
                        tokens)
                    logits = eng.step_rows_paged(pcache, tokens,
                                                 fused=self.fused)
                else:
                    fused_step = False
                    logits, cache = eng.step_rows(cache, tokens)
                nxt = np.asarray(greedy(logits[:, -1]))
            step_dur = time.perf_counter() - t_dec
            reg.counter("phase.decode_step_s").inc(step_dur)
            reg.counter("decode.steps").inc()
            reg.counter("decode.row_steps").inc(len(active))
            if self.paged:
                pool = pcache.pool
                stats = getattr(pcache, "last_step_stats", None)
                if fused_step and stats is not None:
                    # measured side of the roofline join: bytes implied by
                    # the block tables actually staged this step
                    reg.counter("decode.kv_bytes_measured").inc(
                        fused_step_kv_bytes_measured(
                            pool, stats["blocks_live"], stats["rows_live"]))
                    reg.counter("decode.kv_bytes_stale").inc(
                        fused_step_kv_bytes_measured(
                            pool, stats["blocks_stale"],
                            self.max_slots - stats["rows_live"]))
                else:
                    # three-phase fallback moves the full dense working set
                    # regardless of occupancy — the model IS the measurement
                    reg.counter("decode.kv_bytes_measured").inc(
                        paged_step_kv_bytes_for_pool(
                            pool, [0] * self.max_slots, buf_size=buf,
                            fused=False))
            for r in active.values():
                # every live row waited out the whole step — latency
                # attribution, so the per-request phases sum to ≈ latency
                r.decode_share_s += step_dur
            for slot, r in list(active.items()):
                tok = int(nxt[slot])
                r.tokens.append(tok)
                cur[slot] = tok
                if tok == EOS or len(r.tokens) >= r.max_new_tokens:
                    if self.paged:
                        # eviction only drops THIS row's refs + private
                        # tail; pages shared with co-resident rows stay put
                        eng.release_row_paged(pcache, slot)
                    finish(r)
                    del active[slot]

        reg.gauge("serve.wall_s").set(now())
        if self.paged:
            # required working set only: refs>0 shared pages + private
            # tails. Refcount-0 LRU pages are a reclaimable hot-set cache
            # (the flash-read savings), not required residency.
            pool = pcache.pool
            reg.gauge("pool.hbm_kv_bytes_resident").set(
                pool.stats.peak_pinned_blocks * pool.bytes_per_block)
            reg.gauge("pool.resident_chunks").set(
                pool.stats.peak_resident_chunks)
            reg.gauge("pool.demotions").set(pool.stats.demotions)
            reg.gauge("pool.promotions").set(pool.stats.promotions)
        else:
            reg.gauge("pool.hbm_kv_bytes_resident").set(
                cache.k.nbytes + cache.v.nbytes)
        if tr.enabled:
            # flash-read wall times + the fraction hidden behind decode
            # steps (satellite of the streaming-admission claim). On a
            # tracer shared across runs these cover the tracer's lifetime,
            # not just this run — benches use a fresh tracer per run.
            try:
                for name, _ts, dur, _tid, _a in tr.spans():
                    if name == "flash_read":
                        reg.hist("serve.flash_read_s").observe(dur)
                reg.gauge("serve.load_overlap_frac").set(
                    span_overlap_frac(tr, "flash_read", "decode_step"))
            except ValueError:
                pass    # another role mid-span on a shared tracer
        # ServeMetrics is a derived view over the run's registry
        metrics = ServeMetrics.from_registry(
            reg, role=getattr(self.engine, "role", "both"))
        if self.paged:
            metrics.pool_shard_bytes = pcache.pool.device_bytes_per_shard()
        answers = [None] * n
        for r in records:
            answers[order[id(r)]] = r.answer
        return answers, metrics
