"""Batched request scheduling + the overlap pipeline (paper §III-C, Fig. 4).

``BatchScheduler`` groups requests into fixed-size batches (rows share the
composed-cache geometry: same top_k x chunk_tokens). With ``overlap=True`` the
flash reads + host-side deserialization for batch i+1 run in a prefetch thread
while the device decodes batch i — MatKV's storage-I/O / compute overlap. With
``overlap=False`` phases serialize, reproducing the paper's "basic MatKV" bar.

Prompts are right-padded to the batch max; first-token logits are gathered at
each row's true last position.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compose import compose_attn_cache
from repro.core.materialize import load_artifact
from repro.data.tokenizer import EOS
from repro.kvstore.async_loader import PrefetchPipeline
from repro.serving.engine import PhaseTimings, RagEngine
from repro.serving.sampling import greedy


@dataclass
class BatchResult:
    answers: List[str]
    timings: PhaseTimings


class BatchScheduler:
    def __init__(self, engine: RagEngine, batch_size: int = 4,
                 overlap: bool = False):
        if engine.cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError("BatchScheduler requires an attention-KV family")
        self.engine = engine
        self.batch_size = batch_size
        self.overlap = overlap

    # -- host-side load stage (runs in prefetch thread when overlapped) -------
    def _load_batch(self, questions: Sequence[str]):
        eng = self.engine
        rows = []
        nbytes = 0
        for q in questions:
            cids = eng.retrieve(q)
            if not cids:
                # empty retrieval: no chunk to replicate into the fixed
                # geometry — mark the row for the query-only fallback path
                rows.append(None)
                continue
            # fixed geometry: exactly top_k chunks per row
            while len(cids) < eng.top_k:
                cids.append(cids[-1])
            arts = []
            for cid in cids[:eng.top_k]:
                payload = eng.reader.get(cid)
                nbytes += len(payload)
                arts.append(load_artifact(eng.cfg, payload)[0])
            rows.append(arts)
        return rows, nbytes

    def _compose_batch(self, rows):
        """Stack per-row artifacts into a batched cache."""
        eng = self.engine
        n_chunks = len(rows[0])
        arts = []
        for j in range(n_chunks):
            k = jnp.concatenate([rows[b][j][0] for b in range(len(rows))],
                                axis=1)
            v = jnp.concatenate([rows[b][j][1] for b in range(len(rows))],
                                axis=1)
            arts.append((k, v))
        total = sum(a[0].shape[2] for a in arts)
        buf = total + 96
        return compose_attn_cache(eng.cfg, arts, buf, rerotate=eng.rerotate)

    def _prompts(self, questions: Sequence[str]):
        eng = self.engine
        proms = [eng._prompt(q) for q in questions]
        width = max(len(p) for p in proms)
        out = np.zeros((len(proms), width), np.int32)
        last = np.zeros((len(proms),), np.int32)
        for i, p in enumerate(proms):
            out[i, :len(p)] = p
            last[i] = len(p) - 1
        return jnp.asarray(out), jnp.asarray(last)

    # -- decode stage -----------------------------------------------------------
    def _serve_batch(self, questions, rows, timings: PhaseTimings,
                     max_new_tokens: int) -> List[str]:
        answers: List[Optional[str]] = [None] * len(questions)
        empty = [i for i, r in enumerate(rows) if r is None]
        if empty:
            # query-only fallback for empty-retrieval rows; the rest of the
            # batch keeps its fixed geometry
            eng = self.engine
            for i in empty:
                ans, t = eng.answer(questions[i], max_new_tokens=max_new_tokens,
                                    chunk_ids=[])
                timings.prefill_s += t.prefill_s
                timings.decode_s += t.decode_s
                timings.n_new_tokens += t.n_new_tokens
                answers[i] = ans
            keep = [i for i in range(len(questions)) if rows[i] is not None]
            if not keep:
                return answers
            for i, ans in zip(keep, self._serve_batch(
                    [questions[i] for i in keep], [rows[i] for i in keep],
                    timings, max_new_tokens)):
                answers[i] = ans
            return answers
        eng = self.engine
        t0 = time.perf_counter()
        cache = self._compose_batch(rows)
        prompts, last = self._prompts(questions)
        logits, cache = eng._subprefill(cache, prompts)
        jax.block_until_ready(logits)
        timings.prefill_s += time.perf_counter() - t0
        first = greedy(jnp.take_along_axis(
            logits, last[:, None, None].astype(jnp.int32), axis=1)[:, 0])
        t0 = time.perf_counter()
        toks, _ = eng._decode_loop(cache, first, max_new_tokens)
        timings.decode_s += time.perf_counter() - t0
        answers = []
        mat = np.stack(toks, axis=1)  # (B, T)
        for row in mat:
            ids = list(row)
            if EOS in ids:
                ids = ids[:ids.index(EOS)]
                # tokens actually emitted: through EOS inclusive — the
                # post-EOS padding the fixed-shape loop keeps decoding is
                # dead air, not useful tokens (ContinuousScheduler counts
                # len(r.tokens) the same way)
                timings.n_new_tokens += len(ids) + 1
            else:
                timings.n_new_tokens += len(ids)
            answers.append(eng.tok.decode(ids))
        return answers

    # -- top-level run -----------------------------------------------------------
    def run(self, questions: Sequence[str], max_new_tokens: int = 20
            ) -> Tuple[List[str], PhaseTimings]:
        batches = [list(questions[i:i + self.batch_size])
                   for i in range(0, len(questions), self.batch_size)]
        timings = PhaseTimings()
        answers: List[str] = []
        t_wall = time.perf_counter()

        if self.overlap:
            pipe = PrefetchPipeline(batches, self._load_batch, depth=1)
            for qs, (rows, nbytes) in pipe:
                timings.kv_bytes_loaded += nbytes
                answers.extend(self._serve_batch(qs, rows, timings,
                                                 max_new_tokens))
            # overlapped load time is whatever wasn't hidden:
            timings.load_s = max(0.0, (time.perf_counter() - t_wall)
                                 - timings.prefill_s - timings.decode_s)
        else:
            for qs in batches:
                t0 = time.perf_counter()
                rows, nbytes = self._load_batch(qs)
                timings.load_s += time.perf_counter() - t0
                timings.kv_bytes_loaded += nbytes
                answers.extend(self._serve_batch(qs, rows, timings,
                                                 max_new_tokens))
        return answers, timings
