from repro.data.pipeline import PrefetchIterator, batched
from repro.data.synthetic import KvQaTask, QaExample, f1_score, lm_stream
from repro.data.tokenizer import BOS, ByteTokenizer, EOS, PAD, SEP

__all__ = ["PrefetchIterator", "batched", "KvQaTask", "QaExample", "f1_score",
           "lm_stream", "BOS", "EOS", "PAD", "SEP", "ByteTokenizer"]
