"""Synthetic data generators.

1. ``lm_stream`` — token LM batches (mixture of Zipf unigrams + copy motifs so
   a model actually has something learnable) for the training substrate.
2. ``KvQaTask`` — the key-value question-answering corpus used for the
   accuracy benchmark (paper Table VI analogue, DESIGN.md §7): documents are
   collections of "key = value" facts; a query names a key; the answer is its
   value. Answering requires attending from the query into one retrieved
   document — exactly the self-attention pattern MatKV preserves — while
   cross-document attention is unnecessary, mirroring the paper's insight.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Tuple

import numpy as np

from repro.data.tokenizer import ByteTokenizer, EOS, SEP


def lm_stream(vocab_size: int, batch: int, seq_len: int, seed: int = 0
              ) -> Iterator[Dict[str, np.ndarray]]:
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(np.arange(1, vocab_size), size=(batch, seq_len + 1),
                          p=probs)
        # plant learnable copy motifs: x[t] == x[t-3] on random spans
        for b in range(batch):
            start = rng.integers(0, seq_len // 2)
            span = rng.integers(8, max(9, seq_len // 4))
            motif = toks[b, start:start + 3]
            reps = np.tile(motif, span // 3 + 1)[:span]
            toks[b, start:start + span] = reps
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


# ---------------------------------------------------------------------------
# KV-QA retrieval task
# ---------------------------------------------------------------------------

_WORDS = [
    "amber", "basil", "cedar", "delta", "ember", "fjord", "grove", "haven",
    "iris", "jade", "karst", "lotus", "maple", "nadir", "ocean", "pearl",
    "quartz", "raven", "slate", "topaz", "umber", "vapor", "willow", "xenon",
    "yarrow", "zephyr", "birch", "coral", "dune", "elm",
]


def _word(rng) -> str:
    return rng.choice(_WORDS) + str(rng.integers(10, 99))


@dataclass
class QaExample:
    question: str
    answer: str
    gold_doc: str


class KvQaTask:
    """n_docs documents, each with n_facts 'key = value' lines."""

    def __init__(self, n_docs: int = 32, n_facts: int = 8, seed: int = 0):
        rng = np.random.default_rng(seed)
        self.tok = ByteTokenizer()
        self.docs: Dict[str, str] = {}
        self.facts: List[Tuple[str, str, str]] = []  # (key, value, doc_id)
        used = set()
        for d in range(n_docs):
            doc_id = f"doc{d:04d}"
            lines = []
            for _ in range(n_facts):
                key = _word(rng) + " " + _word(rng)
                while key in used:
                    key = _word(rng) + " " + _word(rng)
                used.add(key)
                val = _word(rng)
                lines.append(f"the {key} is {val}.")
                self.facts.append((key, val, doc_id))
            self.docs[doc_id] = " ".join(lines)
        self._rng = rng

    def examples(self, n: int) -> List[QaExample]:
        idx = self._rng.choice(len(self.facts), size=n, replace=True)
        return [QaExample(question=f"what is the {self.facts[i][0]}?",
                          answer=self.facts[i][1],
                          gold_doc=self.facts[i][2]) for i in idx]

    # -- tokenized forms --------------------------------------------------------
    def doc_tokens(self, doc_id: str) -> np.ndarray:
        return self.tok.encode(self.docs[doc_id])

    def prompt_tokens(self, question: str) -> np.ndarray:
        # EXACTLY the serving engine's prompt layout (RagEngine._prompt):
        # SEP question SEP — train/serve format mismatches here cost the
        # whole benchmark (a 2-layer model has no robustness to spare)
        return np.concatenate([[SEP], self.tok.encode(" " + question + " "),
                               [SEP]])

    def train_example(self, max_len: int, n_context: int = 2,
                      chunk_tokens: int = 64) -> Tuple[np.ndarray, np.ndarray]:
        """(tokens, loss_mask): [docs | SEP question SEP answer EOS], loss on
        the answer tokens only. Docs are padded to ``chunk_tokens`` multiples
        with PAD — the same layout the serving engine produces when it
        concatenates materialized chunk KVs."""
        i = int(self._rng.integers(len(self.facts)))
        key, val, doc_id = self.facts[i]
        others = [d for d in self.docs if d != doc_id]
        picks = list(self._rng.choice(others, size=n_context - 1,
                                      replace=False)) if n_context > 1 else []
        doc_ids = picks + [doc_id]
        self._rng.shuffle(doc_ids)

        def chunked(tokens: np.ndarray) -> np.ndarray:
            n = -(-len(tokens) // chunk_tokens) * chunk_tokens
            out = np.zeros((n,), np.int32)     # PAD = 0
            out[:len(tokens)] = tokens
            return out

        parts = [chunked(self.tok.encode(self.docs[d])) for d in doc_ids]
        prompt = self.prompt_tokens(f"what is the {key}?")
        ans = np.concatenate([self.tok.encode(val), [EOS]])
        toks = np.concatenate(parts + [prompt, ans]).astype(np.int32)
        mask = np.zeros_like(toks)
        mask[-len(ans):] = 1
        if len(toks) > max_len:
            toks = toks[-max_len:]
            mask = mask[-max_len:]
        return toks, mask


def f1_score(pred: str, gold: str) -> float:
    """Token-level F1 (the paper's QA metric)."""
    p = pred.lower().split()
    g = gold.lower().split()
    if not p or not g:
        return float(p == g)
    common = 0
    gg = list(g)
    for t in p:
        if t in gg:
            gg.remove(t)
            common += 1
    if common == 0:
        return 0.0
    prec = common / len(p)
    rec = common / len(g)
    return 2 * prec * rec / (prec + rec)
