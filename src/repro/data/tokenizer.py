"""Byte-level tokenizer (no external vocab files): token = byte + offset for a
few special tokens. Enough to run real text through the RAG pipeline and the
synthetic QA benchmarks; any vocab_size >= 260 model config can consume it."""

from __future__ import annotations

import numpy as np

PAD = 0
BOS = 1
EOS = 2
SEP = 3  # document / query separator in RAG prompts
_OFFSET = 4


class ByteTokenizer:
    vocab_size = 256 + _OFFSET

    def encode(self, text: str, add_bos: bool = False,
               add_eos: bool = False) -> np.ndarray:
        ids = [b + _OFFSET for b in text.encode("utf-8")]
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return np.asarray(ids, np.int32)

    def decode(self, ids) -> str:
        # skip specials and out-of-byte-range ids (models may have
        # vocab_size > 260; an untrained one can emit those ids)
        bs = bytes(int(i) - _OFFSET for i in np.asarray(ids).ravel()
                   if _OFFSET <= int(i) < 256 + _OFFSET)
        return bs.decode("utf-8", errors="replace")
