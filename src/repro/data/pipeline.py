"""Host-side data pipeline: batching + background prefetch of the next batch
(device-feed overlap, the training-side sibling of the serving prefetcher)."""

from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator

import numpy as np


class PrefetchIterator:
    """Wrap a batch iterator; a daemon thread keeps ``depth`` batches ready."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                self._q.put(item)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item


def batched(task, batch: int, max_len: int, n_context: int = 2,
            seed: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Batch KvQaTask training examples with left-padding to max_len."""
    while True:
        toks = np.zeros((batch, max_len), np.int32)
        labels = np.zeros((batch, max_len), np.int32)
        mask = np.zeros((batch, max_len), np.float32)
        for b in range(batch):
            t, m = task.train_example(max_len, n_context)
            toks[b, -len(t):] = t
            # next-token prediction: labels shifted left
            labels[b, -len(t):-1] = t[1:]
            mask[b, -len(t):-1] = m[1:]
        yield {"tokens": toks, "labels": labels, "loss_mask": mask}
