from repro.retrieval.embed import EMBED_DIM, HashingEmbedder
from repro.retrieval.vectordb import VectorDB

__all__ = ["EMBED_DIM", "HashingEmbedder", "VectorDB"]
