"""Embedding model stub for the vector DB (paper uses all-MiniLM-L6-v2; any
embedding model is interchangeable here — §IV "customizable"). We use a seeded
random-projection bag-of-tokens embedder: deterministic, order-insensitive at
the n-gram level, good enough to give realistic skewed retrieval behaviour for
the system benchmarks without shipping a trained encoder."""

from __future__ import annotations

import numpy as np

EMBED_DIM = 128


class HashingEmbedder:
    def __init__(self, dim: int = EMBED_DIM, vocab_size: int = 1 << 16,
                 seed: int = 0):
        rng = np.random.default_rng(seed)
        self.dim = dim
        self.vocab_size = vocab_size
        self.table = rng.standard_normal((vocab_size, dim), np.float32)
        self.table /= np.linalg.norm(self.table, axis=1, keepdims=True)

    def embed_tokens(self, tokens: np.ndarray) -> np.ndarray:
        idx = np.asarray(tokens, np.int64) % self.vocab_size
        # bag of tokens + bigrams for mild order sensitivity
        vec = self.table[idx].sum(0)
        if len(idx) > 1:
            bi = (idx[:-1] * 31 + idx[1:]) % self.vocab_size
            vec = vec + 0.5 * self.table[bi].sum(0)
        n = np.linalg.norm(vec)
        return (vec / n if n > 0 else vec).astype(np.float32)
