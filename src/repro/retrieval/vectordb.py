"""Minimal in-memory vector database (the paper uses ChromaDB): exact top-k
cosine search over chunk embeddings, with the chunk_id <-> flash-KV linkage
that MatKV's delete path relies on (paper §IV delete(O))."""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


class VectorDB:
    def __init__(self, dim: int):
        self.dim = dim
        self._ids: List[str] = []
        self._vecs: List[np.ndarray] = []
        self._matrix: Optional[np.ndarray] = None
        self._pos: Dict[str, int] = {}

    def __len__(self) -> int:
        return len(self._ids)

    def add(self, chunk_id: str, embedding: np.ndarray) -> None:
        if chunk_id in self._pos:
            return
        v = np.asarray(embedding, np.float32)
        n = np.linalg.norm(v)
        if n > 0:
            v = v / n
        self._pos[chunk_id] = len(self._ids)
        self._ids.append(chunk_id)
        self._vecs.append(v)
        self._matrix = None

    def delete(self, chunk_id: str, kv_store=None) -> bool:
        """Remove the embedding and (per the paper) the stale materialized KV."""
        pos = self._pos.pop(chunk_id, None)
        if pos is None:
            return False
        self._ids.pop(pos)
        self._vecs.pop(pos)
        self._pos = {c: i for i, c in enumerate(self._ids)}
        self._matrix = None
        if kv_store is not None:
            kv_store.delete(chunk_id)
        return True

    def _mat(self) -> np.ndarray:
        if self._matrix is None:
            self._matrix = (np.stack(self._vecs) if self._vecs
                            else np.zeros((0, self.dim), np.float32))
        return self._matrix

    def search(self, query: np.ndarray, top_k: int = 5
               ) -> List[Tuple[str, float]]:
        m = self._mat()
        if not len(m):
            return []
        q = np.asarray(query, np.float32)
        n = np.linalg.norm(q)
        if n > 0:
            q = q / n
        scores = m @ q
        k = min(top_k, len(scores))
        idx = np.argpartition(-scores, k - 1)[:k]
        idx = idx[np.argsort(-scores[idx])]
        return [(self._ids[i], float(scores[i])) for i in idx]
