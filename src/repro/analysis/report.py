"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run's
results.jsonl — and, when the serving benches have appended records to
``experiments/serving/results.jsonl`` (``benchmarks.common.emit_result``),
the §Serving tables: per-run throughput/latency/TTFT and the
predicted-vs-measured per-step KV bytes join (DESIGN.md §15).

Usage:
  PYTHONPATH=src python -m repro.analysis.report [--results PATH] [--mesh 16x16]
      [--serving PATH]
"""

from __future__ import annotations

import argparse
import json
from collections import OrderedDict
from pathlib import Path

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_rows(path: str):
    """Latest row per (arch, shape, mesh) wins."""
    rows: "OrderedDict[tuple, dict]" = OrderedDict()
    for line in Path(path).read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        rows[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return list(rows.values())


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(rows, mesh: str) -> str:
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful FLOPs | peak/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"**ERROR** | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['peak_memory_per_device'] / 2**30:.2f} GiB |")
    return "\n".join(out)


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | status | peak/dev | HLO FLOPs/chip | "
           "HLO bytes/chip | collective bytes/chip | top collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped ({r['reason'][:60]}…) | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"**ERROR** | — | — | — | — | — |")
            continue
        colls = sorted((r.get("collectives") or {}).items(),
                       key=lambda kv: -kv[1])[:2]
        cstr = ", ".join(f"{k}:{v / 2**20:.0f}MiB" for k, v in colls) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['peak_memory_per_device'] / 2**30:.2f} GiB | "
            f"{r['hlo_flops']:.3g} | {r['hlo_bytes']:.3g} | "
            f"{r['collective_bytes']:.3g} | {cstr} |")
    return "\n".join(out)


def pick_hillclimb_pairs(rows, mesh: str = "16x16"):
    """The three §Perf pairs: worst useful-FLOPs fraction, most
    collective-bound, most MatKV-representative (decode with attention KV)."""
    ok = [r for r in rows if r.get("mesh") == mesh and r["status"] == "ok"]
    worst = min(ok, key=lambda r: r["useful_flops_ratio"] or 1e9)
    coll = max(ok, key=lambda r: r["collective_s"]
               / max(r["compute_s"], r["memory_s"], 1e-12))
    return worst, coll


def load_serving_rows(path: str):
    """All serving records with a known schema, append order preserved
    (unlike the dry-run, repeated runs of one bench are distinct rows)."""
    rows = []
    p = Path(path)
    if not p.exists():
        return rows
    for line in p.read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        if r.get("schema") == 1 and "suite" in r:
            rows.append(r)
    return rows


def serving_table(rows) -> str:
    out = ["| suite | run | role | tok/s | decode tok/s | p95 lat | "
           "p95 TTFT | hit rate |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        m = r.get("metrics")
        if not m:
            continue
        d = m.get("derived", {})
        out.append(
            f"| {r['suite']} | {r['name']} | {m.get('role', '?')} | "
            f"{d.get('tokens_per_s', 0.0):.1f} | "
            f"{d.get('decode_tokens_per_s', 0.0):.1f} | "
            f"{_fmt_s(d.get('p95_latency_s', 0.0))} | "
            f"{_fmt_s(d.get('p95_ttft_s', 0.0))} | "
            f"{d.get('chunk_hit_rate', 0.0):.2f} |")
    return "\n".join(out)


def serving_report(path: str) -> str:
    """The §Serving section, or "" when no serving results exist (the
    default dry-run-only report is then unchanged)."""
    rows = load_serving_rows(path)
    if not rows:
        return ""
    out = ["## Serving — results.jsonl", serving_table(rows)]
    pm = [dict(r, name=f"{r['suite']}/{r['name']}") for r in rows
          if "predicted_step_bytes" in r]
    if pm:
        from repro.obs import comparison_table
        out += ["", "## Predicted vs measured — per-step KV bytes",
                comparison_table(pm)]
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="experiments/dryrun/results.jsonl")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--serving", default="experiments/serving/results.jsonl",
                    help="serving-bench results.jsonl (rendered only when "
                         "present)")
    args = ap.parse_args()
    serving = serving_report(args.serving)
    if not Path(args.results).exists():
        if serving:
            print(serving)
            return
        raise SystemExit(f"error: no results at {args.results} and no "
                         f"serving results at {args.serving}")
    rows = load_rows(args.results)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9,
                             r.get("mesh", "")))
    print("## Roofline —", args.mesh)
    print(roofline_table(rows, args.mesh))
    print()
    print("## Dry-run detail")
    print(dryrun_table(rows))
    w, c = pick_hillclimb_pairs(rows, args.mesh)
    print()
    print(f"worst useful-FLOPs pair: {w['arch']} x {w['shape']} "
          f"(ratio {w['useful_flops_ratio']:.2f})")
    print(f"most collective-bound pair: {c['arch']} x {c['shape']} "
          f"(coll {_fmt_s(c['collective_s'])} vs "
          f"max(comp,mem) {_fmt_s(max(c['compute_s'], c['memory_s']))})")
    if serving:
        print()
        print(serving)


if __name__ == "__main__":
    main()
