"""Render the EXPERIMENTS.md §Dry-run / §Roofline tables from the dry-run's
results.jsonl.

Usage:
  PYTHONPATH=src python -m repro.analysis.report [--results PATH] [--mesh 16x16]
"""

from __future__ import annotations

import argparse
import json
from collections import OrderedDict
from pathlib import Path

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_rows(path: str):
    """Latest row per (arch, shape, mesh) wins."""
    rows: "OrderedDict[tuple, dict]" = OrderedDict()
    for line in Path(path).read_text().splitlines():
        try:
            r = json.loads(line)
        except json.JSONDecodeError:
            continue
        rows[(r["arch"], r["shape"], r.get("mesh", "?"))] = r
    return list(rows.values())


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(rows, mesh: str) -> str:
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful FLOPs | peak/dev |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"**ERROR** | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(r['compute_s'])} | "
            f"{_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['peak_memory_per_device'] / 2**30:.2f} GiB |")
    return "\n".join(out)


def dryrun_table(rows) -> str:
    out = ["| arch | shape | mesh | status | peak/dev | HLO FLOPs/chip | "
           "HLO bytes/chip | collective bytes/chip | top collectives |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"skipped ({r['reason'][:60]}…) | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"**ERROR** | — | — | — | — | — |")
            continue
        colls = sorted((r.get("collectives") or {}).items(),
                       key=lambda kv: -kv[1])[:2]
        cstr = ", ".join(f"{k}:{v / 2**20:.0f}MiB" for k, v in colls) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['peak_memory_per_device'] / 2**30:.2f} GiB | "
            f"{r['hlo_flops']:.3g} | {r['hlo_bytes']:.3g} | "
            f"{r['collective_bytes']:.3g} | {cstr} |")
    return "\n".join(out)


def pick_hillclimb_pairs(rows, mesh: str = "16x16"):
    """The three §Perf pairs: worst useful-FLOPs fraction, most
    collective-bound, most MatKV-representative (decode with attention KV)."""
    ok = [r for r in rows if r.get("mesh") == mesh and r["status"] == "ok"]
    worst = min(ok, key=lambda r: r["useful_flops_ratio"] or 1e9)
    coll = max(ok, key=lambda r: r["collective_s"]
               / max(r["compute_s"], r["memory_s"], 1e-12))
    return worst, coll


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="experiments/dryrun/results.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    rows = load_rows(args.results)
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 9,
                             r.get("mesh", "")))
    print("## Roofline —", args.mesh)
    print(roofline_table(rows, args.mesh))
    print()
    print("## Dry-run detail")
    print(dryrun_table(rows))
    w, c = pick_hillclimb_pairs(rows, args.mesh)
    print()
    print(f"worst useful-FLOPs pair: {w['arch']} x {w['shape']} "
          f"(ratio {w['useful_flops_ratio']:.2f})")
    print(f"most collective-bound pair: {c['arch']} x {c['shape']} "
          f"(coll {_fmt_s(c['collective_s'])} vs "
          f"max(comp,mem) {_fmt_s(max(c['compute_s'], c['memory_s']))})")


if __name__ == "__main__":
    main()
