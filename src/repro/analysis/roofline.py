"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs            / (chips * peak_FLOP/s)
  memory     = HLO_bytes_accessed   / (chips * HBM_bw)
  collective = collective_bytes     / (chips * n_links * link_bw)

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective bytes are
NOT in cost_analysis: we parse the optimized HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute. Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI (we credit 3 links/chip on the 2D torus +
pod interconnect).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW_PER_LINK = 50e9       # bytes / s / link
ICI_LINKS = 3                # usable links per chip (2D torus + pod axis)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "bf16[16,4096,128]{2,1,0}"  (layout suffix optional)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")


def _shape_bytes(m: re.Match) -> int:
    dt, dims = m.group(1), m.group(2)
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum output-shape bytes of every collective op in optimized HLO text.

    HLO ops are printed as ``<shape> <opname>(...)``; for collectives the
    output shape equals the per-participant payload (all-gather output is the
    gathered tensor, all-reduce output the reduced tensor, etc.), which is the
    natural "bytes moved per chip" proxy for the roofline term.
    """
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        # match: "%name = bf16[...] all-gather(...)" or fusion-free forms
        mo = re.search(r"=\s+((?:\([^)]*\))|(?:[a-z0-9]+\[[\d,]*\]\S*))\s+"
                       r"([a-z\-]+)", stripped)
        if not mo:
            continue
        op = mo.group(2)
        if op.rstrip("-start").rstrip("-done") not in _COLLECTIVES \
                and op not in _COLLECTIVES:
            continue
        shapes = mo.group(1)
        nbytes = sum(_shape_bytes(m) for m in _SHAPE_RE.finditer(shapes))
        base = op.replace("-start", "").replace("-done", "")
        if op.endswith("-done"):
            continue  # avoid double counting start/done pairs
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + nbytes
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    model_flops: float
    peak_memory_per_device: int
    collectives: Dict[str, int] = field(default_factory=dict)

    @property
    def compute_s(self) -> float:
        # cost_analysis() on the SPMD-partitioned module is PER-DEVICE
        # (verified empirically: an 8-way-sharded matmul reports total/8)
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        # collective_bytes parsed from single-program HLO = per-chip payload
        return self.collective_bytes / (ICI_LINKS * ICI_BW_PER_LINK)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs (both per-chip). < 1 means remat /
        redundant compute; > 1 would mean the compiler lost useful work."""
        if not self.hlo_flops:
            return 0.0
        return (self.model_flops / self.chips) / self.hlo_flops

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.collective_bytes,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "peak_memory_per_device": self.peak_memory_per_device,
            "collectives": self.collectives,
        }


def model_flops_for(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N*D for train (N = active params, D = tokens incl. the
    backward pass), 2*N*D for forward-only prefill, 2*N per decoded token."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # one token per sequence


def time_scan_correction(cfg, shape, chips: int):
    """Analytic correction for time-step recurrences (mamba / RG-LRU), whose
    lax.scan bodies XLA's cost model counts exactly once. Structural scans are
    unrolled at dry-run lowering (REPRO_UNROLL=1, see models.scan_utils); the
    time axis cannot be, so we add (S-1) iterations' worth of per-device
    flops/bytes here. Returns (extra_flops, extra_bytes), both per-device."""
    if cfg.family not in ("ssm", "hybrid") or shape.kind == "decode":
        return 0.0, 0.0
    s = shape.seq_len
    b = shape.global_batch
    if cfg.family == "ssm":
        n_rec = cfg.num_layers
        width, state = cfg.d_inner, cfg.ssm_state
        flops_tok = 10.0 * width * state
        bytes_tok = 2.0 * width * state * 4 + 3.0 * width * 4
    else:
        n_rec = sum(1 for k in cfg.layer_kinds if k == "recurrent")
        width, state = cfg.rglru_width, 1
        flops_tok = 12.0 * width
        bytes_tok = 2.0 * width * 4 + 4.0 * width * 4
    mult = 3.0 if shape.kind == "train" else 1.0  # fwd + bwd + remat
    extra_flops = mult * n_rec * b * (s - 1) * flops_tok / chips
    extra_bytes = mult * n_rec * b * (s - 1) * bytes_tok / chips
    return extra_flops, extra_bytes


def paged_step_kv_bytes(n_layers: int, kv_heads: int, head_dim: int,
                        row_lengths, block_size: int, buf_size: int, *,
                        storage_bytes: int, scale_bytes: int = 0,
                        act_bytes: int = 2, fused: bool = False) -> int:
    """Analytic HBM *KV* traffic of ONE paged decode step (all layers, K+V),
    the DESIGN §Roofline-accounting model for the serving hot loop. Only KV
    movement is counted — weights/activations are identical between the two
    pipelines and cancel out of the comparison.

    Three-phase (gather -> dense step -> scatter), per layer and per K/V
    tensor: the gather reads the row's pool slots (storage width + scales)
    and writes an activation-width dense (B, S_buf) view; the jitted step
    reads that view for attention and writes the updated view buffers back
    out (they are jit outputs); the scatter persists one token per row at
    storage width. Every term is full-working-set: 1 storage-width + ~3
    activation-width (B * S_buf) round trips per step.

    Fused, per layer and per K/V tensor: each row's occupied pages stream
    from HBM exactly once at STORAGE width (``ceil(len / block)`` blocks —
    whole blocks, since partial pages are staged whole), plus the one-token
    write-back. Nothing activation-width and (B, S_buf)-sized ever touches
    HBM; dequant and the dense-order view live in VMEM.

    ``row_lengths`` are per-row token counts INCLUDING the step's new token
    (pass ``[buf_size] * B`` for the worst case). Returns total bytes.
    """
    b = len(row_lengths)
    vec_store = kv_heads * (head_dim * storage_bytes + scale_bytes)
    vec_act = kv_heads * head_dim * act_bytes
    token_write = b * vec_store
    if fused:
        blocks = sum(-(-max(int(l), 1) // block_size) for l in row_lengths)
        page_read = blocks * block_size * vec_store
        return 2 * n_layers * (page_read + token_write)
    dense = b * buf_size
    gather = dense * (vec_store + vec_act)       # pool read + view write
    step = 2 * dense * vec_act                   # attention read + new buffers
    return 2 * n_layers * (gather + step + token_write)


def paged_step_kv_bytes_for_pool(pool, row_lengths, *, buf_size: int,
                                 fused: bool = False) -> int:
    """``paged_step_kv_bytes`` with widths read off a live ``PagedKvPool``
    (storage dtype, scale dtype, view dtype) — what the serving benchmarks
    assert the fused-vs-three-phase HBM win against."""
    import jax.numpy as jnp
    scale_b = (0 if pool.k_scale is None
               else jnp.dtype(pool.k_scale.dtype).itemsize)
    return paged_step_kv_bytes(
        pool.n_layers, pool.cfg.num_kv_heads, pool.cfg.head_dim,
        row_lengths, pool.block_size, buf_size,
        storage_bytes=jnp.dtype(pool.storage_dtype).itemsize,
        scale_bytes=scale_b, act_bytes=jnp.dtype(pool.dtype).itemsize,
        fused=fused)


def streaming_ttft_model(payload_bytes: int, read_gbps: float, *,
                         compose_s: float, prefill_s: float,
                         fold_s: float = 0.0,
                         finalize_s: float) -> dict:
    """Analytic TTFT for one cold request, baseline vs streamed admission
    (DESIGN.md §16) — the predicted side of the bench's
    predicted-vs-measured join.

    Baseline (all-or-nothing): the request waits for the FULL artifact
    payload on the flash link, then composes the document KV into its row
    and runs the prompt prefill:

        baseline = link_s + compose_s + prefill_s

    Streamed: blocks fold into the online-softmax carry as they land, so
    the admission-side work rides in the link's shadow; what remains on
    the critical path after the last block is the finalize step (the
    streamed prompt prefill against the completed carry):

        streaming = max(link_s, fold_s) + finalize_s

    ``fold_s`` is the total per-block fold compute (usually link-dominated
    and therefore free); ``finalize_s`` is the measured streamed-prefill
    step. All times in seconds, ``read_gbps`` in GB/s (1e9 bytes).
    """
    link_s = payload_bytes / (read_gbps * 1e9) if read_gbps else 0.0
    baseline = link_s + compose_s + prefill_s
    streaming = max(link_s, fold_s) + finalize_s
    return {
        "payload_bytes": int(payload_bytes),
        "read_gbps": float(read_gbps),
        "link_s": link_s,
        "baseline_ttft_s": baseline,
        "streaming_ttft_s": streaming,
        "predicted_ratio": streaming / baseline if baseline else 0.0,
    }


def analyze(arch: str, shape, mesh_name: str, chips: int, compiled,
            cfg) -> Roofline:
    cost = compiled.cost_analysis()
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    xf, xb = time_scan_correction(cfg, shape, chips)
    flops += xf
    nbytes += xb
    stats = parse_collectives(compiled.as_text())
    mem = compiled.memory_analysis()
    peak = int(getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes,
        collective_bytes=float(stats.total_bytes),
        model_flops=model_flops_for(cfg, shape),
        peak_memory_per_device=peak,
        collectives=dict(stats.bytes_by_op))
