"""Async KV loading with double buffering (paper §III-C / §IV "Overlapping").

The paper uses two processes + a shared queue; device dispatch in JAX is
already asynchronous, so a thread pool gives the same overlap: while the device
decodes batch i, worker threads read batch i+1's artifacts from flash into host
memory (the "CPU bounce buffer") and deserialize them. ``PrefetchPipeline``
exposes exactly the two-stage pipeline of Fig. 4.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
from dataclasses import dataclass
from typing import (Callable, Dict, Iterable, Iterator, List, Optional,
                    Sequence, Tuple)


@dataclass
class LoaderStats:
    """Flash-link accounting in *encoded* bytes: payloads cross this layer
    exactly as they sit on flash (the codec's wire form, DESIGN.md §11), so
    these counters are the PCIe/flash traffic — never the widened size."""
    reads: int = 0
    bytes_loaded: int = 0


class AsyncKvLoader:
    """Thread-pool flash reader with in-flight coalescing: concurrent loads
    of one ``chunk_id`` — whether from one ``load_many`` batch or from
    independent requests — share a single future and a single flash read.
    The registry only tracks *in-flight* reads (a done callback drops the
    entry), so it never grows into a payload cache; persistent reuse is the
    paged pool's job."""

    def __init__(self, reader, n_workers: int = 4, tracer=None):
        from repro.obs import NULL_TRACER
        self.reader = reader
        self.pool = cf.ThreadPoolExecutor(max_workers=n_workers,
                                          thread_name_prefix="kvload")
        self.stats = LoaderStats()
        # late-bindable: a scheduler may attach its tracer after construction;
        # each read closure looks the attribute up at call time
        self.tracer = tracer or NULL_TRACER
        self._inflight: Dict[str, "cf.Future[bytes]"] = {}
        self._inflight_lock = threading.Lock()

    def load(self, chunk_id: str) -> "cf.Future[bytes]":
        return self._load(chunk_id)[0]

    @staticmethod
    def _outcome(f: cf.Future) -> Optional[BaseException]:
        """The future's failure as a value, cancellation included. A done
        callback must never call ``f.exception()`` bare: on a cancelled
        future it RAISES CancelledError — a BaseException since py3.8 —
        which escapes ``Future._invoke_callbacks``'s ``except Exception``
        and silently aborts every later callback on the same future
        (gather futures then hang forever)."""
        if f.cancelled():
            return cf.CancelledError()
        return f.exception()

    def _load(self, chunk_id: str) -> "Tuple[cf.Future[bytes], bool]":
        """Returns (future, initiated): ``initiated`` is False when the call
        coalesced onto a read another caller already has in flight — the
        flash bytes belong to the initiator, not this caller."""
        with self._inflight_lock:
            fut = self._inflight.get(chunk_id)
            if fut is not None:
                return fut, False           # coalesce onto the pending read
            if self.tracer.enabled:
                def _read(cid: str = chunk_id) -> bytes:
                    # the span runs on the worker thread — in a Chrome trace
                    # the flash reads show up on their own lanes, visibly
                    # overlapping the scheduler thread's decode_step spans
                    with self.tracer.span("flash_read", chunk=cid):
                        return self.reader.get(cid)
                fut = self.pool.submit(_read)
            else:
                # untraced: submit the bound read itself, no wrapper frame
                fut = self.pool.submit(self.reader.get, chunk_id)
            self._inflight[chunk_id] = fut

        def _forget(f: cf.Future) -> None:
            with self._inflight_lock:
                if self._inflight.get(chunk_id) is f:
                    del self._inflight[chunk_id]
                if self._outcome(f) is None:
                    # one initiated read = one flash transfer of the
                    # encoded payload (coalesced callers cost nothing)
                    self.stats.reads += 1
                    self.stats.bytes_loaded += len(f.result())

        fut.add_done_callback(_forget)
        return fut, True

    def load_many(self, chunk_ids: Sequence[str]) -> "cf.Future[List[bytes]]":
        """Fan out per-chunk loads; the returned future completes when all do.

        The gather is driven by done-callbacks on the per-chunk futures — it
        never occupies a pool worker. (Submitting a blocking gather closure to
        the *same* pool as the loads deadlocks once gathers hold every worker
        while the loads they wait on sit in the queue behind them.)

        The returned future carries ``initiated_flags`` (one bool per
        chunk_id): True where THIS call started the flash read, False where
        it coalesced onto an in-flight one — callers attribute flash bytes
        to initiators only.
        """
        loads = [self._load(c) for c in chunk_ids]
        futures = [f for f, _ in loads]
        out: "cf.Future[List[bytes]]" = cf.Future()
        out.initiated_flags = [i for _, i in loads]
        out.set_running_or_notify_cancel()
        if not futures:
            out.set_result([])
            return out
        pending = len(futures)
        lock = threading.Lock()

        def on_done(_f: cf.Future) -> None:
            nonlocal pending
            with lock:
                pending -= 1
                if pending:
                    return
            results = []
            for f in futures:
                exc = self._outcome(f)    # cancellation as a value, not a
                if exc is not None:       # callback-aborting raise
                    out.set_exception(exc)
                    return
                results.append(f.result())
            out.set_result(results)

        for f in futures:
            f.add_done_callback(on_done)
        return out

    def shutdown(self, wait: bool = True, cancel: bool = False):
        """Stop the loader. ``cancel=True`` additionally cancels queued
        (not-yet-running) reads: their futures — and any ``load_many``
        gather waiting on them — resolve with CancelledError instead of
        draining, and the per-future done callbacks still run, so the
        in-flight dedup registry empties either way."""
        self.pool.shutdown(wait=wait, cancel_futures=cancel)


class PrefetchPipeline:
    """Iterate work items; each item's payload loads while the previous item is
    being consumed (decoded). ``load_fn`` runs in a worker thread.

    Consumed futures are dropped as soon as their payload is handed out, so
    live payload bytes stay bounded by the pipeline depth instead of growing
    with the run length; early exit cancels whatever is still queued. The
    bound is exact: at most ``depth`` payloads are in flight (loading, or
    loaded but not yet handed to the consumer) at any moment — both fill
    loops share the ``len(inflight) < depth`` guard, where an off-by-one
    (``<=``) used to hold depth+1 payloads live.
    """

    def __init__(self, items: Iterable, load_fn: Callable, depth: int = 1,
                 n_workers: int = 2):
        self._items = list(items)
        self._load_fn = load_fn
        self._depth = max(1, depth)
        self._pool = cf.ThreadPoolExecutor(max_workers=n_workers,
                                           thread_name_prefix="prefetch")

    def __iter__(self) -> Iterator:
        inflight: Dict[int, cf.Future] = {}
        idx = 0
        try:
            while idx < len(self._items) and len(inflight) < self._depth:
                inflight[idx] = self._pool.submit(self._load_fn,
                                                  self._items[idx])
                idx += 1
            pos = 0
            while pos < len(self._items):
                item = self._items[pos]
                payload = inflight.pop(pos).result()
                # top up the pipeline before yielding (overlap with
                # consumption), under the same <= depth in-flight bound
                while idx < len(self._items) and len(inflight) < self._depth:
                    inflight[idx] = self._pool.submit(self._load_fn,
                                                      self._items[idx])
                    idx += 1
                yield item, payload
                del payload          # release before blocking on the next load
                pos += 1
        finally:
            for f in inflight.values():
                f.cancel()
            self._pool.shutdown(wait=False, cancel_futures=True)
