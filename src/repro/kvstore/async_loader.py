"""Async KV loading with double buffering (paper §III-C / §IV "Overlapping").

The paper uses two processes + a shared queue; device dispatch in JAX is
already asynchronous, so a thread pool gives the same overlap: while the device
decodes batch i, worker threads read batch i+1's artifacts from flash into host
memory (the "CPU bounce buffer") and deserialize them. ``PrefetchPipeline``
exposes exactly the two-stage pipeline of Fig. 4.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
from typing import Callable, Iterable, Iterator, List, Optional, Sequence


class AsyncKvLoader:
    def __init__(self, reader, n_workers: int = 4):
        self.reader = reader
        self.pool = cf.ThreadPoolExecutor(max_workers=n_workers,
                                          thread_name_prefix="kvload")

    def load(self, chunk_id: str) -> "cf.Future[bytes]":
        return self.pool.submit(self.reader.get, chunk_id)

    def load_many(self, chunk_ids: Sequence[str]) -> "cf.Future[List[bytes]]":
        futures = [self.load(c) for c in chunk_ids]

        def gather():
            return [f.result() for f in futures]

        return self.pool.submit(gather)

    def shutdown(self):
        self.pool.shutdown(wait=True)


class PrefetchPipeline:
    """Iterate work items; each item's payload loads while the previous item is
    being consumed (decoded). ``load_fn`` runs in a worker thread."""

    def __init__(self, items: Iterable, load_fn: Callable, depth: int = 1,
                 n_workers: int = 2):
        self._items = list(items)
        self._load_fn = load_fn
        self._depth = max(1, depth)
        self._pool = cf.ThreadPoolExecutor(max_workers=n_workers,
                                           thread_name_prefix="prefetch")

    def __iter__(self) -> Iterator:
        inflight: List[cf.Future] = []
        idx = 0
        try:
            while idx < len(self._items) and len(inflight) <= self._depth:
                inflight.append(self._pool.submit(self._load_fn, self._items[idx]))
                idx += 1
            pos = 0
            while pos < len(self._items):
                item = self._items[pos]
                payload = inflight[pos].result()
                # top up the pipeline before yielding (overlap with consumption)
                while idx < len(self._items) and idx - pos <= self._depth:
                    inflight.append(self._pool.submit(self._load_fn,
                                                      self._items[idx]))
                    idx += 1
                yield item, payload
                pos += 1
        finally:
            self._pool.shutdown(wait=False)
