"""Async KV loading with double buffering (paper §III-C / §IV "Overlapping").

The paper uses two processes + a shared queue; device dispatch in JAX is
already asynchronous, so a thread pool gives the same overlap: while the device
decodes batch i, worker threads read batch i+1's artifacts from flash into host
memory (the "CPU bounce buffer") and deserialize them. ``PrefetchPipeline``
exposes exactly the two-stage pipeline of Fig. 4.
"""

from __future__ import annotations

import concurrent.futures as cf
import threading
from dataclasses import dataclass
from typing import (Any, Callable, Dict, Iterable, Iterator, List, Optional,
                    Protocol, Sequence, Tuple)


class SupportsGet(Protocol):
    """The flash-reader surface the loader needs: blocking byte reads
    keyed by chunk id (FlashKVStore, SimulatedReader, TieredStore...)."""

    def get(self, chunk_id: str) -> bytes: ...


#: one completed stream block: (t0, t1, EncodedKV payload, encoded bytes)
Block = Tuple[int, int, Any, int]


@dataclass
class LoaderStats:
    """Flash-link accounting in *encoded* bytes: payloads cross this layer
    exactly as they sit on flash (the codec's wire form, DESIGN.md §11), so
    these counters are the PCIe/flash traffic — never the widened size."""
    reads: int = 0
    bytes_loaded: int = 0


class ChunkStream:
    """Handle for one chunk's block-granular flash read (DESIGN.md §16).

    A single loader worker walks the chunk's token blocks in file order (the
    sequential-NVMe model) and appends each completed block here; the
    scheduler polls ``drain_from`` between decode steps and advances the
    row's resident frontier. Blocks are only ever appended, so multiple
    consumers can hold independent cursors; errors surface as a value
    (``error``) rather than a raise on the worker thread.
    """

    def __init__(self, chunk_id: str) -> None:
        self.chunk_id = chunk_id
        self._lock = threading.Lock()
        self._blocks: List[Block] = []         # appended per completed block
        self.n_tokens: Optional[int] = None    # set once the header is read
        self.total_bytes = 0                   # encoded bytes read so far
        self.header_bytes = 0
        self.error: Optional[BaseException] = None
        self._done = False

    def drain_from(self, cursor: int) -> Tuple[List[Block], int]:
        """Blocks completed since ``cursor``; returns (new_blocks, cursor')."""
        with self._lock:
            return self._blocks[cursor:], len(self._blocks)

    @property
    def done(self) -> bool:
        with self._lock:
            return self._done

    def raise_if_failed(self) -> None:
        if self.error is not None:
            raise self.error

    # -- producer side (loader worker thread) ------------------------------
    def _set_header(self, n_tokens: int, header_bytes: int) -> None:
        with self._lock:
            self.n_tokens = n_tokens
            self.header_bytes = header_bytes

    def _push(self, t0: int, t1: int, enc: Any, nbytes: int) -> None:
        with self._lock:
            self._blocks.append((t0, t1, enc, nbytes))
            self.total_bytes += nbytes

    def _finish(self, error: Optional[BaseException] = None) -> None:
        with self._lock:
            self.error = error
            self._done = True


class AsyncKvLoader:
    """Thread-pool flash reader with in-flight coalescing: concurrent loads
    of one ``chunk_id`` — whether from one ``load_many`` batch or from
    independent requests — share a single future and a single flash read.
    The registry only tracks *in-flight* reads (a done callback drops the
    entry), so it never grows into a payload cache; persistent reuse is the
    paged pool's job."""

    def __init__(self, reader: SupportsGet, n_workers: int = 4,
                 tracer: Optional[Any] = None) -> None:
        from repro.obs import NULL_TRACER
        self.reader = reader
        self.pool = cf.ThreadPoolExecutor(max_workers=n_workers,
                                          thread_name_prefix="kvload")
        self.stats = LoaderStats()
        # late-bindable: a scheduler may attach its tracer after construction;
        # each read closure looks the attribute up at call time
        self.tracer = tracer or NULL_TRACER
        self._inflight: Dict[str, "cf.Future[bytes]"] = {}
        self._inflight_lock = threading.Lock()

    def load(self, chunk_id: str) -> "cf.Future[bytes]":
        return self._load(chunk_id)[0]

    @staticmethod
    def _outcome(f: cf.Future) -> Optional[BaseException]:
        """The future's failure as a value, cancellation included. A done
        callback must never call ``f.exception()`` bare: on a cancelled
        future it RAISES CancelledError — a BaseException since py3.8 —
        which escapes ``Future._invoke_callbacks``'s ``except Exception``
        and silently aborts every later callback on the same future
        (gather futures then hang forever)."""
        if f.cancelled():
            return cf.CancelledError()
        return f.exception()

    def _load(self, chunk_id: str) -> "Tuple[cf.Future[bytes], bool]":
        """Returns (future, initiated): ``initiated`` is False when the call
        coalesced onto a read another caller already has in flight — the
        flash bytes belong to the initiator, not this caller."""
        with self._inflight_lock:
            fut = self._inflight.get(chunk_id)
            if fut is not None:
                return fut, False           # coalesce onto the pending read
            if self.tracer.enabled:
                def _read(cid: str = chunk_id) -> bytes:
                    # the span runs on the worker thread — in a Chrome trace
                    # the flash reads show up on their own lanes, visibly
                    # overlapping the scheduler thread's decode_step spans
                    with self.tracer.span("flash_read", chunk=cid):
                        return self.reader.get(cid)
                fut = self.pool.submit(_read)
            else:
                # untraced: submit the bound read itself, no wrapper frame
                fut = self.pool.submit(self.reader.get, chunk_id)
            self._inflight[chunk_id] = fut

        def _forget(f: cf.Future) -> None:
            with self._inflight_lock:
                if self._inflight.get(chunk_id) is f:
                    del self._inflight[chunk_id]
                if self._outcome(f) is None:
                    # one initiated read = one flash transfer of the
                    # encoded payload (coalesced callers cost nothing)
                    self.stats.reads += 1
                    self.stats.bytes_loaded += len(f.result())

        fut.add_done_callback(_forget)
        return fut, True

    def load_stream(self, chunk_id: str, block_tokens: int = 64
                    ) -> ChunkStream:
        """Start a block-granular read of one chunk; returns the stream
        handle immediately. One worker reads the header, then the token
        blocks in order, pushing each as an ``EncodedKV`` — the consumer
        (the streaming scheduler) polls ``drain_from`` between decode steps.

        Unlike ``load``/``load_many`` there is no in-flight coalescing here:
        the streaming scheduler's ``wanted`` registry already guarantees one
        stream per cold chunk per run, and per-consumer cursors make a
        shared handle safe if a caller does share one.
        """
        from repro.kvstore.streaming import (ArtifactIndex,
                                             block_payload_bytes,
                                             read_block_encoded)
        stream = ChunkStream(chunk_id)

        def _run() -> None:
            try:
                # one span covers the whole walk: the link is busy end to
                # end, and in a Chrome trace the lane visibly overlaps the
                # scheduler thread's decode_step spans
                with self.tracer.span("flash_read", chunk=chunk_id,
                                      streamed=True):
                    idx = ArtifactIndex.open(self.reader, chunk_id)
                    stream._set_header(idx.n_tokens, idx.header_bytes)
                    for t0 in range(0, idx.n_tokens, block_tokens):
                        t1 = min(t0 + block_tokens, idx.n_tokens)
                        enc = read_block_encoded(self.reader, idx, t0, t1)
                        stream._push(t0, t1, enc,
                                     block_payload_bytes(idx, t0, t1))
            except BaseException as e:          # surfaced via the handle
                stream._finish(e)
                return
            stream._finish()
            self.stats.reads += 1
            self.stats.bytes_loaded += stream.total_bytes

        self.pool.submit(_run)
        return stream

    def load_many(self, chunk_ids: Sequence[str]) -> "cf.Future[List[bytes]]":
        """Fan out per-chunk loads; the returned future completes when all do.

        The gather is driven by done-callbacks on the per-chunk futures — it
        never occupies a pool worker. (Submitting a blocking gather closure to
        the *same* pool as the loads deadlocks once gathers hold every worker
        while the loads they wait on sit in the queue behind them.)

        The returned future carries ``initiated_flags`` (one bool per
        chunk_id): True where THIS call started the flash read, False where
        it coalesced onto an in-flight one — callers attribute flash bytes
        to initiators only.

        Duplicates *within one call* coalesce deterministically via a local
        map — the global registry alone can't guarantee it, since a fast
        read may complete (and drop its registry entry) between two
        ``_load`` calls of the same batch.
        """
        batch: Dict[str, Tuple[cf.Future[bytes], bool]] = {}
        loads: List[Tuple[cf.Future[bytes], bool]] = []
        for c in chunk_ids:
            if c in batch:
                loads.append((batch[c][0], False))
            else:
                batch[c] = self._load(c)
                loads.append(batch[c])
        futures = [f for f, _ in loads]
        out: cf.Future[List[bytes]] = cf.Future()
        out.initiated_flags = [i for _, i in loads]  # type: ignore[attr-defined]
        out.set_running_or_notify_cancel()
        if not futures:
            out.set_result([])
            return out
        pending = len(futures)
        lock = threading.Lock()

        def on_done(_f: cf.Future) -> None:
            nonlocal pending
            with lock:
                pending -= 1
                if pending:
                    return
            results: List[bytes] = []
            for f in futures:
                exc = self._outcome(f)    # cancellation as a value, not a
                if exc is not None:       # callback-aborting raise
                    out.set_exception(exc)
                    return
                results.append(f.result())
            out.set_result(results)

        for f in futures:
            f.add_done_callback(on_done)
        return out

    def shutdown(self, wait: bool = True, cancel: bool = False) -> None:
        """Stop the loader. ``cancel=True`` additionally cancels queued
        (not-yet-running) reads: their futures — and any ``load_many``
        gather waiting on them — resolve with CancelledError instead of
        draining, and the per-future done callbacks still run, so the
        in-flight dedup registry empties either way."""
        self.pool.shutdown(wait=wait, cancel_futures=cancel)


class PrefetchPipeline:
    """Iterate work items; each item's payload loads while the previous item is
    being consumed (decoded). ``load_fn`` runs in a worker thread.

    Consumed futures are dropped as soon as their payload is handed out, so
    live payload bytes stay bounded by the pipeline depth instead of growing
    with the run length; early exit cancels whatever is still queued. The
    bound is exact: at most ``depth`` payloads are in flight (loading, or
    loaded but not yet handed to the consumer) at any moment — both fill
    loops share the ``len(inflight) < depth`` guard, where an off-by-one
    (``<=``) used to hold depth+1 payloads live.
    """

    def __init__(self, items: Iterable[Any], load_fn: Callable[[Any], Any],
                 depth: int = 1, n_workers: int = 2) -> None:
        self._items = list(items)
        self._load_fn = load_fn
        self._depth = max(1, depth)
        self._pool = cf.ThreadPoolExecutor(max_workers=n_workers,
                                           thread_name_prefix="prefetch")

    def __iter__(self) -> Iterator[Tuple[Any, Any]]:
        inflight: Dict[int, cf.Future[Any]] = {}
        idx = 0
        try:
            while idx < len(self._items) and len(inflight) < self._depth:
                inflight[idx] = self._pool.submit(self._load_fn,
                                                  self._items[idx])
                idx += 1
            pos = 0
            while pos < len(self._items):
                item = self._items[pos]
                payload = inflight.pop(pos).result()
                # top up the pipeline before yielding (overlap with
                # consumption), under the same <= depth in-flight bound
                while idx < len(self._items) and len(inflight) < self._depth:
                    inflight[idx] = self._pool.submit(self._load_fn,
                                                      self._items[idx])
                    idx += 1
                yield item, payload
                del payload          # release before blocking on the next load
                pos += 1
        finally:
            for f in inflight.values():
                f.cancel()
            self._pool.shutdown(wait=False, cancel_futures=True)
