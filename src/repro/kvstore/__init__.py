from repro.kvstore.async_loader import (AsyncKvLoader, LoaderStats,
                                        PrefetchPipeline)
from repro.kvstore.cache_tier import LruBytesCache, TieredStore
from repro.kvstore.serialization import (deserialize, payload_bytes,
                                         read_meta, serialize)
from repro.kvstore.simulated import PROFILES, SimulatedReader
from repro.kvstore.store import FlashKVStore

__all__ = ["AsyncKvLoader", "LoaderStats", "PrefetchPipeline", "LruBytesCache",
           "TieredStore", "deserialize", "payload_bytes", "read_meta",
           "serialize", "PROFILES", "SimulatedReader", "FlashKVStore"]
