from repro.kvstore.async_loader import (AsyncKvLoader, ChunkStream,
                                        LoaderStats, PrefetchPipeline)
from repro.kvstore.cache_tier import LruBytesCache, TieredStore
from repro.kvstore.serialization import (deserialize, payload_bytes,
                                         read_meta, serialize)
from repro.kvstore.simulated import PROFILES, SimulatedReader
from repro.kvstore.store import FlashKVStore
from repro.kvstore.streaming import (ArtifactIndex, block_payload_bytes,
                                     read_block_encoded)

__all__ = ["AsyncKvLoader", "ChunkStream", "LoaderStats", "PrefetchPipeline",
           "LruBytesCache", "TieredStore", "deserialize", "payload_bytes",
           "read_meta", "serialize", "PROFILES", "SimulatedReader",
           "FlashKVStore", "ArtifactIndex", "block_payload_bytes",
           "read_block_encoded"]
