"""Block-granular reads of MKV1 artifacts (streaming admission, DESIGN.md §16).

An artifact's payload layout is deterministic (sorted tensor names, raw
bytes), so a token block ``[t0, t1)`` of every ``(L, S, ...)`` KV tensor maps
to a handful of computable byte ranges: ``L`` strided segments per tensor,
each ``(t1 - t0) * bytes_per_token`` long. ``ArtifactIndex`` builds that map
from the header alone (two small range reads — never the payload), and
``read_block_encoded`` pulls one token block off flash as an ``EncodedKV``
in the artifact's own codec, ready for ``PagedKvPool.extend_stream``.

This is the read primitive under ``AsyncKvLoader.load_stream``: the loader
walks a chunk's blocks in order (the sequential-NVMe model) and the
scheduler advances each row's resident frontier as they land, instead of
waiting on one whole-payload future per chunk.

Readers without ``get_range`` (anything wrapping only ``.get``) degrade to
one whole-payload read cached on the index; block assembly then slices the
cached bytes, so the consumer-side protocol is identical either way.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.quantize import EncodedKV, codec_for_meta
from repro.kvstore.serialization import MAGIC, _parse_header, _restore


@dataclass(frozen=True)
class _TensorEntry:
    """One serialized tensor's placement inside the artifact file."""
    dtype: str                 # numpy dtype name ("bfloat16" allowed)
    shape: Tuple[int, ...]     # (L, S, ...) — axis 1 is the token axis
    offset: int                # absolute file offset of the first payload byte
    nbytes: int

    @property
    def token_stride(self) -> int:
        """Bytes of one token's slice within one layer's segment."""
        itemsize = (2 if self.dtype == "bfloat16"
                    else np.dtype(self.dtype).itemsize)
        per = itemsize
        for d in self.shape[2:]:
            per *= d
        return per


class ArtifactIndex:
    """Byte-range map of one artifact: header meta + per-tensor offsets.

    Built from two range reads (8-byte prefix, then the msgpack header);
    ``n_tokens`` comes from the meta (falling back to the token axis of the
    first tensor for pre-meta artifacts). When the reader only supports
    whole-payload ``get``, the full bytes are cached on the index and block
    reads slice them — same interface, no range support required.
    """

    def __init__(self, chunk_id: str, header: Dict, payload_offset: int,
                 whole: Optional[bytes] = None):
        self.chunk_id = chunk_id
        self.meta = header["meta"]
        self.tensors: Dict[str, _TensorEntry] = {}
        off = payload_offset
        for e in header["tensors"]:
            self.tensors[e["name"]] = _TensorEntry(
                e["dtype"], tuple(e["shape"]), off, e["nbytes"])
            off += e["nbytes"]
        self.total_bytes = off
        self.header_bytes = payload_offset
        self._whole = whole
        self.n_tokens = int(self.meta.get("n_tokens")
                            or next(iter(self.tensors.values())).shape[1])

    @classmethod
    def open(cls, reader, chunk_id: str) -> "ArtifactIndex":
        get_range = getattr(reader, "get_range", None)
        if get_range is None:
            data = reader.get(chunk_id)
            header, off = _parse_header(data)
            return cls(chunk_id, header, off, whole=data)
        prefix = get_range(chunk_id, 0, 8)
        if len(prefix) < 8 or prefix[:4] != MAGIC:
            raise ValueError(f"bad artifact header for {chunk_id!r}")
        hlen = struct.unpack("<I", prefix[4:8])[0]
        header_raw = get_range(chunk_id, 8, hlen)
        header, off = _parse_header(prefix + header_raw)
        return cls(chunk_id, header, off)

    def kv_names(self) -> Tuple[str, str]:
        """The artifact's logical KV tensor names (self- or cross-attention)."""
        for kn, vn in (("k", "v"), ("cross_k", "cross_v")):
            if kn in self.tensors or kn + ".q8" in self.tensors:
                return kn, vn
        raise ValueError(f"artifact {self.chunk_id!r} carries no KV tensors: "
                         f"{sorted(self.tensors)}")

    def block_ranges(self, name: str, t0: int, t1: int
                     ) -> List[Tuple[int, int]]:
        """File (offset, length) segments holding tokens [t0, t1) of one
        tensor — one strided segment per layer."""
        e = self.tensors[name]
        n_layers, s_axis = e.shape[0], e.shape[1]
        if not 0 <= t0 < t1 <= s_axis:
            raise ValueError(f"block [{t0},{t1}) outside token axis "
                             f"{s_axis} of {name!r}")
        row = e.token_stride
        return [(e.offset + layer * s_axis * row + t0 * row, (t1 - t0) * row)
                for layer in range(n_layers)]

    def read_block_tensor(self, reader, name: str, t0: int, t1: int
                          ) -> np.ndarray:
        """Tokens [t0, t1) of one tensor as (L, t1-t0, *tail).

        Adjacent per-layer segments coalesce into one ``get_range`` call:
        a full-token-axis block's L segments are back-to-back in the file,
        so the common block-size == chunk-tokens case costs ONE read per
        tensor instead of one per layer (fewer syscalls on real storage,
        no per-call tax on a simulated link). Byte order is unchanged —
        only runs that were already contiguous merge."""
        e = self.tensors[name]
        merged: List[List[int]] = []
        for off, length in self.block_ranges(name, t0, t1):
            if merged and merged[-1][0] + merged[-1][1] == off:
                merged[-1][1] += length
            else:
                merged.append([off, length])
        if self._whole is not None:
            parts = [self._whole[off:off + length] for off, length in merged]
        else:
            parts = [reader.get_range(self.chunk_id, off, length)
                     for off, length in merged]
        buf = np.frombuffer(b"".join(parts), dtype=np.uint8)
        return _restore(buf, e.dtype, (e.shape[0], t1 - t0) + e.shape[2:])


def block_payload_bytes(index: ArtifactIndex, t0: int, t1: int) -> int:
    """Encoded flash bytes of one token block (all KV tensors + scales) —
    the per-block flash-link accounting unit."""
    kn, vn = index.kv_names()
    names = [n for n in index.tensors
             if n.split(".")[0] in (kn, vn)]
    return sum(length for n in names
               for _, length in index.block_ranges(n, t0, t1))


def read_block_encoded(reader, index: ArtifactIndex, t0: int, t1: int
                       ) -> EncodedKV:
    """One token block [t0, t1) as an ``EncodedKV`` in the artifact's codec —
    the streaming counterpart of ``core.materialize.load_artifact_encoded``."""
    codec = codec_for_meta(index.meta)
    kn, vn = index.kv_names()
    if codec.scale_dtype is not None:
        return EncodedKV(
            codec,
            index.read_block_tensor(reader, kn + ".q8", t0, t1),
            index.read_block_tensor(reader, vn + ".q8", t0, t1),
            index.read_block_tensor(reader, kn + ".scale", t0, t1),
            index.read_block_tensor(reader, vn + ".scale", t0, t1),
            t1 - t0)
    return EncodedKV(codec,
                     index.read_block_tensor(reader, kn, t0, t1),
                     index.read_block_tensor(reader, vn, t0, t1),
                     None, None, t1 - t0)
