"""DRAM LRU cache tier in front of the flash store (paper §III-E "hierarchical
storage"; Table III's DRAM configuration is this tier with capacity=inf).

Capacity is accounted in *encoded* bytes: payloads are cached exactly as
serialized (the artifact codec's wire form, DESIGN.md §11), never widened —
so one DRAM budget holds ~2x the chunks under the int8 codec, the same
residency doubling the paged HBM pool gets."""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Optional


class LruBytesCache:
    def __init__(self, capacity_bytes: int):
        self.capacity = capacity_bytes
        self._data: "OrderedDict[str, bytes]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get(self, key: str) -> Optional[bytes]:
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self.hits += 1
                return self._data[key]
            self.misses += 1
            return None

    def put(self, key: str, value: bytes) -> None:
        with self._lock:
            if len(value) > self.capacity:
                # reject before touching the map: evicting the key's existing
                # entry first and then dropping the insert silently deletes
                # cached data (values are immutable per chunk_id, so keeping
                # the resident entry is always safe)
                return
            if key in self._data:
                self._bytes -= len(self._data.pop(key))
            self._data[key] = value
            self._bytes += len(value)
            while self._bytes > self.capacity and self._data:
                _, old = self._data.popitem(last=False)
                self._bytes -= len(old)

    def contains(self, key: str) -> bool:
        """Membership probe that perturbs neither recency nor hit/miss
        stats — scheduler readiness checks must not look like traffic."""
        with self._lock:
            return key in self._data

    def invalidate(self, key: str) -> None:
        with self._lock:
            if key in self._data:
                self._bytes -= len(self._data.pop(key))

    @property
    def size_bytes(self) -> int:
        return self._bytes

    @property
    def n_entries(self) -> int:
        """Resident chunk count — the codec-sensitive capacity metric (a
        fixed byte budget holds ~2x the int8 chunks vs bf16)."""
        return len(self._data)


class TieredStore:
    """get-through DRAM tier over a FlashKVStore."""

    def __init__(self, flash, dram_capacity_bytes: int = 0):
        self.flash = flash
        self.dram = LruBytesCache(dram_capacity_bytes) if dram_capacity_bytes else None

    def put(self, chunk_id: str, payload: bytes) -> None:
        self.flash.put(chunk_id, payload)
        if self.dram is not None:
            self.dram.put(chunk_id, payload)

    def get(self, chunk_id: str) -> bytes:
        if self.dram is not None:
            hit = self.dram.get(chunk_id)
            if hit is not None:
                return hit
        data = self.flash.get(chunk_id)
        if self.dram is not None:
            self.dram.put(chunk_id, data)
        return data

    def get_range(self, chunk_id: str, offset: int, length: int) -> bytes:
        """Range read through the tier: a DRAM-resident payload serves the
        slice with zero flash bytes; a miss delegates to the flash store's
        range read WITHOUT promoting (a partial read must not cache a full
        payload it never transferred)."""
        if self.dram is not None:
            hit = self.dram.get(chunk_id)
            if hit is not None:
                return hit[offset:offset + length]
        return self.flash.get_range(chunk_id, offset, length)

    def exists(self, chunk_id: str) -> bool:
        return self.flash.exists(chunk_id)

    def delete(self, chunk_id: str) -> bool:
        if self.dram is not None:
            self.dram.invalidate(chunk_id)
        return self.flash.delete(chunk_id)
