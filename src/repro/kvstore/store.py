"""FlashKVStore: the materialized-KV store on flash (paper §IV).

Each chunk's KV artifact is one file named by chunk_id (exactly the paper's
layout), written atomically (tmp + rename). ``delete`` keeps the store
consistent with vector-DB deletions. Stats feed the TCO/economics benchmarks.
"""

from __future__ import annotations

import os
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional


@dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    deletes: int = 0
    bytes_written: int = 0
    bytes_read: int = 0


class FlashKVStore:
    def __init__(self, root: str):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.stats = StoreStats()
        self._lock = threading.Lock()

    def _path(self, chunk_id: str) -> Path:
        if "/" in chunk_id or chunk_id.startswith("."):
            raise ValueError(f"invalid chunk_id {chunk_id!r}")
        return self.root / f"{chunk_id}.kv"

    def put(self, chunk_id: str, payload: bytes) -> None:
        path = self._path(chunk_id)
        tmp = path.with_suffix(".tmp")
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, path)
        with self._lock:
            self.stats.puts += 1
            self.stats.bytes_written += len(payload)

    def get(self, chunk_id: str) -> bytes:
        with open(self._path(chunk_id), "rb") as f:
            data = f.read()
        with self._lock:
            self.stats.gets += 1
            self.stats.bytes_read += len(data)
        return data

    def exists(self, chunk_id: str) -> bool:
        return self._path(chunk_id).exists()

    def delete(self, chunk_id: str) -> bool:
        path = self._path(chunk_id)
        try:
            path.unlink()
        except FileNotFoundError:
            return False
        with self._lock:
            self.stats.deletes += 1
        return True

    def size_bytes(self, chunk_id: str) -> int:
        return self._path(chunk_id).stat().st_size

    def list_ids(self) -> List[str]:
        return sorted(p.stem for p in self.root.glob("*.kv"))

    def total_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.root.glob("*.kv"))
