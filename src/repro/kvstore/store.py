"""FlashKVStore: the materialized-KV store on flash (paper §IV).

Each chunk's KV artifact is one file named by chunk_id (exactly the paper's
layout), written atomically (tmp + rename). ``delete`` keeps the store
consistent with vector-DB deletions. Stats feed the TCO/economics benchmarks.
"""

from __future__ import annotations

import os
import struct
import threading
import uuid
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List

from repro.kvstore.serialization import read_meta


@dataclass
class StoreStats:
    puts: int = 0
    gets: int = 0
    deletes: int = 0
    bytes_written: int = 0
    bytes_read: int = 0


class FlashKVStore:
    def __init__(self, root: str):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.stats = StoreStats()
        self._lock = threading.Lock()

    def _path(self, chunk_id: str) -> Path:
        if "/" in chunk_id or chunk_id.startswith("."):
            raise ValueError(f"invalid chunk_id {chunk_id!r}")
        return self.root / f"{chunk_id}.kv"

    def put(self, chunk_id: str, payload: bytes) -> None:
        """Durable atomic write: unique tmp name (concurrent puts of one
        chunk_id must not race on a shared ``<id>.tmp`` — whichever rename
        lands last wins, and neither crashes), fsync before the rename so a
        power cut can't leave a renamed-but-empty artifact (this repo's whole
        premise is that flash *retains* the materialization)."""
        path = self._path(chunk_id)
        tmp = path.with_name(f".{path.name}.{uuid.uuid4().hex}.tmp")
        try:
            with open(tmp, "wb") as f:
                f.write(payload)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            # POSIX durable rename: the directory entry itself must reach
            # stable storage, or a power cut can forget the replace
            dir_fd = os.open(self.root, os.O_RDONLY)
            try:
                os.fsync(dir_fd)
            finally:
                os.close(dir_fd)
        except BaseException:
            tmp.unlink(missing_ok=True)
            raise
        with self._lock:
            self.stats.puts += 1
            self.stats.bytes_written += len(payload)

    def get(self, chunk_id: str) -> bytes:
        with open(self._path(chunk_id), "rb") as f:
            data = f.read()
        with self._lock:
            self.stats.gets += 1
            self.stats.bytes_read += len(data)
        return data

    def get_range(self, chunk_id: str, offset: int, length: int) -> bytes:
        """Read ``length`` bytes at ``offset`` of the artifact — the
        block-granular read primitive streaming admission is built on
        (``kvstore.streaming`` plans token-block byte ranges against the
        header and pulls them through here while decode runs)."""
        with open(self._path(chunk_id), "rb") as f:
            f.seek(offset)
            data = f.read(length)
        with self._lock:
            self.stats.gets += 1
            self.stats.bytes_read += len(data)
        return data

    def get_meta(self, chunk_id: str) -> Dict[str, Any]:
        """Artifact meta (n_tokens / codec / family) from the header alone:
        reads the 8-byte prefix + msgpack header, never the payload bytes —
        the cheap inspection path for schedulers sizing admits or pools."""
        with open(self._path(chunk_id), "rb") as f:
            prefix = f.read(8)
            if len(prefix) < 8:
                raise ValueError(f"truncated artifact {chunk_id!r}")
            hlen = struct.unpack("<I", prefix[4:8])[0]
            header = f.read(hlen)
        with self._lock:
            self.stats.bytes_read += 8 + len(header)
        return read_meta(prefix + header)

    def exists(self, chunk_id: str) -> bool:
        return self._path(chunk_id).exists()

    def delete(self, chunk_id: str) -> bool:
        path = self._path(chunk_id)
        try:
            path.unlink()
        except FileNotFoundError:
            return False
        with self._lock:
            self.stats.deletes += 1
        return True

    def size_bytes(self, chunk_id: str) -> int:
        return self._path(chunk_id).stat().st_size

    def list_ids(self) -> List[str]:
        return sorted(p.stem for p in self.root.glob("*.kv"))

    def total_bytes(self) -> int:
        return sum(p.stat().st_size for p in self.root.glob("*.kv"))
