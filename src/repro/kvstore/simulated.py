"""Bandwidth-profile simulation for Table III (storage-performance sensitivity).

The container's filesystem is far faster than its role in the experiment, so
reads are throttled to the modeled device's sequential bandwidth: after the real
read completes, sleep the remainder of ``bytes / bandwidth``. Timing-sensitive
benchmarks read through one of these profiles; correctness paths use the raw
store.
"""

from __future__ import annotations

import time
from dataclasses import dataclass

from repro.core.economics import (DRAM_TIER, PM9A3, RAID0_9100_PRO_X4,
                                  SAMSUNG_9100_PRO, SsdSpec)

PROFILES = {
    "9100pro": SAMSUNG_9100_PRO,
    "raid0_x4": RAID0_9100_PRO_X4,
    "pm9a3": PM9A3,
    "dram": DRAM_TIER,
}


@dataclass
class ReadRecord:
    n_bytes: int
    real_s: float
    simulated_s: float


class SimulatedReader:
    """Wraps any store with .get(); enforces the profile's read bandwidth."""

    def __init__(self, store, profile: str | SsdSpec = "9100pro"):
        self.store = store
        self.spec = PROFILES[profile] if isinstance(profile, str) else profile
        self.records: list[ReadRecord] = []

    def get(self, chunk_id: str) -> bytes:
        t0 = time.perf_counter()
        data = self.store.get(chunk_id)
        real = time.perf_counter() - t0
        target = len(data) / (self.spec.read_gbps * 1e9)
        if target > real:
            time.sleep(target - real)
        self.records.append(ReadRecord(len(data), real,
                                       max(real, target)))
        return data

    def exists(self, chunk_id: str) -> bool:
        return self.store.exists(chunk_id)

    @property
    def total_simulated_s(self) -> float:
        return sum(r.simulated_s for r in self.records)

    def energy_joules(self) -> float:
        return self.total_simulated_s * self.spec.active_power_w
