"""Bandwidth-profile simulation for Table III (storage-performance sensitivity).

The container's filesystem is far faster than its role in the experiment, so
reads are throttled to the modeled device's sequential bandwidth: after the real
read completes, sleep the remainder of ``bytes / bandwidth``. Timing-sensitive
benchmarks read through one of these profiles; correctness paths use the raw
store.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

from repro.core.economics import (DRAM_TIER, PM9A3, RAID0_9100_PRO_X4,
                                  SAMSUNG_9100_PRO, SsdSpec)

PROFILES = {
    "9100pro": SAMSUNG_9100_PRO,
    "raid0_x4": RAID0_9100_PRO_X4,
    "pm9a3": PM9A3,
    "dram": DRAM_TIER,
}


@dataclass
class ReadRecord:
    n_bytes: int
    real_s: float
    simulated_s: float


class SimulatedReader:
    """Wraps any store with .get(); enforces the profile's read bandwidth."""

    def __init__(self, store, profile: str | SsdSpec = "9100pro",
                 shared_link: bool = False):
        self.store = store
        self.spec = PROFILES[profile] if isinstance(profile, str) else profile
        self.records: list[ReadRecord] = []
        # shared_link=True models ONE flash link shared by every concurrent
        # reader thread: each read reserves its byte-time on the link and
        # sleeps to the end of its reservation, so N threads see bandwidth/N
        # each instead of N independent links. The per-call throttle (the
        # default) is only honest for sequential readers — equal-bandwidth
        # comparisons between serial and overlapped arms need the link.
        self.shared_link = shared_link
        self._link_lock = threading.Lock()
        self._link_busy_until = 0.0
        self._records_lock = threading.Lock()

    def _throttle(self, nbytes: int, real_s: float,
                  entry_s: float | None = None) -> None:
        target = nbytes / (self.spec.read_gbps * 1e9)
        if self.shared_link:
            with self._link_lock:
                # the reservation backdates to the CALL's entry time (when
                # the link was free then): the backing-store read models the
                # device's internal transfer, which a real link pipelines —
                # charging it on top of the byte-time would bill block-
                # granular readers (many small calls) a per-call tax that
                # sequential whole-blob readers never pay
                now = time.perf_counter()
                start = max(entry_s if entry_s is not None else now,
                            self._link_busy_until)
                end = start + target
                self._link_busy_until = end
            wait = end - time.perf_counter()
            if wait > 0:
                time.sleep(wait)
            simulated = max(real_s, target)
        else:
            if target > real_s:
                time.sleep(target - real_s)
            simulated = max(real_s, target)
        with self._records_lock:
            self.records.append(ReadRecord(nbytes, real_s, simulated))

    def get(self, chunk_id: str) -> bytes:
        t0 = time.perf_counter()
        data = self.store.get(chunk_id)
        self._throttle(len(data), time.perf_counter() - t0, entry_s=t0)
        return data

    def get_range(self, chunk_id: str, offset: int, length: int) -> bytes:
        t0 = time.perf_counter()
        data = self.store.get_range(chunk_id, offset, length)
        self._throttle(len(data), time.perf_counter() - t0, entry_s=t0)
        return data

    def exists(self, chunk_id: str) -> bool:
        return self.store.exists(chunk_id)

    @property
    def total_simulated_s(self) -> float:
        return sum(r.simulated_s for r in self.records)

    def energy_joules(self) -> float:
        return self.total_simulated_s * self.spec.active_power_w
