"""KV artifact serialization: msgpack header + raw tensor bytes.

Mirrors the paper's DeepNVMe usage: tensors are written as raw bytes (no
pickle), so reads are a single sequential scan straight into a reusable bounce
buffer. Header carries shapes/dtypes/meta; payload layout is deterministic
(sorted keys) so offsets are computable without parsing the payload.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Tuple

import msgpack
import numpy as np

MAGIC = b"MKV1"

_DTYPES = {"bfloat16": 2, "float16": 2, "float32": 4, "int8": 1, "int32": 4}


def _np_view(arr) -> np.ndarray:
    """View any array (incl. jax bfloat16) as raw-byte-compatible numpy."""
    a = np.asarray(arr)
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16)
    return a


def _restore(buf: np.ndarray, dtype_name: str, shape) -> np.ndarray:
    if dtype_name == "bfloat16":
        import ml_dtypes  # jax dependency, always present
        return buf.view(ml_dtypes.bfloat16).reshape(shape)
    return buf.view(np.dtype(dtype_name)).reshape(shape)


def serialize(tensors: Dict[str, Any], meta: Dict[str, Any] | None = None) -> bytes:
    """tensors: flat dict name -> array. Returns bytes."""
    names = sorted(tensors)
    entries, payloads = [], []
    for name in names:
        a = np.ascontiguousarray(_np_view(tensors[name]))
        raw_dtype = np.asarray(tensors[name]).dtype.name
        entries.append({"name": name, "dtype": raw_dtype,
                        "shape": list(np.asarray(tensors[name]).shape),
                        "nbytes": a.nbytes})
        payloads.append(a.tobytes())
    header = msgpack.packb({"tensors": entries, "meta": meta or {}})
    return MAGIC + struct.pack("<I", len(header)) + header + b"".join(payloads)


def deserialize(data: bytes) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    if data[:4] != MAGIC:
        raise ValueError("bad magic: not a MatKV artifact")
    hlen = struct.unpack("<I", data[4:8])[0]
    header = msgpack.unpackb(data[8:8 + hlen])
    out, off = {}, 8 + hlen
    for e in header["tensors"]:
        buf = np.frombuffer(data, dtype=np.uint8, count=e["nbytes"], offset=off)
        out[e["name"]] = _restore(buf, e["dtype"], e["shape"])
        off += e["nbytes"]
    return out, header["meta"]


def payload_bytes(tensors: Dict[str, Any]) -> int:
    return sum(np.asarray(v).nbytes for v in tensors.values())
