"""KV artifact serialization: msgpack header + raw tensor bytes.

Mirrors the paper's DeepNVMe usage: tensors are written as raw bytes (no
pickle), so reads are a single sequential scan straight into a reusable bounce
buffer. Header carries shapes/dtypes/meta; payload layout is deterministic
(sorted keys) so offsets are computable without parsing the payload.
"""

from __future__ import annotations

import struct
from typing import Any, Dict, Tuple

import msgpack
import numpy as np

MAGIC = b"MKV1"


def _np_view(arr) -> np.ndarray:
    """View any array (incl. jax bfloat16) as raw-byte-compatible numpy."""
    a = np.asarray(arr)
    if a.dtype.name == "bfloat16":
        return a.view(np.uint16)
    return a


def _restore(buf: np.ndarray, dtype_name: str, shape) -> np.ndarray:
    if dtype_name == "bfloat16":
        import ml_dtypes  # jax dependency, always present
        return buf.view(ml_dtypes.bfloat16).reshape(shape)
    return buf.view(np.dtype(dtype_name)).reshape(shape)


def serialize(tensors: Dict[str, Any], meta: Dict[str, Any] | None = None) -> bytes:
    """tensors: flat dict name -> array. Returns bytes."""
    names = sorted(tensors)
    entries, payloads = [], []
    for name in names:
        a = np.ascontiguousarray(_np_view(tensors[name]))
        raw_dtype = np.asarray(tensors[name]).dtype.name
        entries.append({"name": name, "dtype": raw_dtype,
                        "shape": list(np.asarray(tensors[name]).shape),
                        "nbytes": a.nbytes})
        payloads.append(a.tobytes())
    header = msgpack.packb({"tensors": entries, "meta": meta or {}})
    return MAGIC + struct.pack("<I", len(header)) + header + b"".join(payloads)


def _parse_header(data: bytes) -> Tuple[Dict[str, Any], int]:
    """Parse the fixed prefix + msgpack header; returns (header, payload
    offset). ``data`` may be just the header prefix of an artifact."""
    if data[:4] != MAGIC:
        raise ValueError("bad magic: not a MatKV artifact")
    if len(data) < 8:
        raise ValueError(f"truncated header: need 8 prefix bytes, "
                         f"got {len(data)}")
    hlen = struct.unpack("<I", data[4:8])[0]
    if len(data) < 8 + hlen:
        raise ValueError(f"truncated header: need {8 + hlen} bytes, "
                         f"got {len(data)}")
    return msgpack.unpackb(data[8:8 + hlen]), 8 + hlen


def read_meta(data: bytes) -> Dict[str, Any]:
    """Header-only inspection: the ``meta`` dict (n_tokens / codec / family /
    ids) without touching payload bytes. ``data`` may be a prefix of the
    artifact, as long as it covers the header — schedulers sizing admits or
    pools can read the first few hundred bytes of a file instead of the
    whole payload.
    """
    header, _ = _parse_header(data)
    return header["meta"]


def deserialize(data: bytes) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    header, off = _parse_header(data)
    out = {}
    for e in header["tensors"]:
        buf = np.frombuffer(data, dtype=np.uint8, count=e["nbytes"], offset=off)
        out[e["name"]] = _restore(buf, e["dtype"], e["shape"])
        off += e["nbytes"]
    return out, header["meta"]


def payload_bytes(tensors: Dict[str, Any]) -> int:
    return sum(np.asarray(v).nbytes for v in tensors.values())
