"""ShapeDtypeStruct input specs + lowerable step functions per (arch x shape).

``input_specs(cfg, shape)`` builds weak-type-correct SDS stand-ins for every
model input (tokens/labels, stub frontend embeddings, decode caches) — no
device allocation. ``make_lowerable`` pairs them with the right step function
(train_step / prefill_step / serve_step) and the shardings resolved from
repro.dist, ready for ``jit(...).lower(...).compile()``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import config_for_shape, get_shape
from repro.configs.shapes import InputShape
from repro.dist.partition import (batch_specs, cache_specs, param_specs,
                                  to_shardings, zero1_specs)
from repro.dist.sharding import mesh_context
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, apply_updates, init_state

WHISPER_DECODER_LEN = 448


def shape_rules(cfg, shape: InputShape) -> Dict[str, tuple]:
    """Per-shape logical-rule overrides (DESIGN.md §6)."""
    if shape.kind in ("train", "prefill"):
        # Megatron sequence parallelism for the residual stream (and the
        # context-parallel q fallback for head counts that don't divide the
        # model axis — see attention._shard_q)
        return {"act_seq": ("model",)}
    if shape.kind == "decode" and shape.global_batch == 1:
        # batch=1 long-context: context-parallel cache over every axis
        return {"cache_seq": ("pod", "data", "model")}
    return {}


def resolved_config(arch: str, shape_name: str):
    """config_for_shape + per-shape structural adjustments (whisper enc len)."""
    cfg, ok, reason = config_for_shape(arch, shape_name)
    shape = get_shape(shape_name)
    if cfg.family in ("encdec", "audio"):
        # seq_len maps to the ENCODER frame axis (the MatKV'd "document");
        # decoder length is capped by the architecture (448 for whisper)
        cfg = dataclasses.replace(cfg, enc_positions=shape.seq_len,
                                  frontend_tokens=shape.seq_len)
    return cfg, shape, ok, reason


def params_sds(model, cfg, shape: InputShape):
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    if model.is_encdec:
        return jax.eval_shape(
            lambda k: model.init(k, enc_len=cfg.enc_positions,
                                 dec_len=WHISPER_DECODER_LEN), key)
    return jax.eval_shape(model.init, key)


def input_specs(cfg, shape: InputShape, model=None) -> Dict[str, Any]:
    """SDS stand-ins for the step inputs of this (arch, shape)."""
    model = model or build_model(cfg)
    b, s = shape.global_batch, shape.seq_len
    tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
    emb = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.bfloat16)

    if shape.kind == "train":
        if cfg.family in ("encdec", "audio"):
            return {"frontend": emb(b, s, cfg.d_model),
                    "tokens": tok(b, WHISPER_DECODER_LEN),
                    "labels": tok(b, WHISPER_DECODER_LEN)}
        if cfg.frontend:  # vlm
            ft = min(cfg.frontend_tokens, s // 2)
            return {"frontend": emb(b, ft, cfg.d_model),
                    "tokens": tok(b, s - ft), "labels": tok(b, s - ft)}
        return {"tokens": tok(b, s), "labels": tok(b, s)}

    if shape.kind == "prefill":
        if cfg.family in ("encdec", "audio"):
            return {"frontend": emb(b, s, cfg.d_model)}
        if cfg.frontend:
            ft = min(cfg.frontend_tokens, s // 2)
            return {"frontend": emb(b, ft, cfg.d_model),
                    "tokens": tok(b, s - ft)}
        return {"tokens": tok(b, s)}

    # decode: ONE new token against a seq_len cache
    if cfg.family in ("encdec", "audio"):
        cache = jax.eval_shape(
            lambda: build_model(cfg).init_cache(
                b, WHISPER_DECODER_LEN, enc_len=s))
    else:
        cache = jax.eval_shape(lambda: build_model(cfg).init_cache(b, s))
    return {"cache": cache, "tokens": tok(b, 1)}


def make_lowerable(arch: str, shape_name: str, mesh,
                   adamw: Optional[AdamWConfig] = None,
                   cfg_override=None):
    """Returns (jitted_fn, args tuple of SDS, rules, cfg) or raises
    Inapplicable for skipped (arch, shape) pairs. ``cfg_override`` substitutes
    a modified config (the dry-run's reduced-depth cost lowers)."""
    cfg, shape, ok, reason = resolved_config(arch, shape_name)
    if not ok:
        raise Inapplicable(reason)
    if cfg_override is not None:
        cfg = cfg_override
    model = build_model(cfg)
    rules = shape_rules(cfg, shape)
    p_sds = params_sds(model, cfg, shape)
    p_specs = param_specs(mesh, p_sds, rules)
    p_sh = to_shardings(mesh, p_specs)
    batch = input_specs(cfg, shape, model)

    if shape.kind == "train":
        adamw = adamw or AdamWConfig()
        from repro.training.optimizer import AdamWState
        opt_sds = jax.eval_shape(init_state, p_sds)
        zspecs = zero1_specs(mesh, p_sds, p_specs)
        opt_specs = AdamWState(step=jax.sharding.PartitionSpec(),
                               m=zspecs, v=zspecs)
        opt_sh = to_shardings(mesh, opt_specs)
        b_sh = to_shardings(mesh, batch_specs(mesh, batch, rules))

        def train_step(params, opt_state, b):
            with mesh_context(mesh, rules):
                (loss, metrics), grads = jax.value_and_grad(
                    lambda p: model.loss(p, b, remat=True, ce_chunk=512),
                    has_aux=True)(params)
                params, opt_state, om = apply_updates(adamw, params, grads,
                                                      opt_state)
                metrics = dict(metrics)
                metrics.update(om)
                return params, opt_state, metrics

        fn = jax.jit(train_step, in_shardings=(p_sh, opt_sh, b_sh),
                     donate_argnums=(0, 1))
        return fn, (p_sds, opt_sds, batch), rules, cfg

    if shape.kind == "prefill":
        b_sh = to_shardings(mesh, batch_specs(mesh, batch, rules))

        def prefill_step(params, b):
            with mesh_context(mesh, rules):
                _, artifact = model.prefill(params, b)
                return artifact

        fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
        return fn, (p_sds, batch), rules, cfg

    # decode
    cache_sds = batch["cache"]
    c_sh = to_shardings(mesh, cache_specs(mesh, cache_sds, rules))
    t_sh = to_shardings(mesh, batch_specs(
        mesh, {"tokens": batch["tokens"]}, rules))["tokens"]

    def serve_step(params, cache, tokens):
        with mesh_context(mesh, rules):
            return model.decode_step(params, cache, tokens)

    fn = jax.jit(serve_step, in_shardings=(p_sh, c_sh, t_sh),
                 donate_argnums=(1,))
    return fn, (p_sds, cache_sds, batch["tokens"]), rules, cfg


class Inapplicable(Exception):
    """(arch, shape) pair intentionally skipped (see DESIGN.md §5)."""
