"""Distributed serving launcher (the MatKV read path, batched).

Stands up the full serving stack on the devices present: builds a mesh,
shards params over (data, model), materializes a corpus's chunk KVs onto a
flash store, then serves batched requests through the MatKV engine with the
overlap pipeline. On one CPU device this is the runnable end-to-end demo; on
a pod slice the same script serves with sharded params/caches.

``--mesh N`` serves tensor-parallel over a 1-axis ("model",) mesh of the
first N devices (DESIGN.md §12): params placed by the repro.dist partition
specs, the row cache / paged block pool sharded along the KV-head axis.
``--continuous`` swaps the fixed BatchScheduler for the continuous-batching
scheduler; ``--paged`` additionally serves over the chunk-shared block pool
(implies --continuous). Validate without accelerators via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

``--role`` runs one side of the disaggregated split (DESIGN.md §14):
``materialize`` ingests the corpus and writes codec-tagged artifacts (plus
the work-queue manifest ``<store-dir>/queue.json``) and exits; ``decode``
loads that manifest, hands requests off to a ``DecodeWorker``, and serves
over the paged pool without ever prefilling a document token. The two
roles share nothing but ``--store-dir`` — run them as separate processes
against one directory. ``both`` (default) is the composed single-process
engine, bit-identical to the pre-split monolith.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m --reduced \
      --requests 16 --batch 4 [--mode matkv|vanilla|cacheblend] [--overlap] \
      [--ssd 9100pro|raid0|pm9a3|dram] [--mesh N] [--continuous] [--paged] \
      [--streaming] [--host-tier-mb MB] \
      [--role both|materialize|decode --store-dir DIR] [--trace PATH]

``--trace PATH`` exports the run as a Chrome ``trace_event`` JSON
(chrome://tracing / Perfetto): spans for flash reads, pool inserts,
compose/prefill, decode steps, and materialize jobs (DESIGN.md §15). Each
role process writes its own file; ``repro.obs.merge_chrome`` joins them
into one timeline keyed on chunk/request ids.
"""

from __future__ import annotations

import argparse
import tempfile
import time
from pathlib import Path

import jax

from repro.configs import ASSIGNED, get_config
from repro.kvstore import FlashKVStore, SimulatedReader
from repro.launch.mesh import make_serving_mesh
from repro.models import build_model
from repro.obs import Tracer
from repro.serving import (BatchScheduler, ContinuousScheduler, DecodeWorker,
                           HandoffRecord, MaterializerWorker, RagEngine,
                           WorkQueue)

CORPUS_WORDS = ["amber", "basil", "cedar", "delta", "ember", "fjord",
                "grove", "haven", "iris", "jade", "karst", "lotus"]

CHUNK_TOKENS = 64


def corpus_docs():
    for i, w in enumerate(CORPUS_WORDS):
        yield f"doc{i:02d}", (f"the {w} artifact number {i} rests in chamber "
                              f"{i * 7} of the deep vault. ") * 5


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ASSIGNED))
    ap.add_argument("--mode", default="matkv",
                    choices=["matkv", "vanilla", "cacheblend"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=None,
                    help="batch size / decode slots (default 4). Only valid "
                         "where a batching scheduler runs")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--overlap", action="store_true")
    ap.add_argument("--ssd", default=None,
                    choices=[None, "9100pro", "raid0", "pm9a3", "dram"],
                    help="simulate this SSD tier's read bandwidth")
    ap.add_argument("--store-dir", default=None,
                    help="persistent KV store dir (default: temp)")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--rerotate", action="store_true",
                    help="beyond-paper position re-rotation at compose")
    ap.add_argument("--codec", default="bf16", choices=["bf16", "int8"],
                    help="KV storage codec, end to end (DESIGN.md §11): "
                         "int8 halves flash bytes and doubles pool residency")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="serve tensor-parallel over a ('model',) mesh of "
                         "the first N devices (0 = single-device)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous-batching scheduler (per-request "
                         "admit/evict) instead of fixed batches")
    ap.add_argument("--paged", action="store_true",
                    help="serve over the chunk-shared paged block pool "
                         "(implies --continuous)")
    ap.add_argument("--streaming", action="store_true",
                    help="block-granular streaming admission (DESIGN.md "
                         "§16): cold chunks fold into an online-softmax "
                         "carry as their blocks land, instead of waiting "
                         "for whole artifacts (requires --paged)")
    ap.add_argument("--host-tier-mb", type=float, default=0.0, metavar="MB",
                    help="host-DRAM demotion tier budget in MiB: reclaimed "
                         "refs-0 pool pages pack into host bytes and "
                         "re-promote without touching flash (requires "
                         "--paged; 0 disables)")
    ap.add_argument("--three-phase", action="store_true",
                    help="pin the paged decode step to the three-phase "
                         "gather/step/scatter pipeline instead of the fused "
                         "single-launch kernel (parity oracle / fallback)")
    ap.add_argument("--role", default="both",
                    choices=["both", "materialize", "decode"],
                    help="disaggregated role (DESIGN.md §14): 'materialize' "
                         "writes chunk artifacts + queue manifest to "
                         "--store-dir and exits; 'decode' serves requests "
                         "from those artifacts over the paged pool; 'both' "
                         "composes the two in one process (default)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="export a Chrome trace_event JSON of the run to "
                         "PATH (load it in chrome://tracing or Perfetto). "
                         "Spans cover flash reads, pool inserts, compose, "
                         "prefill, decode steps, materialize; role runs "
                         "write one file per role that merge_chrome can "
                         "join on chunk/request ids (DESIGN.md §15)")
    args = ap.parse_args()

    # reject silently-ignored flag combinations up front: running a
    # different configuration than the one asked for is worse than an error
    if args.three_phase and not (args.paged or args.role == "decode"):
        ap.error("--three-phase only affects the paged decode step; it is "
                 "silently ignored without --paged")
    if args.overlap and (args.continuous or args.paged):
        ap.error("--overlap belongs to the fixed BatchScheduler; the "
                 "continuous scheduler always overlaps loads with decode, "
                 "so the flag would be silently ignored")
    if (args.batch is not None and args.mode != "matkv"
            and not (args.continuous or args.paged)):
        ap.error("--batch has no effect on the sequential vanilla/cacheblend "
                 "path (requests are served one by one, with or without a "
                 "mesh); drop it or serve --mode matkv / --continuous")
    if args.role != "both":
        if args.store_dir is None:
            ap.error(f"--role {args.role} requires --store-dir: the flash "
                     "artifact plane is the only interface between the "
                     "roles, so it must outlive each process")
        if args.mode != "matkv":
            ap.error(f"--role {args.role} requires --mode matkv (the role "
                     "split serves materialized artifacts)")
        if args.rerotate:
            ap.error(f"--role {args.role} requires rerotate=False (decode "
                     "serves position-independent shared pages)")
    if args.role == "decode":
        args.continuous = True
        args.paged = True
    if args.streaming and not args.paged:
        ap.error("--streaming rides the paged block pool's resident "
                 "frontier; add --paged (or --role decode)")
    if args.host_tier_mb and not args.paged:
        ap.error("--host-tier-mb backs the paged pool's reclaim path; it "
                 "is silently ignored without --paged")
    if args.streaming and args.rerotate:
        ap.error("--streaming requires rerotate=False: the online-softmax "
                 "carry folds position-independent shared pages")
    if args.paged:
        args.continuous = True
    if args.trace is not None and args.role == "both" and not args.continuous:
        ap.error("--trace instruments the continuous/paged schedulers and "
                 "the role workers; the fixed-batch and sequential paths "
                 "are untraced — add --continuous/--paged or a --role")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(vocab_size=300, num_layers=2, d_model=128)
    if cfg.family not in ("dense", "vlm", "moe"):
        ap.error(f"{args.arch} ({cfg.family}): batched serving launcher "
                 "supports attention-KV families; SSM/hybrid serve "
                 "single-stream via RagEngine (see examples/)")
    if args.continuous and args.mode != "matkv":
        ap.error("--continuous/--paged require --mode matkv (the continuous "
                 "scheduler serves materialized artifacts)")
    if args.paged and args.rerotate:
        # fail at parse time, not minutes later in init_paged_cache: shared
        # chunk pages must be position-independent (DESIGN.md §10)
        ap.error("--paged requires rerotate=False: re-rotated keys are "
                 "position-dependent and cannot be shared across rows")
    batch = args.batch if args.batch is not None else 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_serving_mesh(args.mesh) if args.mesh else None
    print(f"serving {cfg.name} mode={args.mode} role={args.role} "
          f"devices={len(jax.devices())}"
          + (f" mesh=model:{args.mesh}" if mesh is not None else ""))

    tracer = Tracer(role=args.role) if args.trace else None

    if args.role == "materialize":
        _run_materialize_role(args, model, params, mesh, tracer)
        _export_trace(args, tracer)
        return
    if args.role == "decode":
        _run_decode_role(args, model, params, mesh, batch, tracer)
        _export_trace(args, tracer)
        return

    root_ctx = (tempfile.TemporaryDirectory() if args.store_dir is None
                else None)
    root = args.store_dir or root_ctx.name
    try:
        store = FlashKVStore(root)
        reader = SimulatedReader(store, args.ssd) if args.ssd else None
        eng = RagEngine(model, params, store, mode=args.mode,
                        chunk_tokens=CHUNK_TOKENS, top_k=2, reader=reader,
                        rerotate=args.rerotate, codec=args.codec,
                        mesh=mesh, tracer=tracer)
        t0 = time.perf_counter()
        n = 0
        for doc_id, text in corpus_docs():
            n += len(eng.ingest(doc_id, text))
        print(f"ingest: {n} chunks, {store.total_bytes() / 2**20:.1f} MiB KV, "
              f"{time.perf_counter() - t0:.1f}s")

        qs = [f"where is the {CORPUS_WORDS[i % len(CORPUS_WORDS)]} artifact?"
              for i in range(args.requests)]
        if args.continuous:
            host_tier = (int(args.host_tier_mb * 2**20)
                         if args.host_tier_mb else None)
            sched = ContinuousScheduler(eng, max_slots=batch,
                                        paged=args.paged,
                                        fused=not args.three_phase,
                                        streaming=args.streaming,
                                        host_tier=host_tier)
            sched.run(qs[:batch], max_new_tokens=args.new_tokens)     # warm
            if tracer is not None:
                tracer.clear()          # trace the timed run, not the warmup
            t0 = time.perf_counter()
            answers, m = sched.run(qs, max_new_tokens=args.new_tokens)
            wall = time.perf_counter() - t0
            sched.shutdown()
            print(f"served {len(answers)} requests in {wall:.2f}s "
                  f"({m.tokens_per_s:.1f} tok/s, p95={m.p95_latency_s:.3f}s, "
                  f"paged={args.paged})")
            if args.paged:
                shard_mb = [b / 2**20 for b in m.pool_shard_bytes]
                print(f"pool: hit_rate={m.chunk_hit_rate:.2f} "
                      f"flash={m.flash_bytes_loaded / 2**20:.2f} MiB "
                      f"resident_peak={m.hbm_kv_bytes_resident / 2**20:.2f} "
                      f"MiB over {len(shard_mb)} shard(s) "
                      f"({', '.join(f'{s:.2f}' for s in shard_mb)} MiB each)")
            if args.streaming:
                print(f"streaming: p50_ttft={m.p50_ttft_s:.3f}s "
                      f"p95_ttft={m.p95_ttft_s:.3f}s "
                      f"load_overlap={m.load_overlap_frac:.2f}"
                      + ("" if args.trace else " (overlap needs --trace)"))
            print(f"sample answer: {answers[0]!r}")
            _export_trace(args, tracer)
            return
        if args.mode == "matkv":
            sched = BatchScheduler(eng, batch_size=batch,
                                   overlap=args.overlap)
            sched.run(qs[:batch], max_new_tokens=args.new_tokens)      # warm
            t0 = time.perf_counter()
            answers, t = sched.run(qs, max_new_tokens=args.new_tokens)
            wall = time.perf_counter() - t0
        else:
            eng.answer(qs[0], max_new_tokens=args.new_tokens)          # warm
            t0 = time.perf_counter()
            answers = []
            t = None
            for q in qs:
                a, ti = eng.answer(q, max_new_tokens=args.new_tokens)
                answers.append(a)
                t = ti
            wall = time.perf_counter() - t0
        print(f"served {len(answers)} requests in {wall:.2f}s "
              f"({len(answers) / wall:.2f} req/s, overlap={args.overlap})")
        if t is not None:
            print(f"last-batch phases: load={t.load_s:.3f}s "
                  f"prefill={t.prefill_s:.3f}s decode={t.decode_s:.3f}s")
        print(f"sample answer: {answers[0]!r}")
    finally:
        if root_ctx is not None:
            root_ctx.cleanup()


def _export_trace(args, tracer) -> None:
    if tracer is None:
        return
    path = Path(args.trace)
    path.parent.mkdir(parents=True, exist_ok=True)
    tracer.to_chrome(path)
    n = len(tracer.events)
    print(f"trace: {n} events (role={tracer.role}) -> {path}")


def _load_queue(store_dir: str):
    path = Path(store_dir) / "queue.json"
    return (WorkQueue.load(path) if path.exists() else WorkQueue()), path


def _frontend_index():
    """Retrieval front-end state from corpus text alone — chunking +
    hashing embeddings, zero model compute (what a lightweight router in
    front of the decode fleet runs)."""
    from repro.core.chunking import chunk_document
    from repro.data.tokenizer import ByteTokenizer
    from repro.retrieval.embed import HashingEmbedder
    from repro.retrieval.vectordb import VectorDB

    tok = ByteTokenizer()
    emb = HashingEmbedder()
    vdb = VectorDB(emb.dim)
    chunks = {}
    for doc_id, text in corpus_docs():
        for c in chunk_document(doc_id, tok.encode(text), CHUNK_TOKENS):
            chunks[c.chunk_id] = c
            vdb.add(c.chunk_id, emb.embed_tokens(c.tokens))
    retrieve = lambda q, k=2: [cid for cid, _ in
                               vdb.search(emb.embed_tokens(tok.encode(q)), k)]
    return chunks, retrieve


def _run_materialize_role(args, model, params, mesh, tracer=None) -> None:
    """Materializer role: ingest the corpus, drain any miss jobs a decode
    process left in the manifest, persist the queue manifest, exit."""
    store = FlashKVStore(args.store_dir)
    queue, qpath = _load_queue(args.store_dir)
    if tracer is not None:
        queue.tracer = tracer
    mat = MaterializerWorker(model, params, store, codec=args.codec,
                             chunk_tokens=CHUNK_TOKENS, queue=queue,
                             mesh=mesh, tracer=tracer)
    t0 = time.perf_counter()
    n = 0
    for doc_id, text in corpus_docs():
        n += len(mat.ingest_document(doc_id, text))
    jobs = mat.process_jobs()
    queue.save(qpath)
    m = mat.metrics
    print(f"materialized {n} chunks (+{jobs} queued jobs) in "
          f"{time.perf_counter() - t0:.1f}s: "
          f"{m.n_materialized_tokens} tokens, "
          f"{m.materialize_tokens_per_s:.0f} materialize tok/s, "
          f"{store.total_bytes() / 2**20:.1f} MiB on flash; "
          f"manifest -> {qpath}")


def _run_decode_role(args, model, params, mesh, batch: int,
                     tracer=None) -> None:
    """Decode role: no retrieval model-side — a front-end index hands
    requests off through the queue; the worker serves them over the paged
    pool from the materializer's artifacts."""
    store = FlashKVStore(args.store_dir)
    queue, qpath = _load_queue(args.store_dir)
    if tracer is not None:
        queue.tracer = tracer
    chunks, retrieve = _frontend_index()
    missing = [cid for cid in chunks if not store.exists(cid)]
    if missing:
        raise SystemExit(
            f"decode role: {len(missing)}/{len(chunks)} chunk artifacts "
            f"missing from {args.store_dir}; run --role materialize against "
            f"the same --store-dir first")
    reader = SimulatedReader(store, args.ssd) if args.ssd else None
    worker = DecodeWorker(model, params, store, codec=args.codec,
                          chunk_tokens=CHUNK_TOKENS, top_k=2, reader=reader,
                          queue=queue, mesh=mesh, tracer=tracer)
    qs = [f"where is the {CORPUS_WORDS[i % len(CORPUS_WORDS)]} artifact?"
          for i in range(args.requests)]
    for q in qs:
        cids = retrieve(q)
        queue.submit_handoff(HandoffRecord(
            q, cids, args.new_tokens,
            generations=queue.generations_snapshot(cids)))
    sched = ContinuousScheduler(
        worker, max_slots=batch, paged=True, fused=not args.three_phase,
        streaming=args.streaming,
        host_tier=(int(args.host_tier_mb * 2**20)
                   if args.host_tier_mb else None))
    t0 = time.perf_counter()
    answers, m = sched.run(qs, max_new_tokens=args.new_tokens)
    wall = time.perf_counter() - t0
    sched.shutdown()
    worker.shutdown()
    queue.save(qpath)
    print(f"decoded {len(answers)} requests in {wall:.2f}s "
          f"(role={m.role}, {m.decode_tokens_per_s:.1f} decode tok/s, "
          f"{m.tokens_per_s:.1f} blended tok/s, "
          f"p95={m.p95_latency_s:.3f}s, hit_rate={m.chunk_hit_rate:.2f})")
    print(f"sample answer: {answers[0]!r}")


if __name__ == "__main__":
    main()
