"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""

from __future__ import annotations

import jax

from repro.dist import _compat  # noqa: F401  (AxisType/make_mesh shims)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small host mesh for distribution tests (subprocesses set device count)."""
    return jax.make_mesh(
        (n_data, n_model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)


def make_serving_mesh(n_model: int, *, devices=None):
    """1-axis ``("model",)`` mesh over the first ``n_model`` devices — the
    tensor-parallel serving mesh (DESIGN.md §12): decode shards KV heads and
    the Megatron column/row-parallel projections over this axis. Built from
    an explicit device slice (not ``jax.make_mesh``) so a subset of the
    platform's devices works — the forced-host-device CPU platform and real
    accelerators alike."""
    import numpy as np

    devices = list(jax.devices() if devices is None else devices)
    if not 1 <= n_model <= len(devices):
        raise ValueError(f"make_serving_mesh: n_model={n_model} must be in "
                         f"[1, {len(devices)}] (visible devices)")
    return jax.sharding.Mesh(np.asarray(devices[:n_model]), ("model",))


def make_role_meshes(n_prefill: int, n_decode: int, *, devices=None):
    """Heterogeneous role meshes for disaggregated serving (DESIGN.md §14):
    two DISJOINT 1-axis ``("model",)`` meshes carved from one device pool —
    the first ``n_prefill`` devices for the materializer role, the next
    ``n_decode`` for the decode role. Models the paper's second headline
    result in one process: a large prefill fleet feeding a deliberately
    small (weak) decode mesh, with the flash artifact plane between them.
    Returns ``(prefill_mesh, decode_mesh)``."""
    import numpy as np

    devices = list(jax.devices() if devices is None else devices)
    if n_prefill < 1 or n_decode < 1:
        raise ValueError(f"make_role_meshes: both roles need >=1 device, "
                         f"got prefill={n_prefill} decode={n_decode}")
    if n_prefill + n_decode > len(devices):
        raise ValueError(
            f"make_role_meshes: prefill={n_prefill} + decode={n_decode} "
            f"exceeds {len(devices)} visible devices (roles must not share "
            f"devices — the split is the point)")
    prefill = jax.sharding.Mesh(np.asarray(devices[:n_prefill]), ("model",))
    decode = jax.sharding.Mesh(
        np.asarray(devices[n_prefill:n_prefill + n_decode]), ("model",))
    return prefill, decode
