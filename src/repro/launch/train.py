"""Distributed training launcher.

Builds a mesh from the devices actually present (or ``--mesh data,model``),
resolves parameter / optimizer / batch shardings through ``repro.dist``
(identical logical rules to the dry-run), initializes sharded params, and
runs real steps on the synthetic LM pipeline. On one CPU device the mesh
degenerates to (1, 1) and this is an ordinary training run; on a pod slice
the same script shards over (data, model).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 50 --batch 8 --seq-len 256 [--reduced] [--mesh 1,1]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, get_config
from repro.data.pipeline import PrefetchIterator
from repro.data.synthetic import lm_stream
from repro.dist.partition import (batch_specs, param_specs, to_shardings,
                                  zero1_specs)
from repro.dist.sharding import mesh_context
from repro.models import build_model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizer import (AdamWConfig, AdamWState, apply_updates,
                                      init_state)


def parse_mesh(spec: str | None):
    n_dev = len(jax.devices())
    if spec:
        dims = tuple(int(x) for x in spec.split(","))
    else:
        dims = (n_dev, 1)
    assert dims[0] * dims[1] == n_dev, (
        f"mesh {dims} != {n_dev} devices; pass --mesh d,m matching the host")
    return jax.make_mesh(dims, ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ASSIGNED))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--mesh", default=None, help="data,model (default: N,1)")
    ap.add_argument("--reduced", action="store_true",
                    help="2-layer reduced variant (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    mesh = parse_mesh(args.mesh)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))}")

    # -- resolve shardings exactly as the dry-run does -------------------------
    rules = {"act_seq": ("model",)}          # Megatron sequence parallelism
    p_sds = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_specs = param_specs(mesh, p_sds, rules)
    p_sh = to_shardings(mesh, p_specs)
    zspecs = zero1_specs(mesh, p_sds, p_specs)
    opt_sh = to_shardings(mesh, AdamWState(
        step=jax.sharding.PartitionSpec(), m=zspecs, v=zspecs))

    params = jax.jit(model.init, out_shardings=p_sh)(
        jax.random.PRNGKey(0))
    opt_state = jax.jit(init_state, out_shardings=opt_sh)(params)
    n_params = sum(p.size for p in jax.tree.leaves(params))
    print(f"params: {n_params / 1e6:.1f}M  "
          f"({n_params * 2 / 2**30:.2f} GiB bf16 global)")

    adamw = AdamWConfig(lr=args.lr)
    sample = {"tokens": jax.ShapeDtypeStruct((args.batch, args.seq_len),
                                             jnp.int32),
              "labels": jax.ShapeDtypeStruct((args.batch, args.seq_len),
                                             jnp.int32)}
    b_sh = to_shardings(mesh, batch_specs(mesh, sample, rules))

    def train_step(params, opt_state, batch):
        with mesh_context(mesh, rules):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: model.loss(p, batch), has_aux=True)(params)
            params, opt_state, om = apply_updates(adamw, params, grads,
                                                  opt_state)
            metrics = dict(metrics)
            metrics.update(om)
            return params, opt_state, metrics

    step_fn = jax.jit(train_step, in_shardings=(p_sh, opt_sh, b_sh),
                      donate_argnums=(0, 1))

    stream = PrefetchIterator(
        lm_stream(cfg.vocab_size, args.batch, args.seq_len), depth=2)
    t0 = time.perf_counter()
    for step in range(args.steps):
        batch = next(stream)
        batch = {"tokens": jnp.asarray(batch["tokens"]),
                 "labels": jnp.asarray(batch["labels"])}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            tps = args.batch * args.seq_len * (step + 1) / dt
            print(f"step {step:5d}  loss={loss:.4f}  "
                  f"{tps:,.0f} tok/s  {dt:.1f}s", flush=True)
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, params, opt_state)
        print(f"checkpoint written to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
