import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init). Everything below is ordinary.

"""Multi-pod dry run: lower + compile every (architecture x input shape) on the
production meshes, print memory_analysis / cost_analysis, and emit the roofline
rows consumed by EXPERIMENTS.md §Dry-run / §Roofline.

Two compiles per pair (see DESIGN.md §Roofline-accounting):

1. FIT compile — full depth, scan-over-layers (production lowering). Proves the
   sharding is coherent and ``memory_analysis()`` reflects the true per-device
   peak. XLA's cost model counts while-loop bodies once, so this compile is NOT
   used for FLOPs.
2. COST lowers — reduced-depth (one and two layer-stack periods) with
   REPRO_UNROLL=1 (scans unrolled). Per-layer cost slope = (c2p - c1p)/period;
   total = intercept + slope * num_layers. Captures true per-layer FLOPs,
   bytes, and collective bytes including everything GSPMD inserts. Time-step
   recurrences (mamba/RG-LRU) are corrected analytically on top
   (analysis.roofline.time_scan_correction).

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod]
Results append to experiments/dryrun/results.jsonl (one JSON object per pair).
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.analysis.roofline import (Roofline, model_flops_for,
                                     parse_collectives, time_scan_correction)
from repro.configs import ASSIGNED, SHAPES, get_shape
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import Inapplicable, make_lowerable

RESULTS = Path("experiments/dryrun/results.jsonl")


def _depth_period(cfg) -> int:
    """Layer-stack period for the cost extrapolation."""
    if cfg.family == "hybrid":
        return len(cfg.block_pattern)
    return 1


def _reduced(cfg, n_layers: int):
    repl = {"num_layers": n_layers}
    if cfg.family in ("encdec", "audio"):
        repl.update(enc_layers=n_layers, dec_layers=n_layers)
    if cfg.family == "moe":
        repl.update(first_dense_layers=min(cfg.first_dense_layers, 1))
    return dataclasses.replace(cfg, **repl)


def _cost_of(arch, shape_name, mesh, cfg_override):
    fn, args, _, _ = make_lowerable(arch, shape_name, mesh,
                                    cfg_override=cfg_override)
    with mesh:
        compiled = fn.lower(*args).compile()
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll.total_bytes), dict(coll.bytes_by_op))


def run_pair(arch: str, shape_name: str, multi_pod: bool) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    shape = get_shape(shape_name)

    # ---- 1. FIT compile: full depth, scan lowering --------------------------
    os.environ["REPRO_UNROLL"] = "0"
    t0 = time.perf_counter()
    try:
        fn, args, rules, cfg = make_lowerable(arch, shape_name, mesh)
    except Inapplicable as e:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": str(e)}
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    t_fit = time.perf_counter() - t0
    mem = compiled.memory_analysis()
    peak = int(getattr(mem, "temp_size_in_bytes", 0)
               + getattr(mem, "argument_size_in_bytes", 0)
               + getattr(mem, "output_size_in_bytes", 0)
               - getattr(mem, "alias_size_in_bytes", 0))
    print(compiled.memory_analysis())

    # ---- 2. COST lowers: reduced depth, unrolled -----------------------------
    os.environ["REPRO_UNROLL"] = "1"
    period = _depth_period(cfg)
    l1, l2 = period, 2 * period
    if cfg.family == "moe" and cfg.first_dense_layers:
        l1, l2 = 2, 3  # 1 dense prefix + (1, 2) moe layers
    t0 = time.perf_counter()
    f1, b1, c1, ops1 = _cost_of(arch, shape_name, mesh, _reduced(cfg, l1))
    f2, b2, c2, ops2 = _cost_of(arch, shape_name, mesh, _reduced(cfg, l2))
    t_cost = time.perf_counter() - t0
    os.environ["REPRO_UNROLL"] = "0"

    n_slope = (cfg.num_layers - l1) / (l2 - l1)
    flops = f1 + (f2 - f1) * n_slope
    nbytes = b1 + (b2 - b1) * n_slope
    coll = c1 + (c2 - c1) * n_slope
    coll_ops = {k: ops1.get(k, 0) + (ops2.get(k, 0) - ops1.get(k, 0)) * n_slope
                for k in set(ops1) | set(ops2)}
    xf, xb = time_scan_correction(cfg, shape, chips)
    flops += xf
    nbytes += xb

    roof = Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=nbytes, collective_bytes=coll,
        model_flops=model_flops_for(cfg, shape),
        peak_memory_per_device=peak, collectives=coll_ops)
    row = roof.row()
    row.update({
        "status": "ok",
        "fit_compile_s": round(t_fit, 2),
        "cost_compile_s": round(t_cost, 2),
        "scan_correction_flops": xf, "scan_correction_bytes": xb,
        "memory_analysis": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        },
    })
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ASSIGNED), help="one architecture")
    ap.add_argument("--shape", choices=sorted(SHAPES), help="one input shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) pair")
    ap.add_argument("--out", default=str(RESULTS))
    args = ap.parse_args()

    pairs = []
    if args.all:
        pairs = [(a, s) for a in sorted(ASSIGNED) for s in
                 ("train_4k", "prefill_32k", "decode_32k", "long_500k")]
    elif args.arch and args.shape:
        pairs = [(args.arch, args.shape)]
    else:
        ap.error("--all or both --arch and --shape required")

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    n_devices = len(jax.devices())
    print(f"devices: {n_devices}")
    assert n_devices == 512, "dryrun requires the 512-device host platform"

    mesh_name = "2x16x16" if args.multi_pod else "16x16"
    done = set()
    if out_path.exists():
        for line in out_path.read_text().splitlines():
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass

    for arch, shape in pairs:
        if (arch, shape, mesh_name) in done:
            print(f"CACHED {arch} x {shape} [{mesh_name}]")
            continue
        label = f"{arch} x {shape} [{mesh_name}]"
        try:
            row = run_pair(arch, shape, args.multi_pod)
        except Exception as e:  # a failure here is a bug in our sharding
            row = {"arch": arch, "shape": shape, "mesh": mesh_name,
                   "status": "error", "error": repr(e),
                   "traceback": traceback.format_exc()[-2000:]}
        with open(out_path, "a") as f:
            f.write(json.dumps(row) + "\n")
        if row["status"] == "ok":
            print(f"OK   {label}: fit={row['fit_compile_s']}s "
                  f"cost={row['cost_compile_s']}s "
                  f"bottleneck={row['bottleneck']} "
                  f"compute={row['compute_s']:.3e}s "
                  f"memory={row['memory_s']:.3e}s "
                  f"collective={row['collective_s']:.3e}s "
                  f"peak/dev={row['peak_memory_per_device']/2**30:.2f}GiB",
                  flush=True)
        elif row["status"] == "skipped":
            print(f"SKIP {label}: {row['reason']}", flush=True)
        else:
            print(f"FAIL {label}: {row['error']}", flush=True)


if __name__ == "__main__":
    main()
