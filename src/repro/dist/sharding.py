"""Logical-axis sharding rules + the runtime mesh context (DESIGN.md §6).

Every tensor dimension in the model code is named with a *logical axis*
("batch", "heads", "ffn", ...); a **rule set** maps each logical axis to the
tuple of mesh axes it may shard over. The model layers never mention mesh
axes directly — they call ``shard(x, *logical_names)`` and the active
(mesh, rules) pair decides the physical layout. This is what lets one model
definition serve a single CPU device, the (data, model) trainer mesh, and
the 512-chip (pod, data, model) dry-run without edits.

Three pieces:

* ``DEFAULT_RULES`` — the baseline logical->mesh mapping covering every
  parameter / activation / cache axis used by all five families.
* ``resolve`` / ``spec_for`` — divisibility-aware rule application. A rule
  naming several mesh axes falls back to the longest prefix whose combined
  extent divides the dimension; a dimension no prefix divides stays
  replicated. Partial rule dicts MERGE ONTO the defaults (override
  semantics) — treating an override as the complete rule set silently
  replicates every axis it doesn't mention (EXPERIMENTS.md §Perf iter 4).
* ``mesh_context`` / ``current_mesh`` / ``shard`` — the runtime side: a
  context manager installs (mesh, merged rules); ``shard`` constrains a
  value to the spec its logical names resolve to, and is a no-op when no
  mesh is active (single-device paths, init, smoke tests).
"""

from __future__ import annotations

import contextlib
import contextvars
import math
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import _compat  # noqa: F401  (installs jax version shims)

Rule = Tuple[str, ...]
Rules = Dict[str, Rule]
Resolved = Union[None, str, Tuple[str, ...]]


# ---------------------------------------------------------------------------
# default logical-axis rules (documented in DESIGN.md §6)
# ---------------------------------------------------------------------------

DEFAULT_RULES: Rules = {
    # -- activations --------------------------------------------------------
    # global batch: data parallelism over the pod and data axes
    "batch": ("pod", "data"),
    # residual-stream sequence axis. OFF by default — shape_rules enables
    # Megatron sequence parallelism ({"act_seq": ("model",)}) for
    # train/prefill shapes; decode and single-device paths leave it ()
    "act_seq": (),
    # attention-head axis of (B, S, H, hd) activations: tensor parallelism
    "heads": ("model",),
    # KV-head axis (GQA): same model axis, usually left to the sequence rule
    "kv_heads": ("model",),
    # FFN hidden axis (Megatron column/row-parallel MLP)
    "ffn": ("model",),
    # vocab axis of logits / embedding tables (the matmul-natural layout)
    "vocab": ("model",),
    # mamba d_inner / RG-LRU width: the recurrent channel axis
    "inner": ("model",),
    # d_model (residual) axis: never sharded — it is the contraction axis of
    # every layer boundary matmul
    "embed": (),
    # -- caches / artifacts -------------------------------------------------
    # sequence axis of KV caches and materialized artifacts. Sequence-sharded
    # by default so the collected prefill artifact and the decode cache never
    # replicate over the model axis (EXPERIMENTS.md §Perf); long_500k's
    # batch-1 override widens this to ("pod", "data", "model")
    "cache_seq": ("model",),
    # -- MoE ----------------------------------------------------------------
    # expert axis of routed expert weights (expert parallelism)
    "expert": ("model",),
    # per-expert capacity buffers inside the dispatch
    "expert_cap": ("pod", "data"),
}


# Serving-path overrides (DESIGN.md §12). Decode shards the KV-HEAD axis of
# row caches and the paged block pool (the same "model" mesh axis the wk/wv
# projections shard their output dim over, so each device projects, stores,
# gathers and attends over only its own KV heads — no per-step collectives
# on the KV hot path). cache_seq's default sequence sharding is the
# train/prefill artifact layout; sequence-sharding a paged pool would put
# the gather/scatter indirection behind cross-device collectives every
# decode step, so serving turns it off. act_seq is train/prefill-only.
SERVING_RULES: Rules = {
    "cache_seq": (),
    "act_seq": (),
}


def merge_rules(rules: Optional[Rules] = None) -> Rules:
    """Overrides MERGE ONTO the defaults; an explicit ``{"name": ()}`` entry
    is how a caller turns a default rule off."""
    if not rules:
        return dict(DEFAULT_RULES)
    return {**DEFAULT_RULES, **rules}


# ---------------------------------------------------------------------------
# divisibility-aware resolution
# ---------------------------------------------------------------------------

def _resolve_merged(mesh, dim: int, name: Optional[str], merged: Rules,
                    used: frozenset = frozenset()) -> Resolved:
    """Resolve one dimension against already-merged rules.

    Mesh axes absent from ``mesh`` (e.g. "pod" on a 2-axis debug mesh) and
    axes already consumed by an earlier dimension of the same spec are
    skipped; the longest remaining prefix whose product divides ``dim``
    wins; no divisible prefix -> None (replicated).
    """
    if name is None:
        return None
    try:
        axes = merged[name]
    except KeyError:
        raise KeyError(
            f"unknown logical axis {name!r}; known: {sorted(merged)}"
        ) from None
    axes = tuple(a for a in axes if a in mesh.shape and a not in used)
    for i in range(len(axes), 0, -1):
        extent = math.prod(mesh.shape[a] for a in axes[:i])
        if dim % extent == 0:
            return axes[0] if i == 1 else axes[:i]
    return None


def resolve(mesh, dim: int, name: Optional[str],
            rules: Optional[Rules] = None) -> Resolved:
    """Mesh axis (str), axis tuple, or None for one dimension of size ``dim``.

    ``rules`` is a partial override dict merged onto ``DEFAULT_RULES``.
    """
    return _resolve_merged(mesh, dim, name, merge_rules(rules))


def _spec_merged(mesh, dims, names, merged: Rules) -> P:
    """spec_for against already-merged rules, tracking used mesh axes so a
    PartitionSpec never names one mesh axis twice."""
    used: set = set()
    entries = []
    for dim, name in zip(dims, names):
        r = _resolve_merged(mesh, dim, name, merged, frozenset(used))
        if isinstance(r, str):
            used.add(r)
        elif r:
            used.update(r)
        entries.append(r)
    return P(*entries)


def spec_for(mesh, dims, names, rules: Optional[Rules] = None) -> P:
    """PartitionSpec for a shape ``dims`` whose dimensions carry logical
    ``names`` (None entries stay replicated)."""
    if len(dims) != len(names):
        raise ValueError(f"spec_for: {len(dims)} dims vs {len(names)} names")
    return _spec_merged(mesh, dims, names, merge_rules(rules))


# ---------------------------------------------------------------------------
# runtime context: the active (mesh, rules) pair
# ---------------------------------------------------------------------------

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dist_active", default=None)


def current_mesh():
    """The mesh installed by the innermost ``mesh_context``, or None."""
    active = _ACTIVE.get()
    return active[0] if active is not None else None


def current_rules() -> Rules:
    """The merged rules of the innermost ``mesh_context`` (defaults if none)."""
    active = _ACTIVE.get()
    return active[1] if active is not None else dict(DEFAULT_RULES)


@contextlib.contextmanager
def mesh_context(mesh, rules: Optional[Rules] = None):
    """Install (mesh, rules merged onto defaults) for ``shard`` /
    ``current_mesh`` within the block. Reentrant; the inner context wins."""
    token = _ACTIVE.set((mesh, merge_rules(rules)))
    try:
        yield mesh
    finally:
        _ACTIVE.reset(token)


def shard(x, *names):
    """Constrain ``x`` to the layout its logical ``names`` resolve to.

    One name per dimension; None names — and names whose rule is (), absent
    from the mesh, or indivisible — leave that dimension replicated. The
    constraint is *complete*: dimensions that resolve to None are pinned
    replicated, which is what callers rely on to force a gather (e.g. the
    flash-attention scan constrains its K operand replicated so GSPMD never
    gathers per block). Outside any ``mesh_context`` this is the identity.
    """
    if len(names) != x.ndim:
        # checked before the no-mesh early-out so single-device test runs
        # catch arity bugs too, not just the production mesh paths
        raise ValueError(
            f"shard: got {len(names)} names for rank-{x.ndim} value")
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, merged = active
    spec = _spec_merged(mesh, x.shape, names, merged)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
