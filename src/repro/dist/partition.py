"""PartitionSpec trees for whole pytrees: params, batches, caches, optimizer.

``param_specs`` walks a parameter pytree (real arrays or eval_shape
ShapeDtypeStructs) and assigns every leaf a PartitionSpec from the leaf's
*name* — the same nested-dict keys the model init functions use — via the
table below, resolved through :mod:`repro.dist.sharding`'s divisibility-aware
rules. Leading stack dimensions (vmapped layer stacks: leaves shaped
(L, ...)) are detected by rank and stay replicated.

The table is deliberately STRICT: an unrecognized parameter name raises
instead of silently replicating. Silent replication is exactly the failure
mode the partial-rule merge regression guards against (26 GiB of parameter
replicas per chip — see tests/test_dist.py), so new parameters must be added
here explicitly.

Conventions (Megatron-style tensor parallelism; DESIGN.md §6):
  * column-parallel into the hidden axis (wq / wi_* / in_proj / in_x: output
    dim sharded over "model"), row-parallel back out (wo / out_proj /
    x_proj: input dim sharded) — activations between them carry the sharded
    hidden axis, the residual stream stays replicated over "model" unless
    act_seq sequence parallelism is on.
  * the d_model axis ("embed") is never sharded: it is the contraction axis
    of every layer-boundary matmul.
  * MoE expert stacks shard their leading expert axis ("expert"); the router
    is replicated (it is tiny and every device routes its own tokens).
"""

from __future__ import annotations

import math
from typing import Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist.sharding import Rules, _spec_merged, merge_rules

# Logical names for the TRAILING dims of each named parameter leaf.
# Extra leading dims (layer stacks) are padded with None.
_PARAM_TRAILING: Dict[str, tuple] = {
    # embedding / head / frontend
    "embed": ("vocab", "embed"),
    "lm_head": ("embed", "vocab"),
    "projector": ("embed", "embed"),
    "frontend_proj": ("embed", "embed"),
    "enc_pos": (None, "embed"),
    "dec_pos": (None, "embed"),
    # norms (1-D gains / biases, incl. enc-dec LayerNorm {"w","b"} dicts)
    "final_norm": ("embed",),
    "ln1": ("embed",), "ln2": ("embed",), "ln3": ("embed",),
    "w": ("embed",), "b": ("embed",),
    "q_norm": (None,), "k_norm": (None,),
    # attention
    "wq": ("embed", "heads"),
    "wk": ("embed", "kv_heads"),
    "wv": ("embed", "kv_heads"),
    # mlp
    "wi_gate": ("embed", "ffn"),
    "wi_up": ("embed", "ffn"),
    "wi": ("embed", "ffn"),
    # "wo" is context-dependent (attention vs mlp) — see _trailing_names
    # moe
    "router": ("embed", None),
    "w_gate": ("expert", "embed", "ffn"),
    "w_up": ("expert", "embed", "ffn"),
    "w_down": ("expert", "ffn", "embed"),
    # mamba
    "in_proj": ("embed", "inner"),
    "conv_w": (None, "inner"),
    "conv_b": ("inner",),
    "x_proj": ("inner", None),
    "dt_proj_w": (None, "inner"),
    "dt_proj_b": ("inner",),
    "A_log": ("inner", None),
    "D": ("inner",),
    "out_proj": ("inner", "embed"),
    # rg-lru
    "in_x": ("embed", "inner"),
    "in_gate": ("embed", "inner"),
    "w_r": (None, "inner"),
    "w_i": (None, "inner"),
    "lam": ("inner",),
}

_ATTN_PARENTS = frozenset({"attn", "self_attn", "cross_attn"})


def _path_names(path) -> list:
    """String keys along a key path (dict keys / dataclass fields; list
    indices are skipped)."""
    names = []
    for entry in path:
        if hasattr(entry, "key"):
            names.append(str(entry.key))
        elif hasattr(entry, "name"):
            names.append(str(entry.name))
    return names


def _trailing_names(path) -> tuple:
    names = _path_names(path)
    if not names:
        raise ValueError(f"param leaf without a name at path {path!r}")
    leaf = names[-1]
    if leaf == "wo":
        # attention out-projection (qd, D) vs mlp down-projection (d_ff, D):
        # both row-parallel, under different logical names
        parent = names[-2] if len(names) > 1 else ""
        return ("heads", "embed") if parent in _ATTN_PARENTS \
            else ("ffn", "embed")
    try:
        return _PARAM_TRAILING[leaf]
    except KeyError:
        raise ValueError(
            f"param_specs: no sharding entry for parameter "
            f"{'.'.join(names)!r} — add it to repro.dist.partition."
            f"_PARAM_TRAILING (unnamed params silently replicate, which is "
            f"the regression this strictness prevents)") from None


def _leaf_spec(mesh, path, leaf, merged: Rules) -> P:
    trailing = _trailing_names(path)
    ndim = len(leaf.shape)
    if ndim < len(trailing):
        raise ValueError(
            f"param {'.'.join(_path_names(path))!r}: rank {ndim} below the "
            f"{len(trailing)} trailing dims its table entry names")
    names = (None,) * (ndim - len(trailing)) + tuple(trailing)
    return _spec_merged(mesh, leaf.shape, names, merged)


def param_specs(mesh, params, rules: Optional[Rules] = None):
    """PartitionSpec tree matching ``params`` (arrays or SDS)."""
    merged = merge_rules(rules)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_leaf_spec(mesh, path, leaf, merged) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def batch_specs(mesh, batch, rules: Optional[Rules] = None):
    """Specs for step inputs (tokens / labels / loss_mask (B, S), frontend
    embeddings (B, T, D)): batch-axis data parallelism, sequence axis under
    the ``act_seq`` rule (off by default, "model" under train/prefill
    rules), feature dims replicated."""
    merged = merge_rules(rules)

    def one(leaf):
        ndim = len(leaf.shape)
        names = ("batch", "act_seq") + (None,) * max(0, ndim - 2)
        return _spec_merged(mesh, leaf.shape, names[:ndim], merged)

    return jax.tree.map(one, batch)


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

# Trailing logical names per cache field; chosen by (name, rank) so the same
# field name across cache flavours (SSMCache.h is (L,B,din,st), HybridCache.h
# is (L,B,width)) maps correctly.
_CACHE_NAMES: Dict[tuple, tuple] = {
    # KV buffers (L, B, S_buf, KV, hd): sequence-sharded under the default
    # (train/prefill) rules; the serving rules turn cache_seq off and the
    # kv_heads axis carries the tensor parallelism instead (DESIGN.md §12)
    ("k", 5): (None, "batch", "cache_seq", "kv_heads", None),
    ("v", 5): (None, "batch", "cache_seq", "kv_heads", None),
    ("cross_k", 5): (None, "batch", "cache_seq", "kv_heads", None),
    ("cross_v", 5): (None, "batch", "cache_seq", "kv_heads", None),
    # recurrent state
    ("conv", 4): (None, "batch", None, "inner"),
    ("h", 4): (None, "batch", "inner", None),
    ("h", 3): (None, "batch", "inner"),
    # bookkeeping (replicated); rank-2 slot_pos / rank-1 length are the
    # row-slotted (RowAttnCache) per-row variants
    ("slot_pos", 1): (None,),
    ("slot_pos", 2): (None, None),
    ("length", 0): (),
    ("length", 1): (None,),
}


def cache_specs(mesh, cache, rules: Optional[Rules] = None):
    """Specs for a decode cache pytree (AttnCache / RowAttnCache / SSMCache /
    HybridCache / EncDecCache, real or eval_shape)."""
    merged = merge_rules(rules)
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    specs = []
    for path, leaf in flat:
        names = _path_names(path)
        field = names[-1] if names else ""
        ndim = len(leaf.shape)
        try:
            logical = _CACHE_NAMES[(field, ndim)]
        except KeyError:
            raise ValueError(
                f"cache_specs: no entry for cache field "
                f"{'.'.join(names)!r} of rank {ndim}") from None
        specs.append(_spec_merged(mesh, leaf.shape, logical, merged))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer-state sharding
# ---------------------------------------------------------------------------

def zero1_specs(mesh, params, p_specs, rules: Optional[Rules] = None):
    """Optimizer-moment specs: the param spec plus data-axis sharding of the
    first replicated, divisible dimension (ZeRO-1).

    AdamW's m/v are f32 shadows of the (often bf16) params — at production
    scale they dominate optimizer memory. Each moment leaf inherits its
    param's tensor-parallel spec and is additionally sharded over whichever
    of (pod, data) the param spec leaves unused, on the first dimension
    they divide; params with no eligible dimension keep the param spec
    (replicated moments, e.g. tiny norm gains)."""
    del rules  # moments follow the already-resolved param specs

    def one(leaf, spec):
        entries = list(spec) + [None] * (len(leaf.shape) - len(spec))
        used = set()
        for e in entries:
            if isinstance(e, str):
                used.add(e)
            elif e:
                used.update(e)
        avail = tuple(a for a in ("pod", "data")
                      if a in mesh.shape and a not in used)
        for i, e in enumerate(entries):
            if e is not None:
                continue
            for j in range(len(avail), 0, -1):
                extent = math.prod(mesh.shape[a] for a in avail[:j])
                if extent > 1 and leaf.shape[i] % extent == 0:
                    entries[i] = avail[0] if j == 1 else avail[:j]
                    return P(*entries)
        return P(*entries)

    return jax.tree.map(one, params, p_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# specs -> shardings
# ---------------------------------------------------------------------------

def to_shardings(mesh, specs):
    """Map every PartitionSpec leaf to a NamedSharding on ``mesh``."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
