"""Mesh / sharding layer: logical-axis rules, specs, runtime constraints.

Importing this package also installs the JAX version shims in
:mod:`repro.dist._compat` (AxisType / make_mesh / shard_map forward-compat
aliases for older JAX releases).
"""

from repro.dist import _compat  # noqa: F401  (must import first: jax shims)
from repro.dist.partition import (batch_specs, cache_specs, param_specs,
                                  to_shardings, zero1_specs)
from repro.dist.sharding import (DEFAULT_RULES, SERVING_RULES, current_mesh,
                                 current_rules, merge_rules, mesh_context,
                                 resolve, shard, spec_for)

__all__ = [
    "DEFAULT_RULES", "SERVING_RULES", "batch_specs", "cache_specs",
    "current_mesh", "current_rules", "merge_rules", "mesh_context",
    "param_specs", "resolve", "shard", "spec_for", "to_shardings",
    "zero1_specs",
]
