"""Version shims for the small set of new-JAX surface this repo uses.

The codebase targets the modern distribution API (``jax.make_mesh`` with
``axis_types``, ``jax.sharding.AxisType``, top-level ``jax.shard_map`` with
``check_vma``). Older JAX releases (<= 0.4.x) ship the same functionality
under earlier names:

  * ``jax.sharding.AxisType``       -> absent (all mesh axes are "auto")
  * ``jax.make_mesh(axis_types=..)`` -> no ``axis_types`` kwarg
  * ``jax.shard_map(check_vma=..)``  -> ``jax.experimental.shard_map.shard_map``
                                        with ``check_rep``

Importing :mod:`repro.dist` installs forward-compatible aliases for whichever
of these are missing, so the one source tree runs on both API generations.
Each shim is a no-op when the installed JAX already provides the name, and
installation is idempotent. No behaviour changes on new JAX.
"""

from __future__ import annotations

import enum
import functools
import inspect

import jax


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        """Stand-in for jax.sharding.AxisType on releases that predate it.

        Pre-AxisType JAX treats every mesh axis as what was later named
        ``Auto`` (GSPMD-propagated sharding), which is the only mode this
        repo uses — the values exist so call sites type-check, and
        ``axis_types`` arguments are dropped by the make_mesh shim below.
        """

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    if not hasattr(jax, "make_mesh"):
        # releases that predate jax.make_mesh entirely: synthesize it from
        # mesh_utils + Mesh
        from jax.experimental import mesh_utils

        def make_mesh(axis_shapes, axis_names, *, axis_types=None,
                      devices=None):
            del axis_types
            devs = mesh_utils.create_device_mesh(tuple(axis_shapes),
                                                 devices=devices)
            return jax.sharding.Mesh(devs, tuple(axis_names))

        jax.make_mesh = make_mesh
        return
    params = inspect.signature(jax.make_mesh).parameters
    if "axis_types" in params:
        return
    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kwargs):
        del axis_types  # pre-AxisType JAX: every axis is implicitly Auto
        return orig(axis_shapes, axis_names, **kwargs)

    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        sig = inspect.signature(jax.shard_map).parameters
        if "check_vma" in sig or "check_rep" not in sig:
            return
        orig_new = jax.shard_map

        @functools.wraps(orig_new)
        def shard_map_kw(f, **kwargs):
            if "check_vma" in kwargs:
                kwargs["check_rep"] = kwargs.pop("check_vma")
            return orig_new(f, **kwargs)

        jax.shard_map = shard_map_kw
        return

    from jax.experimental.shard_map import shard_map as orig

    @functools.wraps(orig)
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
        # old spelling: check_rep; vma (varying-manual-axes) checking is the
        # renamed successor of replication checking
        return orig(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                    check_rep=check_vma, **kwargs)

    jax.shard_map = shard_map


def _install_cost_analysis() -> None:
    """New JAX: ``Compiled.cost_analysis()`` returns one dict. Old JAX
    returned a one-element list of dicts. Normalize to the new shape."""
    from jax._src import stages

    orig = stages.Compiled.cost_analysis
    if getattr(orig, "_repro_dist_shim", False):
        return

    @functools.wraps(orig)
    def cost_analysis(self):
        out = orig(self)
        if isinstance(out, list) and len(out) == 1 and isinstance(out[0], dict):
            return out[0]
        return out

    cost_analysis._repro_dist_shim = True
    stages.Compiled.cost_analysis = cost_analysis


def install() -> None:
    _install_axis_type()
    _install_make_mesh()
    _install_shard_map()
    _install_cost_analysis()


install()
