"""CLI trace-schema validator: ``python -m repro.obs.validate trace.json...``

Exits non-zero (with a one-line reason) if any file fails
:func:`repro.obs.trace.validate_chrome` — the CI smoke step that keeps
exported traces loadable in Perfetto.
"""

from __future__ import annotations

import sys

from .trace import load_chrome, validate_chrome


def main(argv=None) -> int:
    paths = list(sys.argv[1:] if argv is None else argv)
    if not paths:
        print("usage: python -m repro.obs.validate TRACE.json [...]",
              file=sys.stderr)
        return 2
    rc = 0
    for path in paths:
        try:
            stats = validate_chrome(load_chrome(path))
        except (OSError, ValueError) as e:
            print(f"{path}: INVALID: {e}", file=sys.stderr)
            rc = 1
            continue
        print(f"{path}: ok ({stats['events']} events, "
              f"{stats['spans']} spans)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
